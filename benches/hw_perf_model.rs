//! Hardware claims (paper §1-2): analytic A100 roofline over the exact
//! dataflow — per-op memory/compute costs, the 2x LN data-volume claim,
//! and projected mode speedups at BERT_base scale.

use zqhero::bench::Table;
use zqhero::model::manifest::Switches;
use zqhero::perfmodel::{self, OpClass};

fn sw(tag: &str) -> Switches {
    let b: Vec<bool> = tag.chars().map(|c| c == '1').collect();
    Switches {
        embedding: b[0], qkv: b[1], attn: b[2],
        attn_output: b[3], fc1: b[4], fc2: b[5],
    }
}

fn main() {
    let cfg = perfmodel::bert_base();
    let (batch, seq) = (16usize, 128usize);
    println!("A100 analytic model — BERT_base, batch={batch}, seq={seq}");
    println!("HBM {:.0} GB/s, FP16 {:.0} TFLOPs, INT8 {:.0} TOPs, floor {:.0}us\n",
             perfmodel::HBM_BW_GBS, perfmodel::FP16_TFLOPS,
             perfmodel::INT8_TOPS, perfmodel::KERNEL_FLOOR_US);

    // per-op table for FP vs M3
    let n = batch * seq;
    let fp_ops = perfmodel::layer_ops(&cfg, &sw("000000"), n, seq);
    let m3_ops = perfmodel::layer_ops(&cfg, &sw("111111"), n, seq);
    let mut t = Table::new(&[
        "op", "class", "FP16 MB", "M3 MB", "vol ratio", "FP16 us", "M3 us", "speedup",
    ]);
    for (a, b) in fp_ops.iter().zip(&m3_ops) {
        t.row(vec![
            a.name.clone(),
            match a.class { OpClass::MemoryBound => "mem", OpClass::ComputeBound => "compute" }
                .into(),
            format!("{:.2}", a.bytes / 1e6),
            format!("{:.2}", b.bytes / 1e6),
            format!("{:.2}x", a.bytes / b.bytes),
            format!("{:.1}", a.time_us()),
            format!("{:.1}", b.time_us()),
            format!("{:.2}x", a.time_us() / b.time_us()),
        ]);
    }
    t.print();

    // LN data-volume claim (paper §2.2.1: ~2x)
    let fp_ln = fp_ops.iter().find(|o| o.name == "ln1").unwrap().bytes;
    let m3_ln = m3_ops.iter().find(|o| o.name == "ln1").unwrap().bytes;
    println!("\nLN^quant data-volume reduction: {:.2}x (paper claims ~2x)", fp_ln / m3_ln);

    // mode totals
    println!("\nprojected end-to-end (embedding + {} layers):", cfg.layers);
    let mut mt = Table::new(&["mode", "proj us", "speedup vs FP16"]);
    let fp_t = perfmodel::model_time_us(&cfg, &sw("000000"), batch, seq);
    for (label, tag) in [("FP16", "000000"), ("HERO-M1", "110010"),
                         ("HERO-M2", "111110"), ("HERO-M3", "111111")] {
        let t_us = perfmodel::model_time_us(&cfg, &sw(tag), batch, seq);
        mt.row(vec![label.into(), format!("{t_us:.0}"), format!("{:.2}x", fp_t / t_us)]);
    }
    mt.print();

    // the TWQ placement claim: unfused quantize penalizes the GeMM
    let fused = perfmodel::model_time_us(&cfg, &sw("111110"), batch, seq);
    let unfused = perfmodel::model_time_us(&cfg, &sw("110110"), batch, seq);
    println!("\nTWQ placement (paper §2.1): M2 fused {fused:.0}us vs attn-off/attn-out-on \
              unfused {unfused:.0}us");
}
