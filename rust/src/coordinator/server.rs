//! The serving coordinator: bounded admission queue -> dynamic batcher
//! thread -> engine (PJRT) thread -> completion workers.  This is the
//! "end-to-end system" the paper leaves as future work: batched W8A8
//! inference with per-request precision modes and zero Python anywhere.
//!
//! Hot-path discipline (DESIGN.md §5): route strings are interned to
//! `TaskId`/`ModeId` at admission; batch assembly writes into pooled
//! staging buffers; the engine overlaps upload/execute/readback; and
//! de-batching + reply dispatch run on the completion pool, never on the
//! engine thread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::exec::ThreadPool;
use crate::model::manifest::{Manifest, ModeId};
use crate::model::Container;
use crate::runtime::engine::{Engine, EngineOptions, InferDone, InferJob};
use crate::runtime::staging::StagingPool;

use super::batcher::{Batch, Batcher};
use super::request::{GroupKey, Request, Response, Timing};
use super::stats::Recorder;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    pub completion_workers: usize,
    /// Overlap upload/execute/readback in the engine (`false` = the
    /// pre-pipeline serial loop, kept for A/B benchmarking).
    pub pipeline: bool,
    /// Staging buffers kept warm per bucket.
    pub staging_per_bucket: usize,
    /// Test-only fault injection: the completion callback for this
    /// dispatch sequence number panics, exercising panic isolation in the
    /// readback/completion stage.  Never set in production.
    pub fault_inject_batch: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 1024,
            completion_workers: 4,
            pipeline: true,
            staging_per_bucket: 4,
            fault_inject_batch: None,
        }
    }
}

pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    batcher_join: Option<std::thread::JoinHandle<()>>,
    // Drop order matters (declaration order): the engine must shut down
    // (draining its queue into completion jobs) before the pool joins its
    // workers, so every admitted request gets a reply or a hangup.
    engine: Option<Arc<Engine>>,
    pool: Option<Arc<ThreadPool>>,
    pub recorder: Arc<Recorder>,
    man: Arc<Manifest>,
    /// `[task * num_modes + mode]` -> checkpoint resident in the engine.
    loaded: Vec<bool>,
    next_id: AtomicU64,
    seq: usize,
    num_labels: usize,
    pub config: ServerConfig,
}

impl Coordinator {
    /// Load checkpoints for the given (task, mode) pairs, spawn the engine
    /// and batcher, pre-compile every (mode, bucket) executable.
    pub fn start(
        artifacts: std::path::PathBuf,
        pairs: &[(String, String)],
        config: ServerConfig,
    ) -> Result<Coordinator> {
        let manifest = Manifest::load(&artifacts)?;
        let seq = manifest.seq;
        let num_labels = manifest.model.num_labels;
        let buckets = manifest.buckets.clone();

        // load quantized/fp checkpoints from disk
        let mut preload = Vec::new();
        let mut modes_used = std::collections::BTreeSet::new();
        let mut loaded = vec![false; manifest.num_tasks() * manifest.num_modes()];
        for (task, mode) in pairs {
            let t = manifest.task(task)?;
            let rel = checkpoint_rel(t, mode);
            let path = manifest.path(&rel);
            let ckpt = Container::read_file(&path)
                .with_context(|| {
                    format!("loading checkpoint {path:?} (run `repro quantize` first?)")
                })?
                .reordered(&manifest.mode(mode)?.params)?;
            let key =
                GroupKey { task: manifest.task_id(task)?, mode: manifest.mode_id(mode)? };
            loaded[route_slot(manifest.num_modes(), key)] = true;
            preload.push((task.clone(), mode.clone(), ckpt));
            modes_used.insert(mode.clone());
        }
        let precompile: Vec<(String, usize)> = modes_used
            .iter()
            .flat_map(|m| buckets.iter().map(move |b| (m.clone(), *b)))
            .collect();

        let pool = Arc::new(ThreadPool::new(config.completion_workers, "zqh-complete"));
        let staging = Arc::new(StagingPool::new(&buckets, seq, config.staging_per_bucket));
        let engine = Arc::new(Engine::spawn(
            artifacts,
            preload,
            precompile,
            Arc::clone(&pool),
            Arc::clone(&staging),
            EngineOptions { overlap: config.pipeline },
        )?);
        let man = Arc::new(manifest);
        let recorder = Arc::new(Recorder::new(man.mode_order.clone()));

        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(config.queue_cap);
        let batcher_cfg = config.clone();
        let b_recorder = Arc::clone(&recorder);
        let b_engine = Arc::clone(&engine);
        let b_man = Arc::clone(&man);
        let batcher_join = std::thread::Builder::new()
            .name("zqh-batcher".into())
            .spawn(move || {
                batcher_main(rx, batcher_cfg, b_man, b_engine, b_recorder, staging)
            })
            .context("spawn batcher")?;

        Ok(Coordinator {
            tx: Some(tx),
            batcher_join: Some(batcher_join),
            engine: Some(engine),
            pool: Some(pool),
            recorder,
            man,
            loaded,
            next_id: AtomicU64::new(0),
            seq,
            num_labels,
            config,
        })
    }

    /// Submit a request; `Err` on backpressure (queue full) or bad input.
    /// Route strings are interned here — nothing downstream sees them.
    pub fn submit(
        &self,
        task: &str,
        mode: &str,
        ids: Vec<i32>,
        type_ids: Vec<i32>,
    ) -> Result<Receiver<Response>> {
        if ids.len() != self.seq || type_ids.len() != self.seq {
            bail!("request must be exactly seq={} tokens (got {})", self.seq, ids.len());
        }
        let key = self.resolve(task, mode)?;
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            key,
            ids,
            type_ids,
            enqueued: Instant::now(),
            reply,
        };
        match self.tx.as_ref().expect("live").try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => Err(anyhow!("admission queue full (backpressure)")),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("coordinator stopped")),
        }
    }

    /// Intern (task, mode) and check the route has a resident checkpoint.
    fn resolve(&self, task: &str, mode: &str) -> Result<GroupKey> {
        let no_ckpt =
            || anyhow!("no checkpoint loaded for ({task},{mode}); not in this server's pairs");
        let key = GroupKey {
            task: self.man.task_id(task).map_err(|_| no_ckpt())?,
            mode: self.man.mode_id(mode).map_err(|_| no_ckpt())?,
        };
        if !self.loaded[route_slot(self.man.num_modes(), key)] {
            return Err(no_ckpt());
        }
        Ok(key)
    }

    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    pub fn seq(&self) -> usize {
        self.seq
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue; batcher drains and exits
        if let Some(j) = self.batcher_join.take() {
            let _ = j.join();
        }
        // engine before pool: Engine::drop drains its queue into
        // completion jobs; ThreadPool::drop then runs them all.
        drop(self.engine.take());
        drop(self.pool.take());
    }
}

/// Flat slot of a (task, mode) route in the `loaded` bitmap — the one
/// definition of the 2D->1D layout.
fn route_slot(num_modes: usize, key: GroupKey) -> usize {
    key.task.index() * num_modes + key.mode.index()
}

pub fn checkpoint_rel(task: &crate::model::manifest::TaskSpec, mode: &str) -> String {
    if mode == "fp" {
        task.checkpoint.clone()
    } else {
        format!("checkpoints/{}/hero-{}.bin", task.name, mode)
    }
}

fn batcher_main(
    rx: Receiver<Request>,
    config: ServerConfig,
    man: Arc<Manifest>,
    engine: Arc<Engine>,
    recorder: Arc<Recorder>,
    staging: Arc<StagingPool>,
) {
    let mut batcher = Batcher::new(config.max_batch, config.max_wait);
    let mut batch_seq: u64 = 0;
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req) {
                    dispatch(batch, &mut batch_seq, &config, &man, &engine, &recorder, &staging);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                for batch in batcher.drain_all() {
                    dispatch(batch, &mut batch_seq, &config, &man, &engine, &recorder, &staging);
                }
                break;
            }
        }
        for batch in batcher.tick(Instant::now()) {
            dispatch(batch, &mut batch_seq, &config, &man, &engine, &recorder, &staging);
        }
    }
}

/// Assemble a batch into a pooled staging buffer and hand it to the
/// engine with a completion callback (de-batching + reply dispatch, run
/// on the worker pool after readback).
fn dispatch(
    batch: Batch,
    batch_seq: &mut u64,
    config: &ServerConfig,
    man: &Arc<Manifest>,
    engine: &Arc<Engine>,
    recorder: &Arc<Recorder>,
    staging: &Arc<StagingPool>,
) {
    let real = batch.requests.len();
    let bucket = man.bucket_for(real);
    let dispatched = Instant::now();
    let seq_no = *batch_seq;
    *batch_seq += 1;

    let mut host = staging.take(bucket);
    for r in &batch.requests {
        host.push_row(&r.ids, &r.type_ids);
    }
    host.finish();

    let mode = batch.key.mode;
    let requests = batch.requests;
    let recorder = Arc::clone(recorder);
    let fault = config.fault_inject_batch;
    let done = Box::new(move |result: Result<InferDone>| {
        if fault == Some(seq_no) {
            panic!("fault injection: completion panic for batch {seq_no}");
        }
        match result {
            Ok(done) => {
                let logits = match done.logits.as_f32() {
                    Ok(v) => v.to_vec(),
                    Err(e) => {
                        let msg = format!("bad logits: {e}");
                        for r in requests {
                            send_error(&r, mode, &recorder, &msg);
                        }
                        return;
                    }
                };
                let nl = logits.len() / bucket;
                recorder.record_batch(mode, real, done.exec_us);
                for (row, r) in requests.into_iter().enumerate() {
                    let now = Instant::now();
                    let timing = Timing {
                        queue_us: dispatched.duration_since(r.enqueued).as_micros() as u64,
                        exec_us: done.exec_us,
                        total_us: now.duration_since(r.enqueued).as_micros() as u64,
                        batch_real: real,
                        bucket,
                        batch_seq: seq_no,
                    };
                    recorder.record_request(mode, timing.total_us, timing.queue_us, false);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        logits: logits[row * nl..(row + 1) * nl].to_vec(),
                        timing,
                        error: None,
                    });
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in requests {
                    send_error(&r, mode, &recorder, &msg);
                }
            }
        }
    });

    let job = InferJob { task: batch.key.task, mode, staging: host, done };
    if let Err(job) = engine.submit(job) {
        let job = *job;
        staging.put(job.staging);
        (job.done)(Err(anyhow!("engine unavailable")));
    }
}

fn send_error(r: &Request, mode: ModeId, recorder: &Recorder, msg: &str) {
    recorder.record_request(mode, 0, 0, true);
    let _ = r.reply.send(Response {
        id: r.id,
        logits: vec![],
        timing: Timing::default(),
        error: Some(msg.to_string()),
    });
}
