//! Outlier transplant: a *function-preserving* checkpoint transform that
//! injects per-feature activation outliers, reproducing the failure
//! mechanism behind the paper's CoLA collapse at M3.
//!
//! Real BERT develops large per-channel activation outliers during
//! pretraining; our build-time-trained tiny models do not, so plain
//! quantization barely hurts them (EXPERIMENTS.md Table 2).  To study the
//! paper's sensitivity claims on this substrate we exploit an exact
//! invariance of attention: scaling a subset of head-dim columns of `W_q`
//! by `alpha` while scaling the *same* columns of `W_k` by `1/alpha`
//! leaves `A = Q K^T` bit-identical in exact arithmetic — but `X_q` now
//! has `alpha`-scaled outlier channels that a per-tensor SQ scale must
//! cover, starving the remaining channels of resolution.  The same trick
//! applies to `(W_v, W_o-rows)` for the PV path.
//!
//! FP metrics are unchanged (up to f32 rounding); quantized modes degrade
//! with `alpha` exactly the way the paper's sensitive tasks do.

use anyhow::Result;

use crate::model::manifest::ModelCfg;
use crate::model::{Container, Tensor};

#[derive(Debug, Clone, Copy)]
pub struct OutlierSpec {
    /// scale factor applied to the selected channels
    pub alpha: f32,
    /// how many of the `head_dim` channels per head get scaled
    pub channels_per_head: usize,
    /// inject into the Q/K pair
    pub qk: bool,
    /// inject into the V/O pair
    pub vo: bool,
}

impl Default for OutlierSpec {
    fn default() -> Self {
        OutlierSpec { alpha: 32.0, channels_per_head: 4, qk: true, vo: true }
    }
}

fn scale_columns(w: &mut [f32], rows: usize, cols: usize, pick: &dyn Fn(usize) -> bool, f: f32) {
    for r in 0..rows {
        for c in 0..cols {
            if pick(c) {
                w[r * cols + c] *= f;
            }
        }
    }
}

fn scale_rows(w: &mut [f32], rows: usize, cols: usize, pick: &dyn Fn(usize) -> bool, f: f32) {
    for r in 0..rows {
        if pick(r) {
            for c in 0..cols {
                w[r * cols + c] *= f;
            }
        }
    }
}

/// Apply the transplant to an fp32 checkpoint (all layers).
pub fn inject_outliers(fp: &Container, cfg: &ModelCfg, spec: &OutlierSpec) -> Result<Container> {
    let d = cfg.hidden;
    let dh = cfg.head_dim();
    let k = spec.channels_per_head.min(dh);
    // channel c (merged-head index) is scaled iff its within-head index < k
    let pick = move |c: usize| c % dh < k;

    let mut out = Container::new();
    for (name, t) in &fp.entries {
        let mut t = t.clone();
        let is_layer = name.starts_with('L');
        if is_layer && spec.qk && name.ends_with("attn.q.w") {
            scale_columns(tensor_f32_mut(&mut t)?, d, d, &pick, spec.alpha);
        } else if is_layer && spec.qk && name.ends_with("attn.q.b") {
            for (c, v) in tensor_f32_mut(&mut t)?.iter_mut().enumerate() {
                if pick(c) {
                    *v *= spec.alpha;
                }
            }
        } else if is_layer && spec.qk && name.ends_with("attn.k.w") {
            scale_columns(tensor_f32_mut(&mut t)?, d, d, &pick, 1.0 / spec.alpha);
        } else if is_layer && spec.qk && name.ends_with("attn.k.b") {
            for (c, v) in tensor_f32_mut(&mut t)?.iter_mut().enumerate() {
                if pick(c) {
                    *v /= spec.alpha;
                }
            }
        } else if is_layer && spec.vo && name.ends_with("attn.v.w") {
            scale_columns(tensor_f32_mut(&mut t)?, d, d, &pick, spec.alpha);
        } else if is_layer && spec.vo && name.ends_with("attn.v.b") {
            for (c, v) in tensor_f32_mut(&mut t)?.iter_mut().enumerate() {
                if pick(c) {
                    *v *= spec.alpha;
                }
            }
        } else if is_layer && spec.vo && name.ends_with("attn.o.w") {
            scale_rows(tensor_f32_mut(&mut t)?, d, d, &pick, 1.0 / spec.alpha);
        }
        out.push(name, t);
    }
    Ok(out)
}

fn tensor_f32_mut(t: &mut Tensor) -> Result<&mut [f32]> {
    match &mut t.data {
        crate::model::TensorData::F32(v) => Ok(v.as_mut_slice()),
        _ => anyhow::bail!("expected f32 tensor"),
    }
}

/// Sanity helper for tests/benches: max |a-b| over two fp checkpoints'
/// forward logits is checked by the caller; here we verify the transform
/// touched what it should.
pub fn describe(spec: &OutlierSpec) -> String {
    format!(
        "alpha={} channels/head={} qk={} vo={}",
        spec.alpha, spec.channels_per_head, spec.qk, spec.vo
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab_size: 16,
            hidden: 8,
            layers: 1,
            heads: 2,
            ffn: 16,
            max_seq: 4,
            type_vocab: 2,
            num_labels: 3,
            ln_eps: 1e-12,
        }
    }

    fn tiny_ckpt() -> Container {
        let mut c = Container::new();
        let d = 8;
        for name in ["L0.attn.q.w", "L0.attn.k.w", "L0.attn.v.w", "L0.attn.o.w"] {
            c.push(name, Tensor::f32(vec![d, d], (0..d * d).map(|i| i as f32 + 1.0).collect()));
        }
        for name in ["L0.attn.q.b", "L0.attn.k.b", "L0.attn.v.b"] {
            c.push(name, Tensor::f32(vec![d], (0..d).map(|i| i as f32 + 1.0).collect()));
        }
        c.push("pool.w", Tensor::f32(vec![d, d], vec![1.0; d * d]));
        c
    }

    #[test]
    fn qk_product_preserved() {
        // (q.w scaled col) x (k.w inverse-scaled col): per-feature products
        // q[:,c]*k[:,c] must be unchanged — that is what keeps A invariant.
        let cfg = tiny_cfg();
        let fp = tiny_ckpt();
        let spec = OutlierSpec { alpha: 16.0, channels_per_head: 2, qk: true, vo: false };
        let out = inject_outliers(&fp, &cfg, &spec).unwrap();
        let q0 = fp.get("L0.attn.q.w").unwrap().as_f32().unwrap();
        let k0 = fp.get("L0.attn.k.w").unwrap().as_f32().unwrap();
        let q1 = out.get("L0.attn.q.w").unwrap().as_f32().unwrap();
        let k1 = out.get("L0.attn.k.w").unwrap().as_f32().unwrap();
        for i in 0..q0.len() {
            let before = q0[i] * k0[i];
            let after = q1[i] * k1[i];
            assert!((before - after).abs() <= before.abs() * 1e-6);
        }
        // and the selected columns really are outliers now
        let dh = cfg.head_dim();
        assert!(q1[0] == q0[0] * 16.0); // col 0: within-head idx 0 < 2
        assert!(q1[dh - 1] == q0[dh - 1]); // last col of head: untouched
    }

    #[test]
    fn vo_product_preserved() {
        let cfg = tiny_cfg();
        let fp = tiny_ckpt();
        let spec = OutlierSpec { alpha: 8.0, channels_per_head: 1, qk: false, vo: true };
        let out = inject_outliers(&fp, &cfg, &spec).unwrap();
        let d = 8;
        let v0 = fp.get("L0.attn.v.w").unwrap().as_f32().unwrap();
        let o0 = fp.get("L0.attn.o.w").unwrap().as_f32().unwrap();
        let v1 = out.get("L0.attn.v.w").unwrap().as_f32().unwrap();
        let o1 = out.get("L0.attn.o.w").unwrap().as_f32().unwrap();
        // (v column c) * (o row c) contributions preserved
        for c in 0..d {
            for j in 0..d {
                let before = v0[j * d + c] * o0[c * d + j];
                let after = v1[j * d + c] * o1[c * d + j];
                assert!((before - after).abs() <= before.abs() * 1e-6 + 1e-9);
            }
        }
    }

    #[test]
    fn untouched_params_identical() {
        let cfg = tiny_cfg();
        let fp = tiny_ckpt();
        let out = inject_outliers(&fp, &cfg, &OutlierSpec::default()).unwrap();
        assert_eq!(out.get("pool.w").unwrap(), fp.get("pool.w").unwrap());
        assert_eq!(out.len(), fp.len());
    }
}
