//! End-to-end serving benchmark (the paper's missing "system performance
//! measurement"): closed-loop load through the coordinator, per mode, with
//! and without dynamic batching — latency percentiles + throughput.
//!
//! Env: ZQH_REQUESTS (default 128), ZQH_TASK (default sst2).

use std::collections::VecDeque;
use std::time::Duration;

use zqhero::bench::Table;
use zqhero::coordinator::{Coordinator, ServerConfig};
use zqhero::data::Split;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::Runtime;

fn run_load(
    coord: &Coordinator,
    task: &str,
    mode: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
    concurrency: usize,
) -> (f64, Vec<f64>) {
    let t0 = std::time::Instant::now();
    let mut inflight = VecDeque::new();
    let (mut submitted, mut done) = (0usize, 0usize);
    let mut lat = Vec::with_capacity(requests);
    while done < requests {
        while submitted < requests && inflight.len() < concurrency {
            let (ids, tys) = rows[submitted % rows.len()].clone();
            match coord.submit(task, mode, ids, tys) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                }
                Err(_) => break,
            }
        }
        let rx = inflight.pop_front().expect("inflight");
        let resp = rx.recv().expect("resp");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        lat.push(resp.timing.total_us as f64);
        done += 1;
    }
    (t0.elapsed().as_secs_f64(), lat)
}

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("e2e_serving: run `make artifacts` first");
        return;
    }
    let requests: usize =
        std::env::var("ZQH_REQUESTS").ok().and_then(|s| s.parse().ok()).unwrap_or(128);
    let tname = std::env::var("ZQH_TASK").unwrap_or_else(|_| "sst2".into());
    let modes = ["fp", "m1", "m2", "m3"];

    // prep quantized checkpoints
    {
        let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
        let task = rt.manifest.task(&tname).unwrap().clone();
        let hist = eh::ensure_calibration(&mut rt, &task, 100, false).unwrap();
        for m in modes.iter().filter(|m| **m != "fp") {
            let rel = zqhero::coordinator::checkpoint_rel(&task, m);
            if !rt.manifest.path(&rel).exists() {
                eh::quantize_task(&mut rt, &task, m, &hist, 100.0, None).unwrap();
            }
        }
    }
    let man = Manifest::load(&dir).unwrap();
    let task = man.task(&tname).unwrap();
    let split = Split::load(&man, task, "dev").unwrap();
    let rows: Vec<(Vec<i32>, Vec<i32>)> = (0..split.len().min(256))
        .map(|i| {
            let (a, b) = split.row(i);
            (a.to_vec(), b.to_vec())
        })
        .collect();

    println!("\ne2e serving on {tname}: {requests} requests per config\n");
    let mut t = Table::new(&[
        "mode", "batching", "thr req/s", "p50 ms", "p95 ms", "p99 ms", "mean batch",
    ]);
    for (label, max_batch, conc) in [("dynamic b<=16", 16usize, 48usize), ("none (b=1)", 1, 4)] {
        let pairs: Vec<(String, String)> =
            modes.iter().map(|m| (tname.clone(), m.to_string())).collect();
        let coord = Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig {
                max_batch,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
                completion_workers: 4,
            },
        )
        .expect("coordinator");
        for m in modes {
            let (wall, mut lat) = run_load(&coord, &tname, m, &rows, requests, conc);
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let pick = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize] / 1e3;
            let snap = coord.recorder.snapshot();
            t.row(vec![
                m.to_string(),
                label.into(),
                format!("{:.1}", requests as f64 / wall),
                format!("{:.1}", pick(0.50)),
                format!("{:.1}", pick(0.95)),
                format!("{:.1}", pick(0.99)),
                format!("{:.2}", snap[m].mean_batch_size()),
            ]);
        }
    }
    t.print();
    println!("\n(CPU PJRT testbed; A100 projections in hw_perf_model)");
}
