//! L3 coordinator: the paper's missing "end-to-end system" — typed
//! request specs, dynamic batching, per-request precision *policies*
//! (whole-model mode + per-module overrides + fallback escalation),
//! backpressure, and serving metrics over the PJRT engine thread.

pub mod batcher;
pub mod net;
pub mod request;
pub mod server;
pub mod stats;

pub use batcher::{Batch, Batcher};
pub use request::{GroupKey, PolicyRef, Request, RequestSpec, Response, Timing};
pub use server::{Coordinator, ServerConfig};
pub use net::{NetClient, NetServer};
pub use stats::{Histogram, PolicyStats, Recorder, ReplicaStats};
