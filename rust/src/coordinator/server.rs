//! The serving coordinator: bounded admission queue -> dynamic batcher
//! thread -> engine (PJRT) replica pool -> completion workers.  This is the
//! "end-to-end system" the paper leaves as future work: batched W8A8
//! inference with per-request precision *policies* and zero Python
//! anywhere.
//!
//! Hot-path discipline (DESIGN.md §5-§6): `RequestSpec` policy references
//! are interned to `TaskId`/`PolicyId` at admission; batch assembly
//! writes into pooled staging buffers; the engine overlaps
//! upload/execute/readback and selects executables through its mirrored
//! policy table; and de-batching + reply dispatch run on the completion
//! pool, never on the engine thread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::exec::ThreadPool;
use crate::model::manifest::{Manifest, ModeId, PolicyId, TaskId};
use crate::model::Container;
use crate::runtime::engine::{EngineOptions, EnginePool, InferDone, InferJob};
use crate::runtime::staging::StagingPool;

use super::batcher::{Batch, Batcher};
use super::request::{GroupKey, PolicyRef, Request, RequestSpec, Response, Timing};
use super::stats::Recorder;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    pub completion_workers: usize,
    /// Overlap upload/execute/readback in the engine (`false` = the
    /// pre-pipeline serial loop, kept for A/B benchmarking).
    pub pipeline: bool,
    /// Engine replicas behind the load-aware dispatcher (min 1).  Each
    /// replica owns its own PJRT runtime with preloaded checkpoints and
    /// precompiled executables (DESIGN.md §5.7).
    pub replicas: usize,
    /// Staging buffers kept warm per bucket.
    pub staging_per_bucket: usize,
    /// Test-only fault injection: the completion callback for this
    /// dispatch sequence number panics, exercising panic isolation in the
    /// readback/completion stage.  Never set in production.
    pub fault_inject_batch: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 1024,
            completion_workers: 4,
            pipeline: true,
            replicas: 1,
            staging_per_bucket: 4,
            fault_inject_batch: None,
        }
    }
}

pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    batcher_join: Option<std::thread::JoinHandle<()>>,
    // Drop order matters (declaration order): the engine pool must shut
    // down (each replica draining its queue into completion jobs, joined
    // in replica order) before the worker pool joins, so every admitted
    // request gets a reply or a hangup.
    engine: Option<Arc<EnginePool>>,
    pool: Option<Arc<ThreadPool>>,
    pub recorder: Arc<Recorder>,
    man: Arc<Manifest>,
    /// `[task * num_modes + exec_mode]` -> checkpoint resident in the
    /// engine.  Residency is per executable *mode*: policies that resolve
    /// to the same exec mode share a checkpoint.
    loaded: Vec<bool>,
    next_id: AtomicU64,
    seq: usize,
    num_labels: usize,
    pub config: ServerConfig,
}

impl Coordinator {
    /// Load checkpoints for the given (task, policy) routes — mode names
    /// work as uniform policies — spawn the engine and batcher, and
    /// pre-compile every (exec mode, bucket) executable.
    pub fn start(
        artifacts: std::path::PathBuf,
        routes: &[(String, String)],
        config: ServerConfig,
    ) -> Result<Coordinator> {
        let manifest = Manifest::load(&artifacts)?;
        let seq = manifest.seq;
        let num_labels = manifest.model.num_labels;
        let buckets = manifest.buckets.clone();

        // load quantized/fp checkpoints from disk, one per (task, exec
        // mode) — routes naming policies with the same exec mode dedupe
        let mut preload = Vec::new();
        let mut modes_used = std::collections::BTreeSet::new();
        let mut loaded = vec![false; manifest.num_tasks() * manifest.num_modes()];
        for (task, policy) in routes {
            let t = manifest.task(task)?;
            let exec = manifest.policy(policy)?.exec_mode;
            let mode = manifest.mode_name(exec).to_string();
            let slot = route_slot(manifest.num_modes(), manifest.task_id(task)?, exec);
            if loaded[slot] {
                continue;
            }
            let rel = t.checkpoint_rel(&mode);
            let path = manifest.path(&rel);
            let ckpt = Container::read_file(&path)
                .with_context(|| {
                    format!("loading checkpoint {path:?} (run `repro quantize` first?)")
                })?
                .reordered(&manifest.mode(&mode)?.params)?;
            loaded[slot] = true;
            preload.push((task.clone(), mode.clone(), ckpt));
            modes_used.insert(mode);
        }
        let precompile: Vec<(String, usize)> = modes_used
            .iter()
            .flat_map(|m| buckets.iter().map(move |b| (m.clone(), *b)))
            .collect();

        let pool = Arc::new(ThreadPool::new(config.completion_workers, "zqh-complete"));
        let staging = Arc::new(StagingPool::new(&buckets, seq, config.staging_per_bucket));
        let replicas = config.replicas.max(1);
        let engine = Arc::new(EnginePool::spawn(
            artifacts,
            preload,
            precompile,
            Arc::clone(&pool),
            Arc::clone(&staging),
            EngineOptions { overlap: config.pipeline, replicas },
        )?);
        let man = Arc::new(manifest);
        let recorder = Arc::new(Recorder::new(man.policy_order.clone(), replicas));

        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(config.queue_cap);
        let batcher_cfg = config.clone();
        let b_recorder = Arc::clone(&recorder);
        let b_engine = Arc::clone(&engine);
        let b_man = Arc::clone(&man);
        let batcher_join = std::thread::Builder::new()
            .name("zqh-batcher".into())
            .spawn(move || {
                batcher_main(rx, batcher_cfg, b_man, b_engine, b_recorder, staging)
            })
            .context("spawn batcher")?;

        Ok(Coordinator {
            tx: Some(tx),
            batcher_join: Some(batcher_join),
            engine: Some(engine),
            pool: Some(pool),
            recorder,
            man,
            loaded,
            next_id: AtomicU64::new(0),
            seq,
            num_labels,
            config,
        })
    }

    /// Submit a typed request; `Err` on backpressure (queue full) or bad
    /// input.  Policy references are interned here — nothing downstream
    /// sees a string.  Short `ids`/`type_ids` are padded to the model seq.
    pub fn submit(&self, spec: RequestSpec) -> Result<Receiver<Response>> {
        let RequestSpec { task, policy, mut ids, type_ids } = spec;
        if ids.is_empty() || ids.len() > self.seq {
            bail!("request needs 1..={} token ids (got {})", self.seq, ids.len());
        }
        ids.resize(self.seq, crate::data::PAD);
        let mut type_ids = type_ids.unwrap_or_default();
        if type_ids.len() > self.seq {
            bail!("type_ids longer than seq {} (got {})", self.seq, type_ids.len());
        }
        type_ids.resize(self.seq, 0);
        let key = self.resolve(&task, policy.as_ref())?;
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            key,
            ids,
            type_ids,
            enqueued: Instant::now(),
            reply,
        };
        match self.tx.as_ref().expect("live").try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => Err(anyhow!("admission queue full (backpressure)")),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("coordinator stopped")),
        }
    }

    /// Intern (task, policy) and check the policy's executable mode has a
    /// resident checkpoint.
    fn resolve(&self, task: &str, policy: Option<&PolicyRef>) -> Result<GroupKey> {
        let label = match policy {
            None => self.man.mode_order.first().cloned().unwrap_or_default(),
            Some(PolicyRef::Named(n)) => n.clone(),
            Some(PolicyRef::Inline(_)) => "<inline>".to_string(),
        };
        let no_ckpt = |detail: &str| {
            anyhow!(
                "no checkpoint loaded for ({task},{label}){detail}; not in this server's routes"
            )
        };
        let task_id = self.man.task_id(task).map_err(|_| no_ckpt(""))?;
        let pid = match policy {
            None => PolicyId(0), // uniform policy of the manifest's first mode
            Some(PolicyRef::Named(n)) => self.man.policy_id(n).map_err(|_| no_ckpt(""))?,
            Some(PolicyRef::Inline(draft)) => self.man.intern_inline_policy(draft)?,
        };
        let exec = self.man.policy_by_id(pid).exec_mode;
        if !self.loaded[route_slot(self.man.num_modes(), task_id, exec)] {
            let detail = format!(" — policy executes mode {:?}", self.man.mode_name(exec));
            return Err(no_ckpt(&detail));
        }
        Ok(GroupKey { task: task_id, policy: pid })
    }

    /// The coordinator-side manifest (policy/route tables; parity tests
    /// compare these against the engine's mirrored tables).
    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    /// The engine pool handle (mirrored route/policy tables, dispatch
    /// state introspection).
    pub fn engine(&self) -> &EnginePool {
        self.engine.as_ref().expect("engine live")
    }

    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    pub fn seq(&self) -> usize {
        self.seq
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue; batcher drains and exits
        if let Some(j) = self.batcher_join.take() {
            let _ = j.join();
        }
        // engine pool before worker pool: EnginePool::drop stops every
        // replica (queues drain concurrently into completion jobs) and
        // joins them in replica order; ThreadPool::drop then runs all
        // pending completions.
        drop(self.engine.take());
        drop(self.pool.take());
    }
}

/// Flat slot of a (task, exec mode) route in the `loaded` bitmap — the
/// one definition of the 2D->1D layout.
fn route_slot(num_modes: usize, task: TaskId, mode: ModeId) -> usize {
    task.index() * num_modes + mode.index()
}

fn batcher_main(
    rx: Receiver<Request>,
    config: ServerConfig,
    man: Arc<Manifest>,
    engine: Arc<EnginePool>,
    recorder: Arc<Recorder>,
    staging: Arc<StagingPool>,
) {
    let mut batcher = Batcher::new(config.max_batch, config.max_wait);
    let mut batch_seq: u64 = 0;
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req) {
                    dispatch(batch, &mut batch_seq, &config, &man, &engine, &recorder, &staging);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                for batch in batcher.drain_all() {
                    dispatch(batch, &mut batch_seq, &config, &man, &engine, &recorder, &staging);
                }
                break;
            }
        }
        for batch in batcher.tick(Instant::now()) {
            dispatch(batch, &mut batch_seq, &config, &man, &engine, &recorder, &staging);
        }
    }
}

/// Assemble a batch into a pooled staging buffer and hand it to the
/// engine pool with a completion callback (de-batching + reply dispatch,
/// run on the worker pool after readback).  The pool routes the batch to
/// the group's pinned replica, or the least-loaded one.
fn dispatch(
    batch: Batch,
    batch_seq: &mut u64,
    config: &ServerConfig,
    man: &Arc<Manifest>,
    engine: &Arc<EnginePool>,
    recorder: &Arc<Recorder>,
    staging: &Arc<StagingPool>,
) {
    let real = batch.requests.len();
    let bucket = man.bucket_for(real);
    let dispatched = Instant::now();
    let seq_no = *batch_seq;
    *batch_seq += 1;

    let mut host = staging.take(bucket);
    for r in &batch.requests {
        host.push_row(&r.ids, &r.type_ids);
    }
    host.finish();

    let policy = batch.key.policy;
    let requests = batch.requests;
    let recorder = Arc::clone(recorder);
    let fault = config.fault_inject_batch;
    let done = Box::new(move |result: Result<InferDone>| {
        if fault == Some(seq_no) {
            panic!("fault injection: completion panic for batch {seq_no}");
        }
        match result {
            Ok(done) => {
                let logits = match done.logits.as_f32() {
                    Ok(v) => v.to_vec(),
                    Err(e) => {
                        let msg = format!("bad logits: {e}");
                        for r in requests {
                            send_error(&r, policy, &recorder, &msg);
                        }
                        return;
                    }
                };
                let nl = logits.len() / bucket;
                recorder.record_batch(policy, real, done.exec_us, done.replica);
                for (row, r) in requests.into_iter().enumerate() {
                    let now = Instant::now();
                    let timing = Timing {
                        queue_us: dispatched.duration_since(r.enqueued).as_micros() as u64,
                        exec_us: done.exec_us,
                        upload_us: done.upload_us,
                        engine_us: done.engine_us,
                        total_us: now.duration_since(r.enqueued).as_micros() as u64,
                        batch_real: real,
                        bucket,
                        batch_seq: seq_no,
                        replica: done.replica,
                        engine_seq: done.exec_seq,
                    };
                    recorder.record_request(policy, timing.total_us, timing.queue_us, false);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        policy,
                        logits: logits[row * nl..(row + 1) * nl].to_vec(),
                        timing,
                        error: None,
                    });
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in requests {
                    send_error(&r, policy, &recorder, &msg);
                }
            }
        }
    });

    let job = InferJob { task: batch.key.task, policy, staging: host, done };
    if let Err(job) = engine.submit(job) {
        let job = *job;
        staging.put(job.staging);
        (job.done)(Err(anyhow!("engine unavailable")));
    }
}

fn send_error(r: &Request, policy: PolicyId, recorder: &Recorder, msg: &str) {
    recorder.record_request(policy, 0, 0, true);
    let _ = r.reply.send(Response {
        id: r.id,
        policy,
        logits: vec![],
        timing: Timing::default(),
        error: Some(msg.to_string()),
    });
}
