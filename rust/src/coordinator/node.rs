//! Two-tier serving (DESIGN.md §5.14): a front-end process that owns
//! net admission, depth bounding, deadlines, and the precision
//! governor, routing typed requests to N engine-node processes over
//! persistent pipelined links.
//!
//! The pieces:
//!
//! * [`NodeDispatch`] — `DispatchState`'s fewest-in-flight routing
//!   lifted one tier up: (task, policy, seq class) groups pin to an
//!   engine *node* while they have requests in flight and migrate to
//!   the least-loaded live node once drained.  Same generation-tag
//!   discipline: node death stales every outstanding completion.
//! * [`EngineNode`] — a listener wrapping a local [`Coordinator`]
//!   (engine pool + residency manager) behind the v2 protocol.  One
//!   connection carries many requests concurrently: frames are
//!   length-delimited and correlated by an `"id"` field, and replies
//!   stream back in completion order, not submission order.
//! * [`FrontEnd`] — the admission tier.  `submit` mirrors
//!   `Coordinator::submit` (validation, policy interning, governor
//!   steering, depth-bounded shed) but forwards the request as a wire
//!   frame to the node `NodeDispatch` picked.  Node death is handled
//!   the way dead replicas are handled in-process: exclude the node,
//!   purge its pins, sweep its in-flight entries and retry them on a
//!   live node, and keep `admitted = completed + shed + expired +
//!   failed` reconciling exactly on this tier's ledger.
//!
//! Outcome classes cross the tier boundary typed: an engine node's
//! `Busy` / `expired` / `ReplicaFailed` arrive as the same wire flags
//! the public protocol already defines (`net::response_to_json` is the
//! single mapping), and the front end re-types them from those flags —
//! never by parsing error strings.  A node-side `Busy` lands after the
//! front end has already handed the client a receiver, so it surfaces
//! as a terminal `Response { busy: true, .. }`.
//!
//! Delivery is at-least-once across node death: a request whose node
//! died after executing but before its reply crossed the link is
//! retried on a live node.  Requests are single-shot classifications —
//! re-execution is idempotent — and every retry re-routes through the
//! current pin table, so the FIFO witness within a (task, policy,
//! seq-class) group still holds per node incarnation.
//!
//! Concurrency: `NodeDispatch` rides `crate::sync` so heromck can
//! explore its schedules (tests/mck_models.rs); the link machinery
//! below it owns OS sockets heromck does not model and uses `std`
//! directly, like `coordinator/net` (see sync/mod.rs).  Lock ordering
//! is trivial by construction — no code path holds two of
//! {pins, pending, writer} at once.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{
    AtomicBool as StdAtomicBool, AtomicU64 as StdAtomicU64, AtomicUsize as StdAtomicUsize,
    Ordering as StdOrdering,
};
use std::sync::{Arc, Mutex as StdMutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::json::{self, Value};
use crate::model::manifest::{Manifest, PolicyId, TaskId};
use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use crate::sync::Mutex;

use super::governor::{GovernorConfig, GovernorShared, PrecisionGovernor, Signals};
use super::net::{parse_request, request_to_json, response_to_json, BackoffSchedule};
use super::request::{PolicyRef, RequestSpec, Response, Timing};
use super::server::{Coordinator, SubmitError};
use super::stats::Recorder;

// ------------------------------------------------------------- dispatch

/// Routing key one tier up from `DispatchState`'s `(task, policy)`: the
/// sequence-length class joins the key so each seq bucket of a route
/// pins (and migrates) independently — long and short traffic of one
/// policy may land on different nodes, but each class keeps same-node
/// FIFO execution while it has requests in flight.
pub type NodeKey = (TaskId, PolicyId, usize);

/// Load-aware engine-*node* dispatch state, shared by `FrontEnd::submit`
/// (client threads), link readers (reply completions), and the link
/// supervisors: per-node in-flight request counts, liveness, incarnation
/// generations, and per-group pins.  The state machine is
/// `runtime::DispatchState` verbatim with the node-tier key — a group is
/// pinned to one node while it has requests in flight and may migrate to
/// the least-loaded node once it drains; `mark_dead` bumps the node's
/// generation so completions addressed to a dead incarnation can never
/// touch a reconnected node's accounting.  Pure state machine: unit-,
/// property-, and model-tested without sockets.
pub struct NodeDispatch {
    /// Requests forwarded to each node and not yet completed.
    inflight: Vec<AtomicUsize>,
    /// Nodes currently out of service (link down or excluded): excluded
    /// from least-loaded choice so a dead node — which would otherwise
    /// sit at zero in-flight and win every tie — cannot attract all
    /// traffic and turn one failure into a full outage.
    dead: Vec<AtomicBool>,
    /// Incarnation counter per node: bumped by `mark_dead`, left
    /// unchanged by `revive`.  A completion whose generation predates
    /// the current one is stale and dropped.
    generation: Vec<AtomicU64>,
    /// group -> (pinned node, group requests in flight).  Entries exist
    /// only while a group has in-flight requests, so the map stays at
    /// the handful of currently-active routes.
    pins: Mutex<HashMap<NodeKey, (usize, usize)>>,
}

impl NodeDispatch {
    pub fn new(nodes: usize) -> Self {
        assert!(nodes > 0, "node dispatch needs at least one engine node");
        NodeDispatch {
            inflight: (0..nodes).map(|_| AtomicUsize::new(0)).collect(),
            dead: (0..nodes).map(|_| AtomicBool::new(false)).collect(),
            generation: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            pins: Mutex::new(HashMap::new()),
        }
    }

    pub fn nodes(&self) -> usize {
        self.inflight.len()
    }

    /// Requests forwarded to `node` and not yet completed.
    pub fn inflight(&self, node: usize) -> usize {
        self.inflight[node].load(Ordering::SeqCst)
    }

    pub fn alive(&self, node: usize) -> bool {
        !self.dead[node].load(Ordering::SeqCst)
    }

    /// The node's incarnation generation (== its death count).
    pub fn generation(&self, node: usize) -> u64 {
        self.generation[node].load(Ordering::SeqCst)
    }

    /// Groups currently pinned to a node (tests / introspection).
    pub fn pinned_groups(&self) -> usize {
        // panic-ok: pins critical sections are map/counter ops that cannot
        // panic while holding the lock
        self.pins.lock().expect("node pins").len()
    }

    /// Pick the node for one request of `key` and account it in flight:
    /// the pinned node while the group already has requests in flight,
    /// else the live node with the fewest in-flight requests (ties break
    /// to the lowest index; if every node is dead the choice falls back
    /// to all of them — the send will fail either way and the request
    /// re-routes).  Returns the node and its generation at assignment
    /// time; the completion must echo both to `complete`.
    pub fn assign(&self, key: NodeKey) -> (usize, u64) {
        // panic-ok: pins critical sections are panic-free (see pinned_groups)
        let mut pins = self.pins.lock().expect("node pins");
        let node = match pins.get_mut(&key) {
            Some((node, n)) => {
                *n += 1;
                *node
            }
            None => {
                let node = (0..self.inflight.len())
                    .filter(|n| self.alive(*n))
                    .min_by_key(|n| self.inflight[*n].load(Ordering::SeqCst))
                    .unwrap_or_else(|| {
                        (0..self.inflight.len())
                            .min_by_key(|n| self.inflight[*n].load(Ordering::SeqCst))
                            // panic-ok: construction rejects zero nodes
                            .expect("at least one node")
                    });
                pins.insert(key, (node, 1));
                node
            }
        };
        // incremented under the pins lock so a concurrent completion
        // cannot interleave between node choice and accounting
        self.inflight[node].fetch_add(1, Ordering::SeqCst);
        (node, self.generation[node].load(Ordering::SeqCst))
    }

    /// Mark one request of `key` complete on `node`; the group unpins
    /// (and may migrate on its next request) when its last in-flight
    /// request completes.  A completion tagged with a stale generation —
    /// or whose group is no longer pinned to `node` — belongs to a dead
    /// incarnation whose accounting `mark_dead` already purged, and is
    /// dropped without touching the live state.
    pub fn complete(&self, key: NodeKey, node: usize, generation: u64) {
        if self.generation[node].load(Ordering::SeqCst) != generation {
            return;
        }
        // panic-ok: pins critical sections are panic-free (see pinned_groups)
        let mut pins = self.pins.lock().expect("node pins");
        match pins.get_mut(&key) {
            Some((n, count)) if *n == node => {
                *count -= 1;
                if *count == 0 {
                    pins.remove(&key);
                }
                self.inflight[node].fetch_sub(1, Ordering::SeqCst);
            }
            _ => {}
        }
    }

    /// Take `node` out of service: exclude it from least-loaded choices,
    /// bump its generation (staling every outstanding completion), and
    /// purge its pins so affected groups migrate on their next request.
    /// The link layer pairs this with a pending-map sweep so none of
    /// those requests is lost — each is retried on a live node or
    /// answered with a typed `failed` reply.
    pub fn mark_dead(&self, node: usize) {
        self.dead[node].store(true, Ordering::SeqCst);
        self.generation[node].fetch_add(1, Ordering::SeqCst);
        // panic-ok: pins critical sections are panic-free (see pinned_groups)
        let mut pins = self.pins.lock().expect("node pins");
        pins.retain(|_, (n, _)| *n != node);
        // outstanding completions are now stale no-ops, so zero the
        // counter — introspection and the all-dead fallback must not see
        // phantom in-flight work
        self.inflight[node].store(0, Ordering::SeqCst);
    }

    /// Re-admit a reconnected node to dispatch.  The generation keeps
    /// its post-death value, so completions from the previous link
    /// incarnation stay stale; in-flight is already zero (`mark_dead`
    /// cleared it and nothing routed here while dead).
    pub fn revive(&self, node: usize) {
        self.dead[node].store(false, Ordering::SeqCst);
    }
}

// -------------------------------------------------------------- framing

/// Read exactly `n` bytes (beyond what `buf` already holds) from a
/// socket with a read timeout, checking `stop` between timeouts.
/// `Ok(true)` = the bytes are in `buf`; `Ok(false)` = stop was raised,
/// or the peer closed cleanly *between* frames (`buf` empty).  A close
/// mid-frame is an error: the peer tore a frame.
fn read_exact_interruptible(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    n: usize,
    stop: &StdAtomicBool,
) -> std::io::Result<bool> {
    let mut chunk = [0u8; 4096];
    while buf.len() < n {
        if stop.load(StdOrdering::SeqCst) {
            return Ok(false);
        }
        let want = (n - buf.len()).min(chunk.len());
        match stream.read(&mut chunk[..want]) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(false);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "peer closed mid-frame",
                ));
            }
            Ok(k) => buf.extend_from_slice(&chunk[..k]),
            // read timeout: partial bytes stay in `buf`; loop to check
            // stop and keep filling — a frame may straddle many timeouts
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one length-delimited frame (4-byte big-endian length, then that
/// many bytes of JSON).  `Ok(None)` = clean shutdown (stop or EOF at a
/// frame boundary); errors are link poison — the caller drops the
/// connection.  The byte cap bounds what one frame can buffer, exactly
/// like the newline protocol's cap (`net::read_frame`).
pub fn read_ld_frame(
    stream: &mut TcpStream,
    stop: &StdAtomicBool,
    max_frame: usize,
) -> std::io::Result<Option<Vec<u8>>> {
    let mut head = Vec::with_capacity(4);
    if !read_exact_interruptible(stream, &mut head, 4, stop)? {
        return Ok(None);
    }
    let len = u32::from_be_bytes([head[0], head[1], head[2], head[3]]) as usize;
    if len == 0 || len > max_frame {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} outside 1..={max_frame}"),
        ));
    }
    let mut body = Vec::with_capacity(len);
    if !read_exact_interruptible(stream, &mut body, len, stop)? {
        return Ok(None);
    }
    Ok(Some(body))
}

/// Write one length-delimited frame.  Callers serialize writes per link
/// (a torn interleaved frame would poison the whole connection).
pub fn write_ld_frame(stream: &mut TcpStream, body: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(body.len() as u32).to_be_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Append the correlation id to a frame body.  `parse_request` ignores
/// unknown keys, so the node strips nothing: the same v2 grammar crosses
/// both the public socket and the inter-tier link.
fn with_id(mut v: Value, id: u64) -> Value {
    if let Value::Object(pairs) = &mut v {
        pairs.push(("id".to_string(), json::num(id as f64)));
    }
    v
}

/// Re-type a node's wire reply into the `Response` the client channel
/// expects — the inverse of `net::response_to_json`, driven entirely by
/// the typed boolean wire fields (`ok`/`busy`/`expired`/`failed`), never
/// by error-string inspection.  `policy` is the effective policy the
/// front end routed (already interned; the wire name is redundant with
/// it), `total_us` is stamped by the caller from its own clock.
pub fn response_from_wire(v: &Value, id: u64, policy: PolicyId) -> Response {
    let flag = |k: &str| v.get(k).and_then(Value::as_bool) == Some(true);
    let ok = flag("ok");
    let num = |k: &str| v.get(k).and_then(Value::as_f64).unwrap_or(0.0);
    let mut timing = Timing::default();
    let logits = if ok {
        timing.queue_us = num("queue_us") as u64;
        timing.exec_us = num("exec_us") as u64;
        timing.bucket = num("bucket") as usize;
        timing.seq_bucket = num("seq_bucket") as usize;
        timing.batch_real = num("batch") as usize;
        v.get("logits")
            .and_then(Value::as_array)
            .map(|a| a.iter().map(|x| x.as_f64().unwrap_or(0.0) as f32).collect())
            .unwrap_or_default()
    } else {
        vec![]
    };
    let error = if ok {
        None
    } else {
        Some(
            v.get("error")
                .and_then(Value::as_str)
                .unwrap_or("engine node answered without an error message")
                .to_string(),
        )
    };
    Response {
        id,
        policy,
        logits,
        timing,
        error,
        expired: flag("expired"),
        failed: flag("failed"),
        busy: flag("busy"),
    }
}

// ----------------------------------------------------------- engine node

/// An engine-node process: the existing single-process [`Coordinator`]
/// (engine pool, residency manager, local admission bound) behind a
/// length-delimited v2 listener.  Unlike the public `NetServer` (one
/// request outstanding per connection), a node connection is a
/// *pipelined link*: the reader admits frames as fast as they arrive and
/// a pump thread streams replies back in completion order, so one link
/// carries the front end's whole in-flight window.
pub struct EngineNode {
    pub addr: SocketAddr,
    stop: Arc<StdAtomicBool>,
    accept_join: Option<std::thread::JoinHandle<()>>,
}

impl EngineNode {
    /// Bind `host:port` (port 0 = ephemeral) and serve until dropped.
    pub fn start(coord: Arc<Coordinator>, host: &str, port: u16) -> Result<EngineNode> {
        let listener =
            TcpListener::bind((host, port)).with_context(|| format!("bind {host}:{port}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(StdAtomicBool::new(false));
        let t_stop = Arc::clone(&stop);
        let accept_join = std::thread::Builder::new()
            .name("zqh-node-accept".into())
            .spawn(move || {
                let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !t_stop.load(StdOrdering::SeqCst) {
                    let mut i = 0;
                    while i < workers.len() {
                        if workers[i].is_finished() {
                            let _ = workers.swap_remove(i).join();
                        } else {
                            i += 1;
                        }
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let coord = Arc::clone(&coord);
                            let stop = Arc::clone(&t_stop);
                            workers.push(std::thread::spawn(move || {
                                let _ = node_conn(stream, &coord, &stop);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for w in workers {
                    let _ = w.join();
                }
            })
            .context("spawn node acceptor")?;
        Ok(EngineNode { addr, stop, accept_join: Some(accept_join) })
    }
}

impl Drop for EngineNode {
    fn drop(&mut self) {
        self.stop.store(true, StdOrdering::SeqCst);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

/// One node link: a reader that admits frames into the local
/// coordinator, and a pump that streams completed replies back.  Both
/// write through one mutex — the frame serializer for this link.
fn node_conn(stream: TcpStream, coord: &Arc<Coordinator>, stop: &Arc<StdAtomicBool>) -> Result<()> {
    stream.set_read_timeout(Some(coord.config.net_read_timeout))?;
    stream.set_nodelay(true)?;
    let max_frame = coord.config.max_frame_bytes;
    let writer = Arc::new(StdMutex::new(stream.try_clone()?));
    type PendingVec = Vec<(u64, Receiver<Response>)>;
    let pending: Arc<StdMutex<PendingVec>> = Arc::new(StdMutex::new(Vec::new()));
    let done_reading = Arc::new(StdAtomicBool::new(false));

    let pump = {
        let coord = Arc::clone(coord);
        let writer = Arc::clone(&writer);
        let pending = Arc::clone(&pending);
        let done_reading = Arc::clone(&done_reading);
        let stop = Arc::clone(stop);
        std::thread::Builder::new()
            .name("zqh-node-pump".into())
            .spawn(move || node_pump(&coord, &writer, &pending, &done_reading, &stop))
            .context("spawn node pump")?
    };

    let mut rstream = stream;
    loop {
        match read_ld_frame(&mut rstream, stop, max_frame) {
            Ok(Some(body)) => {
                if !node_frame(coord, &writer, &pending, &body) {
                    break;
                }
            }
            Ok(None) | Err(_) => break,
        }
    }
    done_reading.store(true, StdOrdering::SeqCst);
    let _ = pump.join();
    Ok(())
}

/// Admit one inter-tier frame.  Returns `false` on a protocol violation
/// (unparseable frame, missing id) — the peer is our own front end, so a
/// malformed frame means the link is corrupt and the connection drops.
fn node_frame(
    coord: &Coordinator,
    writer: &StdMutex<TcpStream>,
    pending: &StdMutex<Vec<(u64, Receiver<Response>)>>,
    body: &[u8],
) -> bool {
    let text = String::from_utf8_lossy(body);
    let Ok(req) = json::parse(text.trim()) else { return false };
    let Some(id) = req.get("id").and_then(Value::as_f64) else { return false };
    let id = id as u64;
    let reply = |v: Value| write_link_frame(writer, &with_id(v, id));
    let spec = match parse_request(&req, coord.seq()) {
        Ok((spec, _)) => spec,
        Err(e) => {
            return reply(json::obj(vec![
                ("ok", Value::Bool(false)),
                ("error", Value::String(format!("{e:#}"))),
            ]))
        }
    };
    match coord.submit(spec) {
        Ok(rx) => {
            // panic-ok: pending critical sections are vec ops that cannot
            // panic while holding the lock
            pending.lock().expect("node pending").push((id, rx));
            true
        }
        // local admission shed: the same typed busy flag the public
        // protocol uses, correlated so the front end sheds exactly this
        // request
        Err(e @ SubmitError::Busy { .. }) => reply(json::obj(vec![
            ("ok", Value::Bool(false)),
            ("busy", Value::Bool(true)),
            ("error", Value::String(e.to_string())),
            ("v", json::num(2.0)),
        ])),
        // a stopping node is indistinguishable from a dying one to the
        // front end: answer `failed` (retryable elsewhere), typed
        Err(SubmitError::Stopped) => reply(json::obj(vec![
            ("ok", Value::Bool(false)),
            ("failed", Value::Bool(true)),
            ("error", Value::String("engine node stopping".into())),
            ("v", json::num(2.0)),
        ])),
        Err(e) => reply(json::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::String(e.to_string())),
        ])),
    }
}

/// Serialize one frame onto the link.  Returns `false` when the link is
/// gone (the caller unwinds the connection).
fn write_link_frame(writer: &StdMutex<TcpStream>, v: &Value) -> bool {
    let body = json::to_string(v).into_bytes();
    // panic-ok: writer critical sections are a single frame write that
    // cannot panic while holding the lock
    let mut w = writer.lock().expect("link writer");
    // block-ok: the writer mutex *is* this link's frame serializer — a
    // torn interleaved frame would poison the connection; the only peers
    // are other single-frame writes on the same link
    write_ld_frame(&mut w, &body).is_ok()
}

/// Stream completed replies back over the link, out of submission order
/// — whichever batch the local coordinator finishes first answers first
/// (the correlation id resolves them on the front end).  Exits when the
/// reader is done and the backlog is drained, when the link dies, or on
/// stop.
fn node_pump(
    coord: &Coordinator,
    writer: &StdMutex<TcpStream>,
    pending: &StdMutex<Vec<(u64, Receiver<Response>)>>,
    done_reading: &StdAtomicBool,
    stop: &StdAtomicBool,
) {
    loop {
        let mut ready: Vec<(u64, Option<Response>)> = Vec::new();
        let empty = {
            // panic-ok: pending critical sections are vec ops that cannot
            // panic while holding the lock
            let mut p = pending.lock().expect("node pending");
            let mut i = 0;
            while i < p.len() {
                match p[i].1.try_recv() {
                    Ok(resp) => {
                        let (id, _) = p.swap_remove(i);
                        ready.push((id, Some(resp)));
                    }
                    Err(TryRecvError::Empty) => i += 1,
                    Err(TryRecvError::Disconnected) => {
                        let (id, _) = p.swap_remove(i);
                        ready.push((id, None));
                    }
                }
            }
            p.is_empty()
        };
        for (id, resp) in ready {
            let v = match resp {
                Some(resp) => with_id(response_to_json(&resp, 2, coord.manifest()), id),
                // the local coordinator dropped the reply channel
                // mid-flight (teardown): typed `failed` so the front end
                // retries on a live node
                None => with_id(
                    json::obj(vec![
                        ("ok", Value::Bool(false)),
                        ("failed", Value::Bool(true)),
                        ("error", Value::String("engine node dropped the request".into())),
                        ("v", json::num(2.0)),
                    ]),
                    id,
                ),
            };
            if !write_link_frame(writer, &v) {
                return;
            }
        }
        if stop.load(StdOrdering::SeqCst) {
            return;
        }
        if done_reading.load(StdOrdering::SeqCst) && empty {
            return;
        }
        std::thread::sleep(Duration::from_micros(500));
    }
}

// ------------------------------------------------------------ front end

/// Admission-tier knobs — the subset of `ServerConfig` that lives on the
/// front end, plus the link-layer reconnect schedule.
#[derive(Debug, Clone)]
pub struct FrontEndConfig {
    /// Admitted-but-unanswered bound across every node (shed past it).
    pub queue_cap: usize,
    /// Deadline stamped onto requests that do not carry one; enforced by
    /// the engine node that owns the queue the request waits in.
    pub default_deadline: Option<Duration>,
    /// Precision governor (depth + node-reported queue-time signals).
    pub governor: Option<GovernorConfig>,
    /// Socket read timeout for client connections *and* node links.
    pub net_read_timeout: Duration,
    /// Per-frame byte cap on both protocols.
    pub max_frame_bytes: usize,
    /// Link reconnect backoff (shared shape with `NetClient` retries).
    pub reconnect: BackoffSchedule,
    /// How long `FrontEnd::start` waits for the initial link set.
    pub connect_timeout: Duration,
}

impl Default for FrontEndConfig {
    fn default() -> Self {
        FrontEndConfig {
            queue_cap: 1024,
            default_deadline: None,
            governor: None,
            net_read_timeout: Duration::from_millis(200),
            max_frame_bytes: 1 << 20,
            reconnect: BackoffSchedule::default(),
            connect_timeout: Duration::from_secs(10),
        }
    }
}

/// One persistent link to an engine node.  `pending` is the correlation
/// table: removal *is* ownership of the terminal reply — the reader, the
/// death sweep, and a failed send race on `remove`, and exactly one wins,
/// so every admitted request is finished exactly once no matter how the
/// link dies.
struct NodeLink {
    /// Re-addressable so a node that re-joins on a fresh port (new
    /// process, new ephemeral listener) takes over the slot —
    /// service-discovery-style relocation without SO_REUSEADDR.
    addr: StdMutex<SocketAddr>,
    /// `Some` while the link is connected; the mutex is the link's frame
    /// serializer.  Cleared (under the lock) by whoever sees the link
    /// die first.
    writer: StdMutex<Option<TcpStream>>,
    pending: StdMutex<HashMap<u64, NodePending>>,
}

/// A request forwarded to a node and awaiting its reply.
struct NodePending {
    key: NodeKey,
    /// Node incarnation the current forward was assigned under; echoed
    /// to `NodeDispatch::complete` so a reply that raced a death is a
    /// stale no-op there.
    generation: u64,
    /// Policy the client asked for — the ledger key.
    requested: PolicyId,
    /// Policy actually routed (may be a governed downgrade).
    effective: PolicyId,
    enqueued: Instant,
    /// The serialized wire frame, kept for re-sends after node death.
    frame: Vec<u8>,
    /// Forward attempts so far; capped at nodes+1 before the request is
    /// answered `failed` (every node refused or died while it was in
    /// hand).
    attempts: usize,
    reply: Sender<Response>,
}

/// Shared state behind the front end: links, dispatch, ledger, governor
/// table.  Split from [`FrontEnd`] so link-supervisor threads can hold
/// it without a reference cycle through their own join handles.
struct Router {
    man: Arc<Manifest>,
    recorder: Recorder,
    cfg: FrontEndConfig,
    /// Admitted-but-unanswered requests (the `queue_cap` bound).
    depth: StdAtomicUsize,
    dispatch: NodeDispatch,
    links: Vec<NodeLink>,
    /// Governor's shared effective-policy table (admission reads it).
    governor: Option<Arc<GovernorShared>>,
    /// Policies the governor table was sized for at start; late-interned
    /// inline policies past this are ungovernable (no chain) and route
    /// as requested.
    governor_policies: usize,
    /// Max node-reported queue time since the governor's last tick
    /// (consumed by swap, like the batcher's queue signal).
    queue_sig: StdAtomicU64,
    stop: StdAtomicBool,
}

impl Router {
    /// Forward (or re-forward) one request.  Loops because a send can
    /// discover a dead link: the entry comes back, the node is marked
    /// dead, and dispatch picks another.  Bounded by `attempts` — once
    /// every node has had its chance the request is answered `failed`.
    fn route(&self, id: u64, mut p: NodePending) {
        loop {
            if p.attempts > self.links.len() {
                self.finish_failed(id, p, "no live engine node to run the request");
                return;
            }
            p.attempts += 1;
            let (node, generation) = self.dispatch.assign(p.key);
            p.generation = generation;
            match self.try_send(node, id, p) {
                None => return,
                Some(back) => p = back,
            }
        }
    }

    /// Park the entry in `node`'s pending map, then push its frame onto
    /// the link.  `None` = the entry is out of our hands (sent, or a
    /// concurrent sweep now owns it); `Some(p)` = the link was down and
    /// we still own the entry — the caller re-routes it.
    ///
    /// The park happens *before* the write: the reply can race back the
    /// instant the frame hits the wire, and the link reader resolves ids
    /// through this map.  No path holds the pending lock across the
    /// write (or any two link locks at once).
    fn try_send(&self, node: usize, id: u64, p: NodePending) -> Option<NodePending> {
        let link = &self.links[node];
        let frame = p.frame.clone();
        let (key, generation) = (p.key, p.generation);
        {
            // panic-ok: pending critical sections are map ops that cannot
            // panic while holding the lock
            link.pending.lock().expect("link pending").insert(id, p);
        }
        let wrote = {
            // panic-ok: writer critical sections are a single frame write
            // that cannot panic while holding the lock
            let mut w = link.writer.lock().expect("link writer");
            match w.as_mut() {
                None => false,
                // block-ok: the writer mutex *is* this link's frame
                // serializer — a torn interleaved frame would poison the
                // connection; peers are other single-frame writes
                Some(stream) => match write_ld_frame(stream, &frame) {
                    Ok(()) => true,
                    Err(_) => {
                        // poison the writer under the lock so no later
                        // sender writes into a half-dead socket
                        *w = None;
                        false
                    }
                },
            }
        };
        if wrote {
            return None;
        }
        // the frame never made it out; whoever still finds the entry in
        // the map owns it (a concurrent sweep may have already re-routed)
        // panic-ok: pending critical sections are panic-free (see above)
        let back = link.pending.lock().expect("link pending").remove(&id);
        match back {
            None => None,
            Some(p) => {
                // undo the assignment accounting; if the node died in
                // between, mark_dead already purged and this is a stale
                // no-op by generation
                self.dispatch.complete(key, node, generation);
                self.link_down(node);
                Some(p)
            }
        }
    }

    /// Transition a node to dead and sweep its in-flight entries — each
    /// swept request re-routes to a live node (or finishes `failed` once
    /// its attempts run out).  Exactly the dead-replica discipline, one
    /// tier up: exclude, purge pins, retry.
    fn link_down(&self, node: usize) {
        {
            // panic-ok: writer critical sections are panic-free
            let mut w = self.links[node].writer.lock().expect("link writer");
            *w = None;
        }
        if self.dispatch.alive(node) {
            self.dispatch.mark_dead(node);
        }
        let swept: Vec<(u64, NodePending)> = {
            // panic-ok: pending critical sections are panic-free
            let mut pend = self.links[node].pending.lock().expect("link pending");
            pend.drain().collect()
        };
        for (id, p) in swept {
            self.route(id, p);
        }
    }

    /// Resolve one wire reply against the pending map.  A miss means the
    /// entry was already finished elsewhere (swept and retried, or a
    /// duplicate from a dead incarnation) — dropped, so nothing is ever
    /// finished twice.
    fn finish_from_wire(&self, node: usize, id: u64, v: &Value) {
        // panic-ok: pending critical sections are panic-free
        let p = self.links[node].pending.lock().expect("link pending").remove(&id);
        let Some(p) = p else { return };
        self.dispatch.complete(p.key, node, p.generation);
        let mut resp = response_from_wire(v, id, p.effective);
        resp.timing.total_us = p.enqueued.elapsed().as_micros() as u64;
        self.finish(p, resp);
    }

    /// Answer a request the node tier could not run: typed `failed`,
    /// same class as a swept replica failure.
    fn finish_failed(&self, id: u64, p: NodePending, msg: &str) {
        let resp = Response {
            id,
            policy: p.effective,
            logits: vec![],
            timing: Timing {
                total_us: p.enqueued.elapsed().as_micros() as u64,
                ..Timing::default()
            },
            error: Some(msg.to_string()),
            expired: false,
            failed: true,
            busy: false,
        };
        self.finish(p, resp);
    }

    /// The single terminal point: ledger the outcome class against the
    /// *requested* policy, release the depth reservation, reply.  Every
    /// admitted request passes through here exactly once, which is what
    /// keeps `admitted = completed + shed + expired + failed`
    /// reconciling on this tier.
    fn finish(&self, p: NodePending, resp: Response) {
        if resp.busy {
            // node-side admission shed: same ledger class as a local shed
            self.recorder.record_shed_at(0, p.requested);
        } else if resp.expired {
            self.recorder.record_expired_at(0, p.requested, resp.timing.queue_us);
        } else if resp.failed {
            self.recorder.record_failed_at(0, p.requested);
        } else if resp.error.is_some() {
            self.recorder.record_request_at(
                0,
                p.requested,
                resp.timing.total_us,
                resp.timing.queue_us,
                true,
            );
        } else {
            self.recorder.record_request_at(
                0,
                p.requested,
                resp.timing.total_us,
                resp.timing.queue_us,
                false,
            );
            // feed the governor the node-observed queue pressure
            self.queue_sig.fetch_max(resp.timing.queue_us, StdOrdering::SeqCst);
        }
        self.depth.fetch_sub(1, StdOrdering::SeqCst);
        let _ = p.reply.send(resp);
    }
}

/// Sleep in small slices so stop lands within ~5 ms, not a full backoff.
fn sleep_interruptible(stop: &StdAtomicBool, d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d && !stop.load(StdOrdering::SeqCst) {
        std::thread::sleep(Duration::from_millis(5).min(d));
    }
}

/// Own one node link for the life of the front end: connect (with the
/// `BackoffSchedule`), install the writer, revive the node in dispatch,
/// then run the reply reader inline until the link dies — at which point
/// the node is marked dead, its in-flight entries sweep onto live nodes,
/// and the loop reconnects.  Re-reads the slot's address every attempt,
/// so `FrontEnd::relocate` redirects a dead slot to a re-joined node.
fn link_supervisor(router: Arc<Router>, node: usize) {
    let mut attempt: u32 = 0;
    while !router.stop.load(StdOrdering::SeqCst) {
        let addr = {
            // panic-ok: addr critical section is a copy
            *router.links[node].addr.lock().expect("link addr")
        };
        let stream = match TcpStream::connect(addr) {
            Ok(s) => s,
            Err(_) => {
                // unreachable node: exclude it from routing while backing
                // off (the sweep re-homes anything a racing send parked)
                if router.dispatch.alive(node) {
                    router.link_down(node);
                }
                sleep_interruptible(&router.stop, router.cfg.reconnect.delay(attempt));
                attempt = attempt.saturating_add(1);
                continue;
            }
        };
        if stream.set_read_timeout(Some(router.cfg.net_read_timeout)).is_err()
            || stream.set_nodelay(true).is_err()
        {
            sleep_interruptible(&router.stop, router.cfg.reconnect.delay(attempt));
            attempt = attempt.saturating_add(1);
            continue;
        }
        let Ok(wstream) = stream.try_clone() else {
            sleep_interruptible(&router.stop, router.cfg.reconnect.delay(attempt));
            attempt = attempt.saturating_add(1);
            continue;
        };
        {
            // panic-ok: writer critical sections are panic-free
            let mut w = router.links[node].writer.lock().expect("link writer");
            *w = Some(wstream);
        }
        router.dispatch.revive(node);
        attempt = 0;
        let mut rstream = stream;
        loop {
            match read_ld_frame(&mut rstream, &router.stop, router.cfg.max_frame_bytes) {
                Ok(Some(body)) => {
                    let text = String::from_utf8_lossy(&body);
                    let Ok(v) = json::parse(text.trim()) else { break };
                    let Some(id) = v.get("id").and_then(Value::as_f64) else { break };
                    router.finish_from_wire(node, id as u64, &v);
                }
                Ok(None) | Err(_) => break,
            }
        }
        if router.stop.load(StdOrdering::SeqCst) {
            break;
        }
        router.link_down(node);
    }
}

/// The front-end tier: depth-bounded typed admission, deadline stamping,
/// and the precision governor — everything `Coordinator::submit` does
/// except touch an engine — over [`NodeDispatch`]-routed links to engine
/// nodes.  Serves the public protocol through `NetServer` via the
/// [`Admission`](super::net::Admission) trait, so clients cannot tell a
/// two-tier deployment from a single process.
pub struct FrontEnd {
    router: Arc<Router>,
    next_id: StdAtomicU64,
    supervisors: Vec<std::thread::JoinHandle<()>>,
    governor_join: Option<std::thread::JoinHandle<()>>,
}

impl FrontEnd {
    /// Load the manifest from `artifacts` (route/policy tables only — no
    /// checkpoints open on this tier), dial every node, and wait for the
    /// initial link set to come up.
    pub fn start(artifacts: &Path, nodes: &[SocketAddr], config: FrontEndConfig) -> Result<FrontEnd> {
        anyhow::ensure!(!nodes.is_empty(), "front end needs at least one engine node");
        let man = Arc::new(Manifest::load(artifacts)?);
        let recorder = Recorder::new(man.policy_order.clone(), nodes.len());
        let governor_policies = man.num_policies();
        let governor_shared =
            config.governor.as_ref().map(|_| Arc::new(GovernorShared::new(governor_policies)));
        let links = nodes
            .iter()
            .map(|a| NodeLink {
                addr: StdMutex::new(*a),
                writer: StdMutex::new(None),
                pending: StdMutex::new(HashMap::new()),
            })
            .collect();
        let router = Arc::new(Router {
            man: Arc::clone(&man),
            recorder,
            cfg: config.clone(),
            depth: StdAtomicUsize::new(0),
            dispatch: NodeDispatch::new(nodes.len()),
            links,
            governor: governor_shared.clone(),
            governor_policies,
            queue_sig: StdAtomicU64::new(0),
            stop: StdAtomicBool::new(false),
        });
        let mut supervisors = Vec::with_capacity(nodes.len());
        for node in 0..nodes.len() {
            let r = Arc::clone(&router);
            supervisors.push(
                std::thread::Builder::new()
                    .name(format!("zqh-link-{node}"))
                    .spawn(move || link_supervisor(r, node))
                    .context("spawn link supervisor")?,
            );
        }
        // governor: pure machine on its own tick thread (the front end
        // has no batcher thread to host it); admission reads the shared
        // table exactly as in-process admission does
        let governor_join = match (config.governor.clone(), governor_shared) {
            (Some(cfg), Some(shared)) => {
                let chains: Vec<Vec<PolicyId>> = (0..man.num_policies())
                    .map(|i| man.downgrade_chain(PolicyId(i as u16)))
                    .collect();
                let mut machine = PrecisionGovernor::new(chains, cfg);
                let r = Arc::clone(&router);
                Some(
                    std::thread::Builder::new()
                        .name("zqh-fe-governor".into())
                        .spawn(move || {
                            while !r.stop.load(StdOrdering::SeqCst) {
                                std::thread::sleep(machine.config().tick);
                                let signals = Signals {
                                    depth: r.depth.load(StdOrdering::SeqCst),
                                    // consumed-on-read, like the batcher's
                                    // queue sample
                                    queue_us: r.queue_sig.swap(0, StdOrdering::SeqCst),
                                };
                                for ev in machine.observe(signals) {
                                    shared.publish(ev.policy, ev.to);
                                }
                            }
                        })
                        .context("spawn front-end governor")?,
                )
            }
            _ => None,
        };
        let fe = FrontEnd {
            router,
            next_id: StdAtomicU64::new(0),
            supervisors,
            governor_join,
        };
        let t0 = Instant::now();
        while fe.live_nodes() < nodes.len() {
            anyhow::ensure!(
                t0.elapsed() < fe.router.cfg.connect_timeout,
                "engine nodes not reachable within {:?} ({}/{} links up)",
                fe.router.cfg.connect_timeout,
                fe.live_nodes(),
                nodes.len()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(fe)
    }

    /// Admit a typed request and route it to an engine node.  Mirrors
    /// `Coordinator::submit` — validation, policy interning, governor
    /// steering, depth-bounded shed with the same typed `Busy` — minus
    /// the residency checks: the node tier owns executables, and a node
    /// that cannot serve a route answers with a typed error instead.
    pub fn submit(
        &self,
        spec: RequestSpec,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        let r = &*self.router;
        let RequestSpec { task, policy, ids, type_ids, deadline } = spec;
        let reject = |e: anyhow::Error| SubmitError::Rejected(e);
        let seq = r.man.seq;
        if ids.is_empty() || ids.len() > seq {
            return Err(reject(anyhow!(
                "request needs 1..={} token ids (got {})",
                seq,
                ids.len()
            )));
        }
        let mut type_ids = type_ids.unwrap_or_default();
        if type_ids.len() > seq {
            return Err(reject(anyhow!(
                "type_ids longer than seq {} (got {})",
                seq,
                type_ids.len()
            )));
        }
        type_ids.resize(ids.len(), 0);
        let seq_bucket = r.man.seq_bucket_for(ids.len());
        let task_id = r
            .man
            .task_id(&task)
            .map_err(|_| reject(anyhow!("unknown task {task:?}; not in this manifest")))?;
        let requested = match &policy {
            None => {
                if r.man.mode_order.is_empty() {
                    return Err(reject(anyhow!(
                        "manifest declares no modes; a request without an explicit \
                         policy has no default route"
                    )));
                }
                PolicyId(0)
            }
            Some(PolicyRef::Named(n)) => r
                .man
                .policy_id(n)
                .map_err(|_| reject(anyhow!("unknown policy {n:?}; not in this manifest")))?,
            Some(PolicyRef::Inline(draft)) => {
                r.man.intern_inline_policy(draft).map_err(reject)?
            }
        };
        // governed steering: late-interned inline policies sit past the
        // table the governor was sized for — ungovernable (no chain),
        // route as requested
        let effective = match &r.governor {
            Some(g) if (requested.0 as usize) < r.governor_policies => g.effective(requested),
            _ => requested,
        };
        let busy = || SubmitError::Busy { queue_cap: r.cfg.queue_cap };
        if r.depth.fetch_add(1, StdOrdering::SeqCst) >= r.cfg.queue_cap {
            r.depth.fetch_sub(1, StdOrdering::SeqCst);
            r.recorder.record_shed_at(0, requested);
            return Err(busy());
        }
        let id = self.next_id.fetch_add(1, StdOrdering::SeqCst);
        let now = Instant::now();
        // the node enforces the deadline — it owns the queue the request
        // waits in — so the budget rides the wire instead of a local
        // timer (clocks need not be synchronized: a duration crosses the
        // link, not an instant)
        let deadline = deadline.or(r.cfg.default_deadline);
        let wire_policy = if effective != requested {
            // governed downgrade: route the chain rung by name (chain
            // targets are manifest-declared, so the node knows it)
            Some(PolicyRef::Named(r.man.policy_name(effective).to_string()))
        } else {
            // pass inline drafts through verbatim — the node interns them
            // against its own manifest
            policy
        };
        let wire = RequestSpec { task, policy: wire_policy, ids, type_ids: Some(type_ids), deadline };
        let frame = json::to_string(&with_id(request_to_json(&wire), id)).into_bytes();
        if effective != requested {
            r.recorder.record_governed_at(0, requested);
        }
        let (reply, rx) = channel();
        let pending = NodePending {
            key: (task_id, effective, seq_bucket),
            generation: 0,
            requested,
            effective,
            enqueued: now,
            frame,
            attempts: 0,
            reply,
        };
        r.route(id, pending);
        Ok(rx)
    }

    /// Point a (dead) node slot at a new address — a re-joined node on a
    /// fresh ephemeral port takes over the slot on the supervisor's next
    /// connect attempt.
    pub fn relocate(&self, node: usize, addr: SocketAddr) {
        // panic-ok: addr critical section is a store
        *self.router.links[node].addr.lock().expect("link addr") = addr;
    }

    /// Links currently connected *and* admitted to dispatch.
    pub fn live_nodes(&self) -> usize {
        (0..self.router.links.len())
            .filter(|n| {
                self.router.dispatch.alive(*n)
                    // panic-ok: writer critical section is a presence check
                    && self.router.links[*n].writer.lock().expect("link writer").is_some()
            })
            .count()
    }

    pub fn nodes(&self) -> usize {
        self.router.links.len()
    }

    /// Node-dispatch introspection (tests / stats).
    pub fn dispatch(&self) -> &NodeDispatch {
        &self.router.dispatch
    }

    /// This tier's ledger: per-policy `requests == completed + errors +
    /// expired + failed` with `shed` counted apart, exactly like the
    /// coordinator's recorder.
    pub fn recorder(&self) -> &Recorder {
        &self.router.recorder
    }

    pub fn manifest(&self) -> &Manifest {
        &self.router.man
    }

    /// Admitted-but-unanswered requests; 0 once every client has its
    /// terminal reply (leak witness for the chaos tests).
    pub fn queue_depth(&self) -> usize {
        self.router.depth.load(StdOrdering::SeqCst)
    }

    pub fn num_labels(&self) -> usize {
        self.router.man.model.num_labels
    }

    pub fn seq(&self) -> usize {
        self.router.man.seq
    }
}

impl super::net::Admission for FrontEnd {
    fn submit_spec(
        &self,
        spec: RequestSpec,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        self.submit(spec)
    }

    fn manifest(&self) -> &Manifest {
        FrontEnd::manifest(self)
    }

    fn seq(&self) -> usize {
        FrontEnd::seq(self)
    }

    fn net_read_timeout(&self) -> Duration {
        self.router.cfg.net_read_timeout
    }

    fn max_frame_bytes(&self) -> usize {
        self.router.cfg.max_frame_bytes
    }
}

impl Drop for FrontEnd {
    fn drop(&mut self) {
        self.router.stop.store(true, StdOrdering::SeqCst);
        for j in self.supervisors.drain(..) {
            let _ = j.join();
        }
        if let Some(j) = self.governor_join.take() {
            let _ = j.join();
        }
        // no thread owns the pending maps any more: fail whatever is
        // still parked so no client blocks on a reply that cannot come
        for node in 0..self.router.links.len() {
            let swept: Vec<(u64, NodePending)> = {
                // panic-ok: pending critical sections are panic-free
                let mut pend = self.router.links[node].pending.lock().expect("link pending");
                pend.drain().collect()
            };
            for (id, p) in swept {
                self.router.finish_failed(id, p, "front end shutting down");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Rng};

    fn key(t: u16, p: u16, s: usize) -> NodeKey {
        (TaskId(t), PolicyId(p), s)
    }

    #[test]
    fn node_dispatch_pins_migrates_and_stales_dead_incarnations() {
        let d = NodeDispatch::new(2);
        let g0 = key(0, 0, 0);
        let g1 = key(0, 0, 1);
        // seq classes of one route pin independently
        let (n0, gen0) = d.assign(g0);
        assert_eq!((n0, gen0), (0, 0));
        assert_eq!(d.assign(g0).0, 0, "pinned while in flight");
        let (n1, _) = d.assign(g1);
        assert_eq!(n1, 1, "fresh class takes the least-loaded node");
        assert_eq!(d.pinned_groups(), 2);
        // node 0 dies: pins purge, counter zeroes, traffic migrates
        d.mark_dead(0);
        assert!(!d.alive(0));
        assert_eq!(d.generation(0), 1);
        assert_eq!(d.inflight(0), 0);
        assert_eq!(d.assign(g0).0, 1, "dead node attracts nothing");
        // completions from the dead incarnation are strict no-ops
        d.complete(g0, 0, gen0);
        assert_eq!(d.inflight(0), 0);
        assert_eq!(d.inflight(1), 3);
        // revive re-admits at the bumped generation
        d.revive(0);
        let g2 = key(1, 0, 0);
        let (n2, gen2) = d.assign(g2);
        assert_eq!((n2, gen2), (0, 1), "revived node is least-loaded again");
        d.complete(g2, 0, gen0); // stale generation: no-op
        assert_eq!(d.inflight(0), 1);
        d.complete(g2, 0, gen2);
        d.complete(g0, 1, 0);
        d.complete(g0, 1, 0);
        d.complete(g1, 1, 0);
        assert_eq!(d.pinned_groups(), 0);
        assert_eq!(d.inflight(0) + d.inflight(1), 0);
    }

    #[test]
    fn prop_node_per_group_fifo_pinning_and_count_consistency() {
        forall("node-dispatch-pinning", 60, |r: &mut Rng| {
            let nnodes = 1 + r.below(4);
            let d = NodeDispatch::new(nnodes);
            // in-flight requests as (group, node, generation)
            let mut open: Vec<(NodeKey, usize, u64)> = Vec::new();
            let mut pinned: HashMap<NodeKey, usize> = HashMap::new();
            for _ in 0..200 {
                if open.is_empty() || r.bool() {
                    let k = key(r.below(2) as u16, r.below(3) as u16, r.below(2));
                    let loads: Vec<usize> = (0..nnodes).map(|i| d.inflight(i)).collect();
                    let (node, gen) = d.assign(k);
                    assert!(node < nnodes);
                    assert_eq!(gen, 0, "no deaths in this test");
                    match pinned.get(&k) {
                        // the FIFO guarantee: while a group has requests
                        // in flight, every new one lands on the same node
                        Some(p) => assert_eq!(*p, node, "group reassigned while in flight"),
                        // a fresh (or migrated) group takes a
                        // least-loaded node, measured before this
                        // assignment
                        None => {
                            let min = loads.iter().copied().min().unwrap();
                            assert_eq!(loads[node], min, "not least-loaded: {loads:?} -> {node}");
                            pinned.insert(k, node);
                        }
                    }
                    open.push((k, node, gen));
                } else {
                    let i = r.below(open.len());
                    let (k, node, gen) = open.swap_remove(i);
                    d.complete(k, node, gen);
                    if !open.iter().any(|(ok, _, _)| *ok == k) {
                        pinned.remove(&k);
                    }
                }
                // accounting consistency: per-node in-flight counters
                // always equal the number of open requests per node
                for node in 0..nnodes {
                    assert_eq!(
                        d.inflight(node),
                        open.iter().filter(|(_, p, _)| *p == node).count(),
                        "node {node} count drifted"
                    );
                }
                assert_eq!(d.pinned_groups(), pinned.len());
            }
            for (k, node, gen) in open.drain(..) {
                d.complete(k, node, gen);
            }
            assert_eq!(d.pinned_groups(), 0);
            for node in 0..nnodes {
                assert_eq!(d.inflight(node), 0);
            }
        });
    }

    #[test]
    fn prop_node_dispatch_generations_neutralize_stale_completions() {
        forall("node-dispatch-supervision", 60, |r: &mut Rng| {
            let nnodes = 1 + r.below(4);
            let d = NodeDispatch::new(nnodes);
            // live requests vs completions orphaned by a death (stale)
            let mut open: Vec<(NodeKey, usize, u64)> = Vec::new();
            let mut stale: Vec<(NodeKey, usize, u64)> = Vec::new();
            let mut pinned: HashMap<NodeKey, usize> = HashMap::new();
            let mut alive = vec![true; nnodes];
            for _ in 0..300 {
                match r.below(10) {
                    // kill a node: its open requests become stale (the
                    // router's sweep re-routes them as *new* assignments)
                    0 => {
                        let node = r.below(nnodes);
                        if alive[node] {
                            d.mark_dead(node);
                            alive[node] = false;
                            let mut kept = Vec::new();
                            for e in open.drain(..) {
                                if e.1 == node {
                                    stale.push(e);
                                } else {
                                    kept.push(e);
                                }
                            }
                            open = kept;
                            pinned.retain(|_, p| *p != node);
                        }
                    }
                    // reconnect re-admits the slot
                    1 => {
                        let node = r.below(nnodes);
                        if !alive[node] {
                            d.revive(node);
                            alive[node] = true;
                        }
                    }
                    // replay a stale completion at a random point: the
                    // generation tag must make it a strict no-op
                    2 | 3 if !stale.is_empty() => {
                        let i = r.below(stale.len());
                        let (k, node, gen) = stale.swap_remove(i);
                        d.complete(k, node, gen);
                    }
                    _ if open.is_empty() || r.bool() => {
                        let k = key(r.below(2) as u16, r.below(3) as u16, r.below(2));
                        let (node, gen) = d.assign(k);
                        assert!(node < nnodes);
                        assert_eq!(gen, d.generation(node));
                        match pinned.get(&k) {
                            Some(p) => assert_eq!(*p, node, "group reassigned while in flight"),
                            None => {
                                if alive.iter().any(|a| *a) {
                                    assert!(
                                        alive[node],
                                        "assigned to a dead node while a live one exists"
                                    );
                                }
                                pinned.insert(k, node);
                            }
                        }
                        open.push((k, node, gen));
                    }
                    _ => {
                        let i = r.below(open.len());
                        let (k, node, gen) = open.swap_remove(i);
                        d.complete(k, node, gen);
                        if !open.iter().any(|(ok, _, _)| *ok == k) {
                            pinned.remove(&k);
                        }
                    }
                }
                // the live accounting never drifts, no matter how death,
                // reconnection, and stale replays interleave
                for node in 0..nnodes {
                    assert_eq!(
                        d.inflight(node),
                        open.iter().filter(|(_, p, _)| *p == node).count(),
                        "node {node} count drifted"
                    );
                }
                assert_eq!(d.pinned_groups(), pinned.len());
            }
            for (k, node, gen) in open.drain(..) {
                d.complete(k, node, gen);
            }
            // any leftover stale completions drain as no-ops
            for (k, node, gen) in stale.drain(..) {
                d.complete(k, node, gen);
            }
            assert_eq!(d.pinned_groups(), 0);
            for node in 0..nnodes {
                assert_eq!(d.inflight(node), 0, "stale completion corrupted node {node}");
            }
        });
    }

    #[test]
    fn ld_frames_survive_read_timeouts_and_pipelining() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let body = br#"{"id":1,"task":"t"}"#;
            // length prefix, then a pause past the read timeout, then the
            // body plus a second whole frame back-to-back (pipelining)
            s.write_all(&(body.len() as u32).to_be_bytes()).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(120));
            s.write_all(body).unwrap();
            write_ld_frame(&mut s, br#"{"id":2}"#).unwrap();
            s.flush().unwrap();
            s
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(40))).unwrap();
        let stop = StdAtomicBool::new(false);
        let mut r = stream;
        let f1 = read_ld_frame(&mut r, &stop, 1 << 20).unwrap().unwrap();
        assert_eq!(&f1, br#"{"id":1,"task":"t"}"#);
        let f2 = read_ld_frame(&mut r, &stop, 1 << 20).unwrap().unwrap();
        assert_eq!(&f2, br#"{"id":2}"#);
        // peer closes at a frame boundary: clean shutdown, not an error
        drop(writer.join().unwrap());
        assert!(read_ld_frame(&mut r, &stop, 1 << 20).unwrap().is_none());
    }

    #[test]
    fn ld_frame_rejects_oversize_and_torn_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // a frame claiming 2 MiB against a 1 MiB cap
            s.write_all(&(2u32 << 20).to_be_bytes()).unwrap();
            let _ = s.flush();
            s
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(40))).unwrap();
        let stop = StdAtomicBool::new(false);
        let mut r = stream;
        assert!(read_ld_frame(&mut r, &stop, 1 << 20).is_err(), "oversize must be link poison");
        drop(writer.join().unwrap());

        // a peer that closes mid-frame tore it: error, not a clean None
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&(10u32).to_be_bytes()).unwrap();
            s.write_all(b"abc").unwrap(); // 3 of 10 promised bytes
            let _ = s.flush();
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(40))).unwrap();
        let mut r = stream;
        writer.join().unwrap();
        assert!(read_ld_frame(&mut r, &stop, 1 << 20).is_err(), "torn frame must be link poison");
    }

    #[test]
    fn correlation_id_rides_the_v2_grammar_unchanged() {
        // the inter-tier frame is request_to_json + id: parse_request
        // must accept it verbatim (unknown keys ignored) and the id must
        // survive the round trip
        let spec = RequestSpec::task("sst2").mode("m3").ids(vec![1, 2, 3]).deadline_ms(250);
        let framed = with_id(request_to_json(&spec), 7);
        assert_eq!(framed.get("id").and_then(Value::as_f64), Some(7.0));
        let (parsed, version) = parse_request(&framed, 8).unwrap();
        assert_eq!(version, 2);
        assert_eq!(parsed.task, spec.task);
        assert_eq!(parsed.policy, spec.policy);
        assert_eq!(parsed.ids, spec.ids);
        assert_eq!(parsed.deadline, spec.deadline);
    }

    fn man_for_wire_tests() -> Manifest {
        // the smallest manifest the name mapping in response_to_json
        // needs: one mode, one task
        Manifest::from_json_str(
            r#"{
              "model": {"vocab_size": 8, "hidden": 4, "layers": 1, "heads": 2,
                        "ffn": 8, "max_seq": 4, "type_vocab": 2, "num_labels": 2,
                        "ln_eps": 0.00001},
              "seq": 4,
              "buckets": [1, 2],
              "modes": {
                "fp": {
                  "switches": {"embedding": false, "qkv": false, "attn": false,
                               "attn_output": false, "fc1": false, "fc2": false},
                  "artifacts": {},
                  "params": []
                }
              },
              "calib": {"artifact": "calib.bin", "batch": 1, "params": [], "stats": []},
              "tasks": {"t": {"splits": {}, "metrics": [], "classes": 2,
                               "checkpoint": "ckpt-{mode}.bin"}}
            }"#,
            Path::new("."),
        )
        .unwrap()
    }

    #[test]
    fn typed_outcome_classes_round_trip_the_wire_both_directions() {
        let man = man_for_wire_tests();
        let base = Response {
            id: 0,
            policy: PolicyId(0),
            logits: vec![],
            timing: Timing::default(),
            error: None,
            expired: false,
            failed: false,
            busy: false,
        };

        // success: logits and timings survive, no outcome flags
        let ok = Response {
            logits: vec![0.5, -1.5],
            timing: Timing {
                queue_us: 120,
                exec_us: 340,
                bucket: 2,
                seq_bucket: 4,
                batch_real: 2,
                ..Timing::default()
            },
            ..base.clone()
        };
        let wire = response_to_json(&ok, 2, &man);
        assert_eq!(wire.get("ok").and_then(Value::as_bool), Some(true));
        let back = response_from_wire(&wire, 9, PolicyId(0));
        assert_eq!(back.id, 9);
        assert_eq!(back.logits, ok.logits);
        assert_eq!(back.timing.queue_us, 120);
        assert_eq!(back.timing.exec_us, 340);
        assert_eq!(back.timing.batch_real, 2);
        assert!(back.error.is_none() && !back.busy && !back.expired && !back.failed);

        // each failure class crosses as its own typed flag and comes
        // back as the same class — never re-derived from the message
        let cases = [
            (Response { busy: true, error: Some("queue full".into()), ..base.clone() }, "busy"),
            (
                Response {
                    expired: true,
                    error: Some("deadline exceeded after 900us in queue".into()),
                    ..base.clone()
                },
                "expired",
            ),
            (
                Response {
                    failed: true,
                    error: Some("engine replica failed before the batch completed".into()),
                    ..base.clone()
                },
                "failed",
            ),
        ];
        for (resp, flag) in cases {
            let wire = response_to_json(&resp, 2, &man);
            assert_eq!(wire.get("ok").and_then(Value::as_bool), Some(false));
            assert_eq!(wire.get(flag).and_then(Value::as_bool), Some(true), "{flag}");
            let back = response_from_wire(&wire, 3, PolicyId(0));
            assert_eq!(back.busy, resp.busy, "{flag}");
            assert_eq!(back.expired, resp.expired, "{flag}");
            assert_eq!(back.failed, resp.failed, "{flag}");
            assert_eq!(back.error, resp.error, "{flag}");
        }

        // a plain terminal error carries no class flag in either
        // direction
        let err = Response { error: Some("unknown task".into()), ..base };
        let wire = response_to_json(&err, 2, &man);
        for flag in ["busy", "expired", "failed"] {
            assert!(wire.get(flag).is_none(), "{flag} must be absent");
        }
        let back = response_from_wire(&wire, 1, PolicyId(0));
        assert_eq!(back.error.as_deref(), Some("unknown task"));
        assert!(!back.busy && !back.expired && !back.failed);
    }
}
