//! PrecisionPolicy API unit suite (DESIGN.md §6) — exercises the
//! manifest `policies` section, resolution/escalation, `PolicyId`
//! interning and the v1→v2 wire shim WITHOUT a generated artifacts dir,
//! via `Manifest::from_json_str`.

use std::path::Path;

use zqhero::coordinator::net::{parse_request, request_to_json};
use zqhero::coordinator::PolicyRef;
use zqhero::json;
use zqhero::model::manifest::{Manifest, ModeId, PolicyDraft, PolicyId};

/// Minimal manifest with the paper's four Table-1 modes; `policies` is
/// spliced in (empty string = no section).
fn manifest_src(policies: &str) -> String {
    let sw = |e: bool, q: bool, a: bool, o: bool, f1: bool, f2: bool| {
        format!(
            r#"{{"switches": {{"embedding": {e}, "qkv": {q}, "attn": {a},
                 "attn_output": {o}, "fc1": {f1}, "fc2": {f2}}},
                "params": [], "artifacts": {{}}}}"#
        )
    };
    let policies_section = if policies.is_empty() {
        String::new()
    } else {
        format!(r#""policies": {policies},"#)
    };
    format!(
        r#"{{
  "model": {{"vocab_size": 16, "hidden": 8, "layers": 1, "heads": 2,
            "ffn": 16, "max_seq": 16, "type_vocab": 2, "num_labels": 2,
            "ln_eps": 1e-12}},
  "seq": 16,
  "buckets": [1, 4],
  "modes": {{
    "fp": {fp}, "m1": {m1}, "m2": {m2}, "m3": {m3}
  }},
  {policies_section}
  "calib": {{"artifact": "c.hlo", "batch": 4, "params": [], "stats": []}},
  "tasks": {{
    "sst2": {{"classes": 2, "metrics": ["acc"], "splits": {{"dev": "d.bin"}},
             "checkpoint": "checkpoints/sst2/fp32.bin"}}
  }}
}}"#,
        fp = sw(false, false, false, false, false, false),
        m1 = sw(true, true, false, false, true, false),
        m2 = sw(true, true, true, true, true, false),
        m3 = sw(true, true, true, true, true, true),
    )
}

fn load(policies: &str) -> anyhow::Result<Manifest> {
    Manifest::from_json_str(&manifest_src(policies), Path::new("unused"))
}

#[test]
fn uniform_policies_share_mode_indices() {
    let man = load("").unwrap();
    assert_eq!(man.policy_order, man.mode_order);
    assert_eq!(man.num_policies(), man.num_modes());
    let names = man.mode_order.clone();
    for name in &names {
        let pid = man.policy_id(name).unwrap();
        let mid = man.mode_id(name).unwrap();
        assert_eq!(pid.0, mid.0, "uniform policy {name} must share the mode index");
        let spec = man.policy_by_id(pid);
        assert!(spec.is_uniform());
        assert_eq!(spec.exec_mode, mid);
    }
    assert!(man.policy_id("nope").unwrap_err().to_string().contains("unknown policy"));
}

#[test]
fn named_policy_exact_match_resolves_without_fallback() {
    // m3 with fc2 recovered == exactly the m2 switch row
    let man = load(r#"{"fc2-fp": {"base": "m3", "overrides": [["fc2", "fp"]]}}"#).unwrap();
    let spec = man.policy("fc2-fp").unwrap();
    assert_eq!(spec.exec_mode, man.mode_id("m2").unwrap());
    assert_eq!(spec.effective.tag(), "111110");
    assert!(!spec.is_uniform());
    // appended after the uniform prefix
    assert_eq!(man.policy_id("fc2-fp").unwrap(), PolicyId(4));
    assert_eq!(man.policy_name(PolicyId(4)), "fc2-fp");
}

#[test]
fn fallback_escalates_precision_only() {
    // m3 minus attn_output (111011) matches no artifact; m2 (111110)
    // would *re-quantize* attn_output so it must be skipped; m1 (110010)
    // only escalates -> wins.
    let man = load(
        r#"{"attn-out-fp": {"base": "m3", "overrides": [["attn_output", "fp"]],
                            "fallback": ["m2", "m1", "fp"]}}"#,
    )
    .unwrap();
    let spec = man.policy("attn-out-fp").unwrap();
    assert_eq!(spec.effective.tag(), "111011");
    assert_eq!(spec.exec_mode, man.mode_id("m1").unwrap());
}

#[test]
fn policy_error_paths_name_the_known_lists() {
    // unknown base mode -> the known-mode list (Manifest::mode_id shape)
    let chain = format!("{:#}", load(r#"{"p": {"base": "m9"}}"#).unwrap_err());
    assert!(chain.contains("unknown mode") && chain.contains("m9"), "{chain}");
    assert!(chain.contains("fp") && chain.contains("m3"), "{chain}");

    // unknown module group in an override -> the group list
    let chain = format!(
        "{:#}",
        load(r#"{"p": {"base": "m3", "overrides": [["fc9", "fp"]]}}"#).unwrap_err()
    );
    assert!(chain.contains("unknown module group") && chain.contains("attn_output"), "{chain}");

    // bad precision spelling
    let chain = format!(
        "{:#}",
        load(r#"{"p": {"base": "m3", "overrides": [["fc2", "int4"]]}}"#).unwrap_err()
    );
    assert!(chain.contains("unknown precision"), "{chain}");

    // unknown mode in the fallback chain
    let chain = format!(
        "{:#}",
        load(
            r#"{"p": {"base": "m3", "overrides": [["attn_output", "fp"]],
                      "fallback": ["m7"]}}"#
        )
        .unwrap_err()
    );
    assert!(chain.contains("bad fallback mode"), "{chain}");

    // unmatched switches with no usable fallback
    let chain = format!(
        "{:#}",
        load(r#"{"p": {"base": "m3", "overrides": [["attn_output", "fp"]]}}"#).unwrap_err()
    );
    assert!(chain.contains("no mode artifact matches"), "{chain}");
}

#[test]
fn duplicate_and_shadowing_policy_names_rejected() {
    // our order-preserving JSON parser keeps duplicate keys, so the
    // loader must reject them rather than silently last-wins
    let dup = r#"{"p": {"base": "fp"}, "p": {"base": "m3"}}"#;
    let chain = format!("{:#}", load(dup).unwrap_err());
    assert!(chain.contains("duplicate policy"), "{chain}");

    let shadow = r#"{"m3": {"base": "fp"}}"#;
    let chain = format!("{:#}", load(shadow).unwrap_err());
    assert!(chain.contains("shadows the mode"), "{chain}");
}

#[test]
fn inline_interning_is_canonical() {
    let man = load(
        r#"{"attn-out-fp": {"base": "m3", "overrides": [["attn_output", "fp"]],
                            "fallback": ["m2", "m1", "fp"]}}"#,
    )
    .unwrap();

    // identical inline draft -> the named policy's id (stats keep its name)
    let named = man
        .intern_inline_policy(
            &PolicyDraft::base("m3")
                .with_override("attn_output", "fp")
                .with_fallback("m2")
                .with_fallback("m1")
                .with_fallback("fp"),
        )
        .unwrap();
    assert_eq!(named, man.policy_id("attn-out-fp").unwrap());

    // novel draft -> uniform policy of its executable mode
    let uniform = man
        .intern_inline_policy(&PolicyDraft::base("m3").with_override("fc2", "fp"))
        .unwrap();
    assert_eq!(uniform, man.policy_id("m2").unwrap());
    assert_eq!(man.policy_by_id(uniform).exec_mode, ModeId(2));

    // a bare uniform draft -> the mode's own slot
    let fp = man.intern_inline_policy(&PolicyDraft::base("fp")).unwrap();
    assert_eq!(fp, man.policy_id("fp").unwrap());

    // unresolvable inline drafts fail at interning, not downstream
    assert!(man
        .intern_inline_policy(&PolicyDraft::base("m3").with_override("attn", "fp"))
        .is_err());
}

/// Degradation-chain introspection (DESIGN.md §5.8): the governor's
/// walk is derived from each policy's declared fallback ∪ base, keeps
/// only modes strictly cheaper (more INT8) than the executable mode,
/// and orders them closest-first.
#[test]
fn downgrade_chain_walks_declared_modes_cheapest_last() {
    let man = load(
        r#"{"attn-out-fp": {"base": "m3", "overrides": [["attn_output", "fp"]],
                            "fallback": ["m2", "m1", "fp"]}}"#,
    )
    .unwrap();
    // exec mode is m1 (110010); of fallback ∪ base, m2 (111110) and the
    // base m3 (111111) strictly contain m1's INT8 set — fp does not, m1
    // is the exec itself.  Ascending INT8 count: m2 (5) then m3 (6).
    let pid = man.policy_id("attn-out-fp").unwrap();
    let chain = man.downgrade_chain(pid);
    assert_eq!(
        chain,
        vec![man.policy_id("m2").unwrap(), man.policy_id("m3").unwrap()],
        "chain must step to the closest cheaper mode first"
    );
    // chain entries are uniform policies sharing the mode's dense index
    for step in &chain {
        assert!(man.policy_by_id(*step).is_uniform());
    }

    // uniform policies declare no fallback -> ungovernable (the governor
    // never invents a precision trade the author did not write down)
    for mode in ["fp", "m1", "m2", "m3"] {
        assert!(
            man.downgrade_chain(man.policy_id(mode).unwrap()).is_empty(),
            "uniform {mode} must have an empty chain"
        );
    }

    // a policy that lands exactly on an artifact (exec == effective) can
    // still degrade along declared fallbacks that quantize further
    let man = load(
        r#"{"fc2-fp": {"base": "m3", "overrides": [["fc2", "fp"]],
                       "fallback": ["m1", "fp"]}}"#,
    )
    .unwrap();
    let pid = man.policy_id("fc2-fp").unwrap();
    // exec is m2 exactly; of fallback ∪ base {m1, fp, m3}, only m3
    // strictly contains m2's INT8 set (m1 and fp only raise precision)
    assert_eq!(man.downgrade_chain(pid), vec![man.policy_id("m3").unwrap()]);
}

#[test]
fn checkpoint_validation_reports_policy_context() {
    use zqhero::model::{Container, Tensor};
    use zqhero::quant::validate_for_policy;

    let man = load(r#"{"fc2-fp": {"base": "m3", "overrides": [["fc2", "fp"]]}}"#).unwrap();
    let policy = man.policy("fc2-fp").unwrap();

    // the fixture modes declare empty signatures: an empty checkpoint
    // validates, a non-empty one fails naming the policy and both tags
    assert!(validate_for_policy(&Container::new(), &man, policy).is_ok());
    let mut ckpt = Container::new();
    ckpt.push("stray", Tensor::f32(vec![1], vec![0.0]));
    let chain = format!("{:#}", validate_for_policy(&ckpt, &man, policy).unwrap_err());
    assert!(chain.contains("fc2-fp") && chain.contains("111110"), "{chain}");
}

#[test]
fn wire_shim_round_trip_preserves_route() {
    let man = load(
        r#"{"attn-out-fp": {"base": "m3", "overrides": [["attn_output", "fp"]],
                            "fallback": ["m2", "m1", "fp"]}}"#,
    )
    .unwrap();

    // v1 string-mode frame desugars to the mode's uniform policy...
    let v1 = json::parse(r#"{"task": "sst2", "mode": "m3", "ids": [1, 2, 3]}"#).unwrap();
    let (spec, version) = parse_request(&v1, man.seq).unwrap();
    assert_eq!(version, 1);
    let pid = match &spec.policy {
        Some(PolicyRef::Named(n)) => man.policy_id(n).unwrap(),
        other => panic!("expected named policy, got {other:?}"),
    };
    assert_eq!(pid, man.policy_id("m3").unwrap());

    // ...and re-emitting the same spec as v2 interns to the same id
    let (spec2, version2) = parse_request(&request_to_json(&spec), man.seq).unwrap();
    assert_eq!(version2, 2);
    assert_eq!(spec2.policy, spec.policy);
    assert_eq!(spec2.ids, spec.ids);

    // an inline v2 frame interns through the same table
    let v2 = json::parse(
        r#"{"v": 2, "task": "sst2",
            "policy": {"base": "m3", "overrides": [["attn_output", "fp"]],
                       "fallback": ["m2", "m1", "fp"]},
            "ids": [1]}"#,
    )
    .unwrap();
    let (spec3, _) = parse_request(&v2, man.seq).unwrap();
    let pid3 = match &spec3.policy {
        Some(PolicyRef::Inline(d)) => man.intern_inline_policy(d).unwrap(),
        other => panic!("expected inline policy, got {other:?}"),
    };
    assert_eq!(pid3, man.policy_id("attn-out-fp").unwrap());
}
