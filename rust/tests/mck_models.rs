//! heromck model tests for the crown-jewel concurrency invariants
//! (DESIGN.md §5.12).
//!
//! Each test runs the real spine type (not a mock) under the modeled
//! scheduler: `crate::sync` resolves to `zqhero::mck::sync` because this
//! test only compiles with `--features heromck`, so the `DispatchState`
//! atomics, the `Recorder` mutex, the governor cells, the staging
//! shelves and the `ThreadPool` condvar are all schedule points heromck
//! can exhaustively interleave (within the preemption/schedule bounds —
//! see the soundness caveat in `mck::explore`).
//!
//! Mutation sensitivity (the reason these tests exist): delete the
//! generation guard at the top of `DispatchState::complete`, or the
//! `GovernorShared::publish` store, and the corresponding test below
//! fails with a replayable `MCK_REPLAY=mck1....` schedule token.

#![cfg(feature = "heromck")]

use std::collections::BTreeSet;
use std::path::Path;

use zqhero::coordinator::{GovernorShared, Recorder};
use zqhero::exec::ThreadPool;
use zqhero::mck::{self, Config};
use zqhero::model::manifest::{PolicyId, TaskId};
use zqhero::runtime::staging::StagingPool;
use zqhero::runtime::DispatchState;
use zqhero::sync::atomic::{AtomicUsize, Ordering};
use zqhero::sync::{thread, Arc, Mutex};

/// CI honours `MCK_SCHEDULES`; local runs get the defaults.
fn cfg() -> Config {
    Config::from_env()
}

// ---------------------------------------------------------------- dispatch

/// The §5.10 incarnation protocol: a completion carrying a generation
/// from *before* a `mark_dead` must not touch the revived replica's
/// accounting.  One replica, one pinned group; three racing threads:
///
///   killer       mark_dead(0); revive(0)         (supervisor restart)
///   re-assigner  assign(key) -> g2               (dispatch after restart)
///   staler       complete(key, 0, g0)            (readback from the old
///                                                 incarnation, swept)
///
/// In every schedule where the re-assign observed the new incarnation
/// (`g2 == g0 + 1`), the stale complete must have been a no-op: the new
/// incarnation's inflight count and pin survive.  Remove the generation
/// check in `complete()` and the schedule killer -> re-assigner ->
/// staler decrements the *new* incarnation's inflight to 0 — heromck
/// finds it and prints the replay token.
#[test]
fn dispatch_stale_completion_is_a_no_op() {
    mck::check("dispatch-stale-generation", cfg(), || {
        let ds = Arc::new(DispatchState::new(1));
        let key = (TaskId(0), PolicyId(0));
        let (r0, g0) = ds.assign(key);
        assert_eq!(r0, 0);

        let killer = {
            let ds = Arc::clone(&ds);
            thread::spawn(move || {
                ds.mark_dead(0);
                ds.revive(0);
            })
        };
        let reassign = {
            let ds = Arc::clone(&ds);
            thread::spawn(move || ds.assign(key))
        };
        let staler = {
            let ds = Arc::clone(&ds);
            thread::spawn(move || ds.complete(key, 0, g0))
        };

        killer.join().unwrap();
        let (_, g2) = reassign.join().unwrap();
        staler.join().unwrap();

        if g2 == g0 + 1 {
            // the re-assign landed on the revived incarnation; the stale
            // complete (generation g0) must not have touched it
            assert_eq!(
                ds.inflight(0),
                1,
                "stale completion decremented the new incarnation's inflight"
            );
            assert_eq!(ds.pinned_groups(), 1, "stale completion unpinned the new group");
        }
        assert!(ds.alive(0));
    });
}

/// Same incarnation protocol one tier up (DESIGN.md §5.14): the
/// front-end's `NodeDispatch` routes (task, policy, seq-class) groups to
/// engine *nodes*, and a node death sweeps its pending frames for
/// re-routing while replies from the old incarnation may still arrive on
/// a half-dead link.  One node, one pinned group; the same three racing
/// threads as the replica model:
///
///   killer       mark_dead(0); revive(0)         (link supervisor reconnect)
///   re-assigner  assign(key) -> g2               (route after re-join)
///   staler       complete(key, 0, g0)            (stale frame from the old
///                                                 incarnation, already swept)
///
/// Whenever the re-assign observed the new incarnation, the stale
/// completion must have been neutralized by the generation guard: the
/// revived node's inflight count and the group pin survive.  Drop the
/// generation check in `NodeDispatch::complete` and heromck finds the
/// schedule that double-retires the request.
#[test]
fn node_dispatch_stale_completion_is_a_no_op() {
    mck::check("node-dispatch-stale-generation", cfg(), || {
        let nd = Arc::new(zqhero::coordinator::NodeDispatch::new(1));
        let key = (TaskId(0), PolicyId(0), 0usize);
        let (n0, g0) = nd.assign(key);
        assert_eq!(n0, 0);

        let killer = {
            let nd = Arc::clone(&nd);
            thread::spawn(move || {
                nd.mark_dead(0);
                nd.revive(0);
            })
        };
        let reassign = {
            let nd = Arc::clone(&nd);
            thread::spawn(move || nd.assign(key))
        };
        let staler = {
            let nd = Arc::clone(&nd);
            thread::spawn(move || nd.complete(key, 0, g0))
        };

        killer.join().unwrap();
        let (_, g2) = reassign.join().unwrap();
        staler.join().unwrap();

        if g2 == g0 + 1 {
            // the re-assign landed on the revived incarnation; the stale
            // complete (generation g0) must not have touched it
            assert_eq!(
                nd.inflight(0),
                1,
                "stale completion decremented the revived node's inflight"
            );
            assert_eq!(nd.pinned_groups(), 1, "stale completion unpinned the new group");
        }
        assert!(nd.alive(0));
    });
}

// ---------------------------------------------------------------- recorder

/// Ledger identity under interleaved terminal replies: however the
/// completion / error / expiry threads interleave inside the slot
/// mutex, `requests == completed + errors + expired + failed` holds in
/// every observable snapshot order.
#[test]
fn recorder_ledger_identity_under_interleaving() {
    mck::check("recorder-ledger-identity", cfg(), || {
        let rec = Arc::new(Recorder::new(vec!["int8".to_string()], 1));
        let p = PolicyId(0);
        let terminals: Vec<_> = (0u8..3)
            .map(|kind| {
                let rec = Arc::clone(&rec);
                thread::spawn(move || match kind {
                    0 => rec.record_request(p, 900, 40, false),
                    1 => rec.record_request(p, 900, 40, true),
                    _ => rec.record_failed(p),
                })
            })
            .collect();
        for t in terminals {
            t.join().unwrap();
        }
        let snap = rec.snapshot();
        let s = &snap["int8"];
        assert_eq!(s.requests, 3);
        assert_eq!(
            s.requests,
            s.completed + s.errors + s.expired + s.failed,
            "ledger identity broken: {s:?}"
        );
        assert_eq!((s.completed, s.errors, s.failed), (1, 1, 1));
    });
}

// ---------------------------------------------------------------- governor

/// The two `relaxed-ok` annotations in `GovernorShared` claim (a) a
/// route read is always a value some `publish` actually stored — never
/// torn, never invented — and (b) after the publisher is joined
/// (happens-before), the new route is visible.  The model's relaxed
/// semantics let the load return *any* coherent store, so (a) fails if
/// a torn value were possible and (b) fails if `publish` is removed.
#[test]
fn governor_publish_effective_honors_relaxed_claims() {
    mck::check("governor-relaxed-cells", cfg(), || {
        let g = Arc::new(GovernorShared::new(2));
        let writer = {
            let g = Arc::clone(&g);
            thread::spawn(move || g.publish(PolicyId(0), PolicyId(1)))
        };
        let reader = {
            let g = Arc::clone(&g);
            thread::spawn(move || g.effective(PolicyId(0)))
        };
        let seen = reader.join().unwrap();
        assert!(
            seen == PolicyId(0) || seen == PolicyId(1),
            "racing read returned a route nobody published: {seen:?}"
        );
        writer.join().unwrap();
        // join() synchronizes-with the writer: the downgrade is now the
        // only coherent value left for this cell
        assert_eq!(g.effective(PolicyId(0)), PolicyId(1), "published route not visible after join");
        // the untouched cell still routes to itself
        assert_eq!(g.effective(PolicyId(1)), PolicyId(1));
    });
}

// ----------------------------------------------------------------- staging

/// Shelf check-in/check-out between a batcher thread and an engine
/// thread: the cap is never exceeded, a shelved buffer is never handed
/// to two takers, and `take` always yields a buffer shaped for the
/// requested cell no matter the interleaving.
#[test]
fn staging_shelf_checkin_checkout() {
    mck::check("staging-shelves", cfg(), || {
        let pool = Arc::new(StagingPool::new(&[128], &[4], 1));
        let sides: Vec<_> = (0..2)
            .map(|_| {
                let pool = Arc::clone(&pool);
                thread::spawn(move || {
                    let buf = pool.take(128, 4);
                    assert_eq!((buf.seq, buf.bucket), (128, 4));
                    pool.put(buf);
                })
            })
            .collect();
        for t in sides {
            t.join().unwrap();
        }
        // cap is 1: whichever put lost the race was dropped, the winner
        // rests on the shelf — never two, never a leak of the cap
        assert!(pool.pooled() <= 1, "per-cell cap exceeded");
        let again = pool.take(128, 4);
        assert_eq!((again.seq, again.bucket), (128, 4));
        assert_eq!(pool.pooled(), 0, "take left a phantom buffer shelved");
    });
}

// --------------------------------------------------------------- exec pool

/// `wait_idle` parks on the pool condvar until `completed == queued`.
/// The hazard is the classic missed wakeup: a worker finishing the last
/// job between the caller's count check and its park.  Under the model
/// every such window is explored; a lost notify deadlocks the schedule
/// and heromck reports it with the held-lock set.
#[test]
fn thread_pool_wait_idle_never_misses_the_wakeup() {
    mck::check("pool-wait-idle", cfg(), || {
        let pool = ThreadPool::new(1, "mdl");
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let hits = Arc::clone(&hits);
            assert!(pool.spawn(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 2, "wait_idle returned before the jobs ran");
        assert_eq!(pool.completed(), 2);
        assert_eq!(pool.pending(), 0);
        drop(pool); // Stop + join must terminate in every schedule
    });
}

// -------------------------------------------------------- lock-order witness

/// Dynamic/static agreement (the tentpole cross-check): heromck records
/// the runtime lock-acquisition order of a protocol model that mirrors
/// the spine's documented nesting — a replica-slot critical section
/// acquiring the job queue — using the same lock classes herolint
/// extracts from `.expect("...")` labels.  Every edge the scheduler
/// witnesses at runtime must already be in herolint's static
/// `lock_edges` for `src/`, and the §5.11 spine edge must be witnessed
/// by both sides.
#[test]
fn runtime_lock_order_witness_agrees_with_static_lock_edges() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = zqhero::lint::lint_tree(&src).expect("linting the source tree");
    let static_edges: BTreeSet<(String, String)> = report
        .analysis
        .edges
        .iter()
        .map(|e| (e.from.clone(), e.to.clone()))
        .collect();

    let out = mck::check("lock-order-witness", cfg(), || {
        let slot = Arc::new(Mutex::new_named("replica slot", 0u32));
        let queue = Arc::new(Mutex::new_named("job queue", Vec::<u32>::new()));
        let pollers: Vec<_> = (0..2)
            .map(|i| {
                let slot = Arc::clone(&slot);
                let queue = Arc::clone(&queue);
                thread::spawn(move || {
                    // poll_replica shape: inspect the slot, then drain
                    // into the queue while still holding it
                    let mut s = slot.lock().expect("replica slot");
                    *s += 1;
                    queue.lock().expect("job queue").push(i);
                })
            })
            .collect();
        for t in pollers {
            t.join().unwrap();
        }
        assert_eq!(*slot.lock().unwrap(), 2);
        assert_eq!(queue.lock().unwrap().len(), 2);
    });

    assert!(!out.edges.is_empty(), "scheduler witnessed no lock nesting");
    for edge in &out.edges {
        assert!(
            static_edges.contains(edge),
            "runtime witnessed {edge:?} but herolint's static lock_edges never saw it \
             — the model and the spine discipline have diverged"
        );
    }
    let spine = ("replica slot".to_string(), "job queue".to_string());
    assert!(out.edges.contains(&spine), "dynamic witness missed the §5.11 spine edge");
    assert!(static_edges.contains(&spine), "static analysis lost the §5.11 spine edge");
}
