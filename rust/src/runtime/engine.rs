//! Engine thread: owns the (non-`Send`) PJRT runtime and serves execution
//! requests over channels — the executor-thread pattern a production GPU
//! server uses.  The coordinator and its worker pool stay fully `Send`.
//!
//! The request loop is a software pipeline (DESIGN.md §5.4): while batch
//! N executes on the device, batch N+1's host arrays are uploaded, and
//! batch N's readback is deferred until N+1 has been launched, so the
//! device never idles waiting on a host copy.  Readback results
//! (de-batching, reply dispatch) are handed to the shared
//! `exec::ThreadPool` instead of blocking the engine thread.  Jobs carry
//! only interned `TaskId`/`PolicyId` — no strings on the hot path; the
//! engine selects the executable through its mirrored `policy -> exec
//! mode` table (manifest-derived, so it agrees with the coordinator's
//! without a handshake — DESIGN.md §6.3).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::exec::ThreadPool;
use crate::model::manifest::{Manifest, ModeId, PolicyId, TaskId};
use crate::model::tensor::Tensor;
use crate::model::Container;

use super::staging::{StagingBuf, StagingPool};
use super::{PendingOutputs, Runtime};

/// Completion callback: runs on the shared worker pool with the batch
/// result (readback stage output).  Owning the per-request reply senders,
/// it is where de-batching and reply dispatch happen.
pub type Completion = Box<dyn FnOnce(Result<InferDone>) + Send + 'static>;

pub struct InferJob {
    pub task: TaskId,
    /// Interned precision policy; the engine maps it to its executable
    /// mode via the mirrored `policy_exec` table.
    pub policy: PolicyId,
    /// Pooled host buffers: `bucket * seq` ids/type_ids/mask.  Recycled to
    /// the staging pool by the engine right after the device upload.
    pub staging: StagingBuf,
    pub done: Completion,
}

pub struct InferDone {
    pub logits: Tensor,
    /// launch -> readback-complete time (engine-thread measured), us.
    /// Under overlap this includes the next batch's upload window.
    pub exec_us: u64,
    /// host -> device input copy time, microseconds.
    pub upload_us: u64,
}

enum Msg {
    Infer(Box<InferJob>),
    Stop,
}

/// Route/policy tables mirrored out of the engine-side manifest at
/// startup: both sides derive ids from the same `manifest.json`, so the
/// coordinator's and engine's tables are identical by construction (the
/// parity the policy integration tests pin).
struct RouteTables {
    tasks: Vec<String>,
    modes: Vec<String>,
    policies: Vec<String>,
    /// `[policy] -> executable mode` — the engine-side half of policy
    /// executable selection.
    policy_exec: Vec<ModeId>,
}

/// `Send` handle to the engine thread.
pub struct Engine {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
    /// Route tables mirrored from the engine-side manifest so blocking
    /// (CLI/test) callers can resolve names without loading it again.
    tasks: Vec<String>,
    modes: Vec<String>,
    policies: Vec<String>,
    policy_exec: Vec<ModeId>,
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Overlap upload/execute/readback (one batch in flight behind the
    /// head).  `false` restores the strictly serial per-batch loop — kept
    /// for A/B benchmarking the pipeline win.
    pub overlap: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { overlap: true }
    }
}

impl Engine {
    /// Spawn the engine: loads the manifest, uploads every (task, mode)
    /// checkpoint in `preload`, and pre-compiles the executables for the
    /// requested (mode, bucket) pairs so the serving hot path never
    /// compiles.  `pool` runs completion callbacks; `staging` receives
    /// recycled host buffers.
    pub fn spawn(
        artifacts: PathBuf,
        preload: Vec<(String, String, Container)>,
        precompile: Vec<(String, usize)>,
        pool: Arc<ThreadPool>,
        staging: Arc<StagingPool>,
        options: EngineOptions,
    ) -> Result<Engine> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<RouteTables>>();
        let join = std::thread::Builder::new()
            .name("zqhero-engine".into())
            .spawn(move || engine_main(artifacts, preload, precompile, rx, ready_tx, pool, staging, options))
            .context("spawning engine thread")?;
        let tables = ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Engine {
            tx,
            join: Some(join),
            tasks: tables.tasks,
            modes: tables.modes,
            policies: tables.policies,
            policy_exec: tables.policy_exec,
        })
    }

    /// Enqueue a job; on failure (engine gone) the job is handed back so
    /// the caller can recycle its staging buffer and fail its requests.
    pub fn submit(&self, job: InferJob) -> std::result::Result<(), Box<InferJob>> {
        self.tx.send(Msg::Infer(Box::new(job))).map_err(|e| match e.0 {
            Msg::Infer(job) => job,
            Msg::Stop => unreachable!("submit only sends Infer"),
        })
    }

    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        crate::model::manifest::intern_position(&self.tasks, name)
            .map(TaskId)
            .with_context(|| format!("unknown task {name:?}"))
    }

    pub fn mode_id(&self, name: &str) -> Result<ModeId> {
        crate::model::manifest::intern_position(&self.modes, name)
            .map(ModeId)
            .with_context(|| format!("unknown mode {name:?}"))
    }

    /// Resolve a policy name against the engine's mirrored table (uniform
    /// mode names included).
    pub fn policy_id(&self, name: &str) -> Result<PolicyId> {
        crate::model::manifest::intern_position(&self.policies, name)
            .map(PolicyId)
            .with_context(|| format!("unknown policy {name:?} (have {:?})", self.policies))
    }

    /// The mirrored policy-name table (parity checks against the
    /// coordinator's `Manifest::policy_order`).
    pub fn policy_names(&self) -> &[String] {
        &self.policies
    }

    /// The executable mode this policy selects on the engine.
    pub fn policy_exec_mode(&self, policy: PolicyId) -> Result<ModeId> {
        self.policy_exec
            .get(policy.index())
            .copied()
            .with_context(|| format!("PolicyId {} out of range", policy.0))
    }

    /// Synchronous convenience call (CLI paths, tests).  `route` is a
    /// policy name (uniform mode names work).  `ids`/`type_ids` are
    /// `[bucket * seq]`; the mask is derived from PAD positions.
    pub fn infer_blocking(
        &self,
        task: &str,
        route: &str,
        bucket: usize,
        ids: Vec<i32>,
        type_ids: Vec<i32>,
    ) -> Result<InferDone> {
        let seq = ids.len() / bucket.max(1);
        let staging = StagingBuf::from_parts(bucket, seq, ids, type_ids);
        let (reply, rx) = channel();
        self.submit(InferJob {
            task: self.task_id(task)?,
            policy: self.policy_id(route)?,
            staging,
            done: Box::new(move |res| {
                let _ = reply.send(res);
            }),
        })
        .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// One launched-but-not-read-back batch (the pipeline register).
struct InFlight {
    pending: PendingOutputs,
    done: Completion,
    t0: Instant,
    upload_us: u64,
}

/// Stage 3: synchronize, copy logits to host, and hand de-batching +
/// reply dispatch to the worker pool.
fn retire(rt: &Runtime, f: InFlight, pool: &ThreadPool) {
    let res = rt.readback_logits(f.pending).map(|logits| InferDone {
        logits,
        exec_us: f.t0.elapsed().as_micros() as u64,
        upload_us: f.upload_us,
    });
    let done = f.done;
    pool.spawn(move || done(res));
}

#[allow(clippy::too_many_arguments)]
fn engine_main(
    artifacts: PathBuf,
    preload: Vec<(String, String, Container)>,
    precompile: Vec<(String, usize)>,
    rx: Receiver<Msg>,
    ready_tx: Sender<Result<RouteTables>>,
    pool: Arc<ThreadPool>,
    staging: Arc<StagingPool>,
    options: EngineOptions,
) {
    let mut rt = match Manifest::load(&artifacts).and_then(Runtime::new) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let mut init = || -> Result<RouteTables> {
        for (task, mode, ckpt) in &preload {
            rt.upload_checkpoint(task, mode, ckpt)?;
        }
        for (mode, bucket) in &precompile {
            rt.model_exe(mode, *bucket)?;
        }
        let man = &rt.manifest;
        Ok(RouteTables {
            tasks: man.task_order.clone(),
            modes: man.mode_order.clone(),
            policies: man.policy_order.clone(),
            policy_exec: man
                .policy_order
                .iter()
                .map(|p| man.policies[p].exec_mode)
                .collect(),
        })
    };
    let tables = match init() {
        Ok(t) => t,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    // keep the engine thread's own copy of executable selection
    let policy_exec = tables.policy_exec.clone();
    if ready_tx.send(Ok(tables)).is_err() {
        return;
    }

    let mut inflight: Option<InFlight> = None;
    loop {
        // With a batch executing, prefer new work (to keep the device fed)
        // but retire the head batch as soon as the queue runs dry.
        let msg = if inflight.is_some() {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(TryRecvError::Empty) => {
                    if let Some(f) = inflight.take() {
                        retire(&rt, f, &pool);
                    }
                    rx.recv().ok()
                }
                Err(TryRecvError::Disconnected) => None,
            }
        } else {
            rx.recv().ok()
        };
        let job = match msg {
            Some(Msg::Infer(job)) => *job,
            Some(Msg::Stop) | None => break,
        };

        let InferJob { task, policy, staging: host, done } = job;
        // Executable selection: policy -> mode through the mirrored table.
        let mode = match policy_exec.get(policy.index()) {
            Some(m) => *m,
            None => {
                staging.put(host);
                pool.spawn(move || done(Err(anyhow!("PolicyId {} out of range", policy.0))));
                continue;
            }
        };
        let t0 = Instant::now();
        // Stage 1: upload this batch's inputs (overlaps the previous
        // batch's device execution), then recycle the host buffers.
        let uploaded = rt.upload_inputs(host.bucket, &host.ids, &host.type_ids, &host.mask);
        let upload_us = t0.elapsed().as_micros() as u64;
        staging.put(host);
        let inputs = match uploaded {
            Ok(i) => i,
            Err(e) => {
                if let Some(f) = inflight.take() {
                    retire(&rt, f, &pool);
                }
                pool.spawn(move || done(Err(e)));
                continue;
            }
        };
        // Stage 2: launch this batch.
        let launched = rt.execute_model(task, mode, &inputs);
        // Stage 3 for the previous batch: its readback now overlaps this
        // batch's execution.
        if let Some(f) = inflight.take() {
            retire(&rt, f, &pool);
        }
        match launched {
            Ok(pending) => {
                let f = InFlight { pending, done, t0, upload_us };
                if options.overlap {
                    inflight = Some(f);
                } else {
                    retire(&rt, f, &pool);
                }
            }
            Err(e) => {
                pool.spawn(move || done(Err(e)));
            }
        }
    }
    if let Some(f) = inflight.take() {
        retire(&rt, f, &pool);
    }
}
