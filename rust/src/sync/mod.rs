//! `crate::sync` — the sync-primitive facade for the concurrent serving
//! spine (DESIGN.md §5.12).
//!
//! Normal builds re-export `std::sync` (and `std::thread`) verbatim:
//! zero cost, zero behaviour change.  Under `--features heromck` the
//! same names resolve to heromck's instrumented doubles
//! ([`crate::mck::sync`], [`crate::mck::thread`]), so the spine's own
//! locks, atomics, channels, and threads can be driven through the
//! deterministic schedule explorer unchanged.
//!
//! `Arc` is always the real `std::sync::Arc` — reference counting is
//! not a schedule point, and modeling it would only bloat traces.
//!
//! The concurrent spine (`coordinator/{server,batcher,governor,stats}`,
//! `runtime/{engine,staging}`, `exec`) imports from here instead of
//! `std::sync`.  Modules outside the model-checked spine (e.g.
//! `coordinator/net`, which owns OS sockets heromck does not model)
//! keep using `std` directly.

#[cfg(not(feature = "heromck"))]
pub use std::sync::{
    atomic, mpsc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
#[cfg(not(feature = "heromck"))]
pub use std::thread;

#[cfg(feature = "heromck")]
pub use crate::mck::sync::{
    atomic, mpsc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
#[cfg(feature = "heromck")]
pub use crate::mck::thread;

pub use std::sync::Arc;
