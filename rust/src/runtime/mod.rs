//! PJRT runtime: loads the AOT HLO-text artifacts, keeps weights
//! device-resident, and executes inference/calibration on the hot path —
//! no Python anywhere.
//!
//! `Runtime` is intentionally single-threaded (`PjRtClient` is `Rc`-based):
//! CLI commands use it directly on the main thread; the serving coordinator
//! wraps it in dedicated engine threads (`engine.rs`) and talks to them over
//! channels, the same shape as GPU-executor threads in a production server.
//! Since the handles are not `Send`, scaling out means *replicating* the
//! runtime: `engine::EnginePool` spawns N engine threads, each owning its
//! own `Runtime` (checkpoints + executables), behind a load-aware
//! dispatcher with per-group FIFO pinning (DESIGN.md §5.7).
//!
//! Hot-path tables are dense: executables live in a
//! `[mode][seq_bucket][batch_bucket]`-indexed `Vec` and checkpoints in
//! `[task][mode]`, both sized from the manifest, so steady-state dispatch
//! is three array indexes — no string hashing, no `HashMap` probes
//! (DESIGN.md §5.2, §5.9).  The string-keyed methods remain as cold-path
//! wrappers that resolve names to `TaskId`/`ModeId` once.

pub mod engine;
pub mod staging;

pub use engine::{
    DispatchState, Engine, EngineOptions, EnginePool, FaultKind, FaultPlan, FaultSpec, PoolEvent,
    PoolEventHook, ReplicaFailed, RestartPolicy,
};

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::manifest::{Manifest, ModeId, TaskId};
use crate::model::tensor::{DType, Tensor};
use crate::model::Container;

/// Host copy of an executable's output tuple.
pub struct Outputs {
    pub tensors: Vec<Tensor>,
}

/// A compiled artifact plus load/compile timings (reported by `repro info`).
pub struct Exe {
    pub exe: xla::PjRtLoadedExecutable,
    pub path: String,
    pub load_ms: f64,
    pub compile_ms: f64,
}

/// Device-resident checkpoint: one buffer per parameter, in manifest order.
pub struct DeviceCheckpoint {
    pub bufs: Vec<xla::PjRtBuffer>,
    pub nbytes: usize,
}

/// Device-resident input buffers for one batch (stage 1 of the pipeline).
pub struct InputBufs {
    pub seq: usize,
    pub bucket: usize,
    ids: xla::PjRtBuffer,
    type_ids: xla::PjRtBuffer,
    mask: xla::PjRtBuffer,
}

/// In-flight execution: device output buffers that have been launched but
/// not read back (stage 2 of the pipeline).  Holding one of these while
/// uploading/launching the next batch is what overlaps the stages.
pub struct PendingOutputs {
    results: Vec<Vec<xla::PjRtBuffer>>,
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    /// `[mode][seq_bucket_index][bucket_index]` -> compiled model
    /// executable (the (seq, batch) grid of DESIGN.md §5.9).
    exes: Vec<Vec<Vec<Option<Exe>>>>,
    /// misc executables (calibration artifact, micro benches) by path.
    raw_exes: HashMap<String, Exe>,
    /// `[task][mode]` -> device-resident weights.
    ckpts: Vec<Vec<Option<DeviceCheckpoint>>>,
}

#[allow(dead_code)]
fn elem_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I8 => xla::ElementType::S8,
        DType::I32 => xla::ElementType::S32,
    }
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        let exes = (0..manifest.num_modes())
            .map(|_| {
                (0..manifest.num_seq_buckets())
                    .map(|_| (0..manifest.num_buckets()).map(|_| None).collect())
                    .collect()
            })
            .collect();
        let ckpts = (0..manifest.num_tasks())
            .map(|_| (0..manifest.num_modes()).map(|_| None).collect())
            .collect();
        Ok(Runtime { client, manifest, exes, raw_exes: HashMap::new(), ckpts })
    }

    // ---------------------------------------------------------------- load

    pub fn compile_hlo_file(client: &xla::PjRtClient, path: &Path) -> Result<Exe> {
        let t0 = Instant::now();
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow::anyhow!("loading {path:?}: {e}"))?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t1 = Instant::now();
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e}"))?;
        Ok(Exe {
            exe,
            path: path.display().to_string(),
            load_ms,
            compile_ms: t1.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Compile (and cache) the model executable for (mode, seq, bucket).
    pub fn model_exe(&mut self, mode: &str, seq: usize, bucket: usize) -> Result<&Exe> {
        let mode = self.manifest.mode_id(mode)?;
        self.model_exe_id(mode, seq, bucket)
    }

    /// Dense hot-path variant: the executable slot is two `Vec` indexes
    /// into the (seq bucket, batch bucket) grid.
    pub fn model_exe_id(&mut self, mode: ModeId, seq: usize, bucket: usize) -> Result<&Exe> {
        let si = self.manifest.seq_bucket_index(seq).with_context(|| {
            format!("mode {} has no seq bucket {seq}", self.manifest.mode_name(mode))
        })?;
        let bi = self.manifest.bucket_index(bucket).with_context(|| {
            format!("mode {} has no bucket {bucket}", self.manifest.mode_name(mode))
        })?;
        if self.exes[mode.index()][si][bi].is_none() {
            let spec = self.manifest.mode_by_id(mode);
            let rel = spec.artifacts.get(&(seq, bucket)).with_context(|| {
                format!(
                    "mode {} has no artifact for (seq {seq}, bucket {bucket})",
                    self.manifest.mode_name(mode)
                )
            })?;
            let exe = Self::compile_hlo_file(&self.client, &self.manifest.path(rel))?;
            self.exes[mode.index()][si][bi] = Some(exe);
        }
        // panic-ok: the None arm directly above just filled this slot
        Ok(self.exes[mode.index()][si][bi].as_ref().expect("just compiled"))
    }

    /// Compile (and cache) an arbitrary artifact by manifest-relative path.
    pub fn raw_exe(&mut self, rel: &str) -> Result<&Exe> {
        if !self.raw_exes.contains_key(rel) {
            let exe = Self::compile_hlo_file(&self.client, &self.manifest.path(rel))?;
            self.raw_exes.insert(rel.to_string(), exe);
        }
        Ok(&self.raw_exes[rel])
    }

    // ------------------------------------------------------------- weights

    pub fn upload_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        // NOTE: the typed `buffer_from_host_buffer::<T>` is used on purpose:
        // the crate's `buffer_from_host_raw_bytes` forwards the rust
        // `ElementType` discriminant straight to the C API, which is offset
        // from XLA's `PrimitiveType` (F32 silently becomes F16).  The typed
        // path converts via `T::TY.primitive_type()` and is correct.
        let buf = match &t.data {
            crate::model::TensorData::F32(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
            crate::model::TensorData::I8(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
            crate::model::TensorData::I32(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
        };
        buf.map_err(|e| anyhow::anyhow!("upload: {e}"))
    }

    /// Upload a checkpoint once; later executions reference the resident
    /// buffers (the per-request upload is only ids+mask — DESIGN.md §5.1).
    pub fn upload_checkpoint(&mut self, task: &str, mode: &str, ckpt: &Container) -> Result<()> {
        let task = self.manifest.task_id(task)?;
        let mode = self.manifest.mode_id(mode)?;
        self.upload_checkpoint_id(task, mode, ckpt)
    }

    pub fn upload_checkpoint_id(
        &mut self,
        task: TaskId,
        mode: ModeId,
        ckpt: &Container,
    ) -> Result<()> {
        let mut bufs = Vec::with_capacity(ckpt.len());
        let mut nbytes = 0;
        for (_, t) in &ckpt.entries {
            bufs.push(self.upload_tensor(t)?);
            nbytes += t.nbytes();
        }
        self.ckpts[task.index()][mode.index()] = Some(DeviceCheckpoint { bufs, nbytes });
        Ok(())
    }

    pub fn has_checkpoint(&self, task: &str, mode: &str) -> bool {
        match (self.manifest.task_id(task), self.manifest.mode_id(mode)) {
            (Ok(t), Ok(m)) => self.ckpts[t.index()][m.index()].is_some(),
            _ => false,
        }
    }

    pub fn checkpoint_nbytes(&self, task: &str, mode: &str) -> Option<usize> {
        let t = self.manifest.task_id(task).ok()?;
        let m = self.manifest.mode_id(mode).ok()?;
        self.ckpts[t.index()][m.index()].as_ref().map(|c| c.nbytes)
    }

    // ------------------------------------------------------------- execute

    fn read_outputs(results: Vec<Vec<xla::PjRtBuffer>>) -> Result<Outputs> {
        let buf = &results
            .first()
            .context("no replica outputs")?
            .first()
            .context("no outputs")?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        // artifacts are lowered with return_tuple=True
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p.array_shape().map_err(|e| anyhow::anyhow!("shape: {e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
            let t = match shape.ty() {
                xla::ElementType::F32 => {
                    Tensor::f32(dims, p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                xla::ElementType::S8 => {
                    Tensor::i8(dims, p.to_vec::<i8>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                xla::ElementType::S32 => {
                    Tensor::i32(dims, p.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                other => bail!("unsupported output element type {other:?}"),
            };
            tensors.push(t);
        }
        Ok(Outputs { tensors })
    }

    // ---- pipelined hot path (engine thread): upload | execute | readback

    /// Stage 1: copy one batch's host arrays into fresh device buffers.
    /// `seq` is the batch's seq bucket — short batches upload (and later
    /// execute) `bucket * seq_bucket` tokens, not `bucket * max_seq`.
    /// Only `&self` — it can run while a previous batch's outputs are
    /// still pending on the device.
    pub fn upload_inputs(
        &self,
        seq: usize,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<InputBufs> {
        if ids.len() != bucket * seq {
            bail!("ids len {} != bucket {bucket} * seq {seq}", ids.len());
        }
        if type_ids.len() != bucket * seq || mask.len() != bucket * seq {
            bail!("type_ids/mask length mismatch for bucket {bucket} * seq {seq}");
        }
        let up = |e: xla::Error| anyhow::anyhow!("{e}");
        Ok(InputBufs {
            seq,
            bucket,
            ids: self.client.buffer_from_host_buffer(ids, &[bucket, seq], None).map_err(up)?,
            type_ids: self
                .client
                .buffer_from_host_buffer(type_ids, &[bucket, seq], None)
                .map_err(up)?,
            mask: self.client.buffer_from_host_buffer(mask, &[bucket, seq], None).map_err(up)?,
        })
    }

    /// Stage 2: launch the executable against resident weights + uploaded
    /// inputs.  Returns without waiting for a host copy; the caller holds
    /// the `PendingOutputs` while staging the next batch.
    pub fn execute_model(
        &mut self,
        task: TaskId,
        mode: ModeId,
        inputs: &InputBufs,
    ) -> Result<PendingOutputs> {
        let (seq, bucket) = (inputs.seq, inputs.bucket);
        self.model_exe_id(mode, seq, bucket)?; // ensure compiled before borrowing ckpt
        let ckpt = self.ckpts[task.index()][mode.index()].as_ref().with_context(|| {
            format!(
                "checkpoint ({},{}) not uploaded",
                self.manifest.task_name(task),
                self.manifest.mode_name(mode)
            )
        })?;

        let mut args: Vec<&xla::PjRtBuffer> = ckpt.bufs.iter().collect();
        args.push(&inputs.ids);
        args.push(&inputs.type_ids);
        args.push(&inputs.mask);

        let si = self.manifest.seq_bucket_index(seq)?;
        let bi = self.manifest.bucket_index(bucket)?;
        // panic-ok: callers reach here only after exe() compiled this slot
        let exe = self.exes[mode.index()][si][bi].as_ref().expect("compiled above");
        let results = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Ok(PendingOutputs { results })
    }

    /// Stage 3: synchronize + copy the logits back to the host.
    pub fn readback_logits(&self, pending: PendingOutputs) -> Result<Tensor> {
        let mut outputs = Self::read_outputs(pending.results)?;
        if outputs.tensors.len() != 1 {
            bail!("model artifact returned {} outputs, expected 1", outputs.tensors.len());
        }
        Ok(outputs.tensors.remove(0))
    }

    /// Run a model executable with resident weights + fresh input buffers.
    /// `ids`/`type_ids` are `[bucket * seq_bucket]` i32, `mask`
    /// `[bucket * seq_bucket]` f32 — the seq bucket is derived from the
    /// payload length (`ids.len() / bucket`) and must name a manifest seq
    /// bucket.  Cold-path convenience: resolves names, then runs the
    /// three pipeline stages back-to-back.
    pub fn infer(
        &mut self,
        task: &str,
        mode: &str,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Tensor> {
        let task = self.manifest.task_id(task)?;
        let mode = self.manifest.mode_id(mode)?;
        self.infer_ids(task, mode, bucket, ids, type_ids, mask)
    }

    pub fn infer_ids(
        &mut self,
        task: TaskId,
        mode: ModeId,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Tensor> {
        if bucket == 0 || ids.len() % bucket != 0 {
            bail!("ids len {} not a multiple of bucket {bucket}", ids.len());
        }
        let seq = ids.len() / bucket;
        self.manifest.seq_bucket_index(seq)?; // fail with the known-bucket list
        let inputs = self.upload_inputs(seq, bucket, ids, type_ids, mask)?;
        let pending = self.execute_model(task, mode, &inputs)?;
        self.readback_logits(pending)
    }

    /// Cold-path policy wrapper: resolve a precision policy (uniform mode
    /// names work too) to its executable mode, then run the three
    /// pipeline stages back-to-back.
    pub fn infer_policy(
        &mut self,
        task: &str,
        policy: &str,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Tensor> {
        let task = self.manifest.task_id(task)?;
        let exec = self.manifest.policy(policy)?.exec_mode;
        self.infer_ids(task, exec, bucket, ids, type_ids, mask)
    }

    /// Run the calibration-instrumented artifact for one batch; returns
    /// (logits, stats in manifest order).
    pub fn calibrate_batch(
        &mut self,
        fp_bufs: &[xla::PjRtBuffer],
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Outputs> {
        let seq = self.manifest.seq;
        let batch = self.manifest.calib.batch;
        if ids.len() != batch * seq {
            bail!("calibration batch must be exactly {batch} x {seq}");
        }
        let rel = self.manifest.calib.artifact.clone();
        self.raw_exe(&rel)?;

        let up = |e: xla::Error| anyhow::anyhow!("{e}");
        let ids_b = self.client.buffer_from_host_buffer(ids, &[batch, seq], None).map_err(up)?;
        let ty_b =
            self.client.buffer_from_host_buffer(type_ids, &[batch, seq], None).map_err(up)?;
        let mask_b =
            self.client.buffer_from_host_buffer(mask, &[batch, seq], None).map_err(up)?;

        let mut args: Vec<&xla::PjRtBuffer> = fp_bufs.iter().collect();
        args.push(&ids_b);
        args.push(&ty_b);
        args.push(&mask_b);

        let exe = &self.raw_exes[&rel];
        let out = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    /// Upload raw tensors (calibration fp params / micro benches).
    pub fn upload_all(&self, tensors: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        tensors.iter().map(|t| self.upload_tensor(t)).collect()
    }

    /// Execute an arbitrary artifact with host tensors (micro benches).
    pub fn run_raw(&mut self, rel: &str, inputs: &[Tensor]) -> Result<Outputs> {
        self.raw_exe(rel)?;
        let bufs = self.upload_all(inputs)?;
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let exe = &self.raw_exes[rel];
        let out = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    /// Execute an arbitrary artifact with pre-uploaded buffers (hot loop).
    pub fn run_raw_buffers(&mut self, rel: &str, args: &[&xla::PjRtBuffer]) -> Result<Outputs> {
        self.raw_exe(rel)?;
        let exe = &self.raw_exes[rel];
        let out = exe.exe.execute_b(args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    pub fn loaded_exe_count(&self) -> usize {
        let model: usize = self
            .exes
            .iter()
            .flat_map(|grid| grid.iter())
            .map(|row| row.iter().filter(|e| e.is_some()).count())
            .sum();
        model + self.raw_exes.len()
    }
}
