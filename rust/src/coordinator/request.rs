//! Request/response types for the serving coordinator.
//!
//! Route strings are resolved to dense `TaskId`/`ModeId` once at
//! admission (`Coordinator::submit`); every type here is `String`-free so
//! the steady-state path never touches the allocator for routing.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::model::manifest::{ModeId, TaskId};

/// Precision mode selection per request (paper §2.3 — the accuracy/latency
/// trade-off is exposed per request, not per deployment).  Interned and
/// `Copy`: batcher group lookup is two integer compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub task: TaskId,
    pub mode: ModeId,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub key: GroupKey,
    /// `[seq]` token ids (already padded/truncated to the model seq).
    pub ids: Vec<i32>,
    pub type_ids: Vec<i32>,
    pub enqueued: Instant,
    pub reply: Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// `[num_labels]` logits for this request's row.
    pub logits: Vec<f32>,
    pub timing: Timing,
    pub error: Option<String>,
}

#[derive(Debug, Clone, Default)]
pub struct Timing {
    /// time from submit to batch dispatch
    pub queue_us: u64,
    /// engine execution time for the whole batch
    pub exec_us: u64,
    /// end-to-end (submit -> response send)
    pub total_us: u64,
    /// batch this request rode in
    pub batch_real: usize,
    pub bucket: usize,
    /// coordinator-wide dispatch sequence number of the batch this request
    /// rode in; within a (task, mode) group it is strictly increasing with
    /// request id — the FIFO witness the pipeline tests assert on.
    pub batch_seq: u64,
}
