"""Calibration-instrumented FP forward (paper §3: "100 batches, batch size
16, seq 128, forward pass only").

Wraps :func:`bert.bert_forward` with taps at every quantization insertion
point and reduces each tap to the statistic its scheme needs:

  =========  =======  ========================================
  tensor     scheme   statistic (per layer, pad-masked)
  =========  =======  ========================================
  X_q/k/v    SQ       scalar abs-max
  P          SQ asym  scalar max (softmax output, >= 0)
  X_attn     FWQ      per-feature abs-max [d]
  X_o        FWQ      per-feature abs-max [d]
  GELU out   FWQ      per-feature abs-max [ffn]
  X_2        FWQ      per-feature abs-max [d]
  =========  =======  ========================================

The AOT artifact built from this function returns one stat bundle per
batch; the rust calibrator aggregates across batches (running max, or the
per-batch history for percentile clipping — Discussion (b)).
"""

import jax.numpy as jnp

from ..config import ModelConfig
from .bert import bert_forward

# Order of the stat outputs in the AOT artifact — mirrored in the rust
# calibrator and in manifest.json.
STAT_NAMES = ("q_absmax", "k_absmax", "v_absmax", "p_max",
              "attn_absmax", "o_absmax", "gelu_absmax", "x2_absmax")


def stat_shapes(cfg: ModelConfig):
    L, d, f = cfg.layers, cfg.hidden, cfg.ffn
    return {
        "q_absmax": (L,), "k_absmax": (L,), "v_absmax": (L,), "p_max": (L,),
        "attn_absmax": (L, d), "o_absmax": (L, d),
        "gelu_absmax": (L, f), "x2_absmax": (L, d),
    }


def calibration_forward(params, cfg: ModelConfig, input_ids, type_ids, attn_mask):
    """Returns (logits, stats-dict).  All stats are pad-masked maxima."""
    b, s = input_ids.shape
    h = cfg.heads
    tok_mask = attn_mask.reshape(b * s, 1)           # [n,1], 1 = real token
    qrow_mask = jnp.repeat(attn_mask, h, axis=0)     # [b*h, s] query rows

    taps = {k: [None] * cfg.layers for k in STAT_NAMES}

    def collect(i, name, t):
        if name in ("q", "k", "v"):
            taps[name + "_absmax"][i] = jnp.max(jnp.abs(t) * tok_mask)
        elif name == "p":
            # probs [b*h, s, s]; zero out pad query rows before the max
            taps["p_max"][i] = jnp.max(t * qrow_mask[:, :, None])
        elif name == "attn":
            taps["attn_absmax"][i] = jnp.max(jnp.abs(t) * tok_mask, axis=0)
        elif name == "o":
            taps["o_absmax"][i] = jnp.max(jnp.abs(t) * tok_mask, axis=0)
        elif name == "gelu":
            taps["gelu_absmax"][i] = jnp.max(jnp.abs(t) * tok_mask, axis=0)
        elif name == "x2":
            taps["x2_absmax"][i] = jnp.max(jnp.abs(t) * tok_mask, axis=0)
        else:  # pragma: no cover
            raise KeyError(name)

    logits = bert_forward(params, cfg, input_ids, type_ids, attn_mask,
                          collect=collect)
    stats = {k: jnp.stack(v) for k, v in taps.items()}
    return logits, stats
