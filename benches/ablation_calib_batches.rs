//! Discussion ablation (a): calibration batch count.  The paper reports
//! that reducing CoLA's calibration from 100 to 5 batches recovers ~1%
//! Mcc at M3 (fewer batches -> smaller observed maxima -> tighter scales).
//!
//! Env: ZQH_TASK (default cola), ZQH_MODE (default m3).

use zqhero::bench::Table;
use zqhero::calib::truncate_history;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::Runtime;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("ablation_calib_batches: run `make artifacts` first");
        return;
    }
    let tname = std::env::var("ZQH_TASK").unwrap_or_else(|_| "cola".into());
    let mode = std::env::var("ZQH_MODE").unwrap_or_else(|_| "m3".into());
    let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
    let task = rt.manifest.task(&tname).unwrap().clone();
    let hist = eh::ensure_calibration(&mut rt, &task, 100, false).unwrap();

    println!("\nAblation (a): calibration batches on {tname} / {mode}");
    println!("(paper: CoLA-M3 gains ~1% Mcc going from 100 -> 5 batches)\n");
    let mut t = Table::new(&["calib batches", "metrics"]);
    let mut results = Vec::new();
    for batches in [1usize, 5, 20, 50, 100] {
        let h = truncate_history(&hist, batches);
        let ckpt = eh::quantize_task(&mut rt, &task, &mode, &h, 100.0,
                                     Some(&format!("ab{batches}")))
            .unwrap();
        rt.upload_checkpoint(&task.name, &mode, &ckpt).unwrap();
        let mut vals = std::collections::BTreeMap::new();
        for split in task.splits.keys().filter(|s| *s != "train") {
            for (k, v) in eh::eval_split(&mut rt, &task, &mode, split).unwrap() {
                vals.insert(if split == "dev" { k } else { format!("{k}_mm") }, v);
            }
        }
        let pretty: Vec<String> =
            vals.iter().map(|(k, v)| format!("{k}={:.2}", v * 100.0)).collect();
        results.push((batches, vals));
        t.row(vec![batches.to_string(), pretty.join("  ")]);
    }
    t.print();

    let first = |i: usize| *results[i].1.values().next().unwrap();
    let (m5, m100) = (first(1), first(4));
    println!("\n5-batch vs 100-batch delta: {:+.2} pts", (m5 - m100) * 100.0);
}
