//! Modeled `spawn`/`join` — the `std::thread` sliver the serving spine
//! uses, scheduled by the heromck controller when a model run is active.
//!
//! Model threads are real OS threads (named `mck-t{tid}` so the quiet
//! panic hook can recognize them), but they only ever *execute* while
//! holding the controller baton; registration happens at the parent's
//! `spawn` schedule point, so thread ids — and therefore decision
//! traces — are deterministic.  Plain code between schedule points may
//! overlap with a freshly spawned child that has not yet reached its
//! first modeled operation; model tests must only share state through
//! modeled primitives, which makes that overlap unobservable.

use std::io;
use std::sync::{Arc, Mutex as StdMutex};
use std::time::Duration;

use super::sched::{BlockReason, Controller, MckAbort, Status, Step};
use super::{current, set_current, RunHandle};

pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

type Slot<T> = Arc<StdMutex<Option<std::thread::Result<T>>>>;

enum Imp<T> {
    Real(std::thread::JoinHandle<T>),
    Model {
        child: usize,
        os: Option<std::thread::JoinHandle<()>>,
        slot: Slot<T>,
    },
}

pub struct JoinHandle<T>(Imp<T>);

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        match self.0 {
            Imp::Real(h) => h.join(),
            Imp::Model { child, os, slot } => {
                if let Some(h) = current() {
                    h.ctl.op(h.tid, "thread.join", |inner, _| {
                        if inner.threads[child].status == Status::Finished {
                            // join edge: the child's final clock
                            // happens-before everything after the join
                            let c = inner.model.clocks[child].clone();
                            inner.model.clocks[h.tid].join(&c);
                            Step::Done(())
                        } else {
                            Step::Block(BlockReason::Join(child))
                        }
                    });
                }
                if let Some(os) = os {
                    let _ = os.join();
                }
                let mut g = match slot.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                g.take().expect("joined model thread left a result")
            }
        }
    }

    pub fn is_finished(&self) -> bool {
        match &self.0 {
            Imp::Real(h) => h.is_finished(),
            Imp::Model { os, .. } => os.as_ref().map(|h| h.is_finished()).unwrap_or(true),
        }
    }
}

#[derive(Default)]
pub struct Builder {
    name: Option<String>,
}

impl Builder {
    pub fn new() -> Builder {
        Builder { name: None }
    }

    pub fn name(mut self, name: String) -> Builder {
        self.name = Some(name);
        self
    }

    pub fn spawn<F, T>(self, f: F) -> io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        if let Some(h) = current() {
            let parent = h.tid;
            let child = h.ctl.op(parent, "thread.spawn", |inner, _| {
                Step::Done(Controller::register_thread(inner, Some(parent)))
            });
            let slot: Slot<T> = Arc::new(StdMutex::new(None));
            let ctl = h.ctl.clone();
            let body_slot = slot.clone();
            let os = std::thread::Builder::new()
                .name(format!("mck-t{child}"))
                .spawn(move || {
                    set_current(Some(RunHandle { ctl: ctl.clone(), tid: child }));
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    let panic_msg = match &result {
                        Ok(_) => None,
                        Err(p) if p.is::<MckAbort>() => None,
                        Err(p) => Some(panic_message(p.as_ref())),
                    };
                    if !result.as_ref().err().map(|p| p.is::<MckAbort>()).unwrap_or(false) {
                        let mut g = match body_slot.lock() {
                            Ok(g) => g,
                            Err(p) => p.into_inner(),
                        };
                        *g = Some(result);
                    }
                    set_current(None);
                    ctl.thread_finished(child, panic_msg);
                })?;
            Ok(JoinHandle(Imp::Model { child, os: Some(os), slot }))
        } else {
            let mut b = std::thread::Builder::new();
            if let Some(n) = self.name {
                b = b.name(n);
            }
            Ok(JoinHandle(Imp::Real(b.spawn(f)?)))
        }
    }
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    Builder::new().spawn(f).expect("failed to spawn thread")
}

/// In a model run sleeping is just a schedule point — model time does
/// not advance, but every interleaving a real sleep could allow is still
/// reachable through the decision it introduces.
pub fn sleep(dur: Duration) {
    if let Some(h) = current() {
        h.ctl.op(h.tid, "thread.sleep", |_, _| Step::Done(()));
    } else {
        std::thread::sleep(dur);
    }
}

/// Same treatment as [`sleep`]: a pure schedule point in a model run.
pub fn yield_now() {
    if let Some(h) = current() {
        h.ctl.op(h.tid, "thread.yield", |_, _| Step::Done(()));
    } else {
        std::thread::yield_now();
    }
}
