//! Discussion ablation (b): min/max truncation of calibration scales.
//! The paper notes a tuned clipping threshold can boost accuracy; this
//! bench sweeps the percentile clip applied to the per-batch stat history.
//!
//! Env: ZQH_TASK (default cola), ZQH_MODE (default m3).

use zqhero::bench::Table;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::Runtime;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("ablation_clipping: run `make artifacts` first");
        return;
    }
    let tname = std::env::var("ZQH_TASK").unwrap_or_else(|_| "cola".into());
    let mode = std::env::var("ZQH_MODE").unwrap_or_else(|_| "m3".into());
    let mut rt = Runtime::new(Manifest::load(&dir).unwrap()).unwrap();
    let task = rt.manifest.task(&tname).unwrap().clone();
    let hist = eh::ensure_calibration(&mut rt, &task, 100, false).unwrap();

    println!("\nAblation (b): scale clipping percentile on {tname} / {mode}\n");
    let mut t = Table::new(&["clip pct", "metrics"]);
    for pct in [100.0f64, 99.99, 99.9, 99.0, 95.0, 90.0] {
        let ckpt = eh::quantize_task(&mut rt, &task, &mode, &hist, pct,
                                     Some(&format!("clip{pct}")))
            .unwrap();
        rt.upload_checkpoint(&task.name, &mode, &ckpt).unwrap();
        let mut vals = std::collections::BTreeMap::new();
        for split in task.splits.keys().filter(|s| *s != "train") {
            for (k, v) in eh::eval_split(&mut rt, &task, &mode, split).unwrap() {
                vals.insert(if split == "dev" { k } else { format!("{k}_mm") }, v);
            }
        }
        let pretty: Vec<String> =
            vals.iter().map(|(k, v)| format!("{k}={:.2}", v * 100.0)).collect();
        t.row(vec![format!("{pct}"), pretty.join("  ")]);
    }
    t.print();
    println!("\n(pct=100 is the paper's untuned running-max calibration)");
}
