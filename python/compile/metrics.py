"""GLUE metrics (python side — used for training monitoring and as the
oracle for the rust implementations in ``rust/src/metrics/``)."""

import numpy as np


def accuracy(preds, labels):
    preds, labels = np.asarray(preds), np.asarray(labels)
    return float((preds == labels).mean())


def f1_binary(preds, labels):
    preds, labels = np.asarray(preds), np.asarray(labels)
    tp = float(((preds == 1) & (labels == 1)).sum())
    fp = float(((preds == 1) & (labels == 0)).sum())
    fn = float(((preds == 0) & (labels == 1)).sum())
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom > 0 else 0.0


def matthews_corrcoef(preds, labels):
    preds, labels = np.asarray(preds), np.asarray(labels)
    tp = float(((preds == 1) & (labels == 1)).sum())
    tn = float(((preds == 0) & (labels == 0)).sum())
    fp = float(((preds == 1) & (labels == 0)).sum())
    fn = float(((preds == 0) & (labels == 1)).sum())
    denom = np.sqrt((tp + fp) * (tp + fn) * (tn + fp) * (tn + fn))
    return float((tp * tn - fp * fn) / denom) if denom > 0 else 0.0


def pearson(x, y):
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    xc, yc = x - x.mean(), y - y.mean()
    denom = np.sqrt((xc**2).sum() * (yc**2).sum())
    return float((xc * yc).sum() / denom) if denom > 0 else 0.0


def _ranks(x):
    """Average ranks (ties get the mean of their rank range)."""
    x = np.asarray(x, np.float64)
    order = np.argsort(x, kind="stable")
    ranks = np.empty_like(x)
    i = 0
    sorted_x = x[order]
    while i < len(x):
        j = i
        while j + 1 < len(x) and sorted_x[j + 1] == sorted_x[i]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    return ranks


def spearman(x, y):
    return pearson(_ranks(x), _ranks(y))


def compute_metric(name, preds_or_scores, labels):
    if name == "acc":
        return accuracy(preds_or_scores, labels)
    if name == "f1":
        return f1_binary(preds_or_scores, labels)
    if name == "mcc":
        return matthews_corrcoef(preds_or_scores, labels)
    if name == "pearson":
        return pearson(preds_or_scores, labels)
    if name == "spearman":
        return spearman(preds_or_scores, labels)
    raise KeyError(name)
