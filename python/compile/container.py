"""ZQHERO named-tensor container — the binary interchange format between the
Python build path and the rust runtime.

Layout (little-endian):
    magic    : 8 bytes  b"ZQHERO01"
    count    : u32      number of tensors
    per tensor:
        name_len : u16
        name     : utf-8 bytes
        dtype    : u8   (0 = f32, 1 = i8, 2 = i32)
        ndim     : u8
        dims     : u32 * ndim
        nbytes   : u64
        data     : raw bytes (C order)

The rust reader/writer lives in ``rust/src/model/container.rs``; round-trip
parity is covered by golden-file tests on both sides.
"""

import struct
from collections import OrderedDict

import numpy as np

MAGIC = b"ZQHERO01"

_DTYPES = {0: np.float32, 1: np.int8, 2: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}


def write_container(path, tensors):
    """tensors: ordered mapping name -> np.ndarray (f32/i8/i32)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _CODES.get(arr.dtype)
            if code is None:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_container(path):
    """Returns OrderedDict name -> np.ndarray."""
    out = OrderedDict()
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            data = f.read(nbytes)
            arr = np.frombuffer(data, dtype=_DTYPES[code]).reshape(dims).copy()
            out[name] = arr
    return out
