//! Property tests over the pure substrates (no artifacts needed):
//! quantization invariants, folding algebra, JSON round-trips, metric
//! bounds, histogram consistency.

use zqhero::json::{self, Value};
use zqhero::metrics;
use zqhero::prop::{forall, Rng};
use zqhero::quant::fold::{fold_fwq_in_fwq_out, fold_sq_output};
use zqhero::quant::schemes::{percentile, quantize_weight_colwise, sym_quantize_one};

#[test]
fn prop_weight_quant_roundtrip_bound() {
    forall("weight-quant-roundtrip", 100, |r: &mut Rng| {
        let k = 1 + r.below(24);
        let m = 1 + r.below(24);
        let scale = r.log_uniform(1e-2, 10.0) as f32;
        let w = r.vec_f32(k * m, -scale, scale);
        let (q, s) = quantize_weight_colwise(&w, k, m);
        for row in 0..k {
            for col in 0..m {
                let recon = q[row * m + col] as f32 * s[col];
                let err = (recon - w[row * m + col]).abs();
                assert!(
                    err <= s[col] / 2.0 + 1e-6,
                    "err {err} > step/2 {} at ({row},{col})",
                    s[col] / 2.0
                );
            }
        }
        // int8 range respected
        assert!(q.iter().all(|v| (-127..=127).contains(&(*v as i32))));
    });
}

#[test]
fn prop_sym_quantize_monotone() {
    forall("sym-quant-monotone", 100, |r: &mut Rng| {
        let scale = r.log_uniform(1e-3, 1.0);
        let a = r.uniform(-100.0, 100.0) as f32;
        let b = r.uniform(-100.0, 100.0) as f32;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(sym_quantize_one(lo, scale) <= sym_quantize_one(hi, scale));
    });
}

#[test]
fn prop_fold_algebra_exact() {
    // fold then unfold reproduces the GeMM semantics in exact f32 algebra
    forall("fold-algebra", 100, |r: &mut Rng| {
        let k = 1 + r.below(12);
        let m = 1 + r.below(12);
        let w = r.vec_f32(k * m, -2.0, 2.0);
        let b = r.vec_f32(m, -1.0, 1.0);
        let s_in: Vec<f32> = (0..k).map(|_| r.log_uniform(1e-3, 1e-1) as f32).collect();
        let s_out: Vec<f32> = (0..m).map(|_| r.log_uniform(1e-3, 1e-1) as f32).collect();
        let (wt, bt) = fold_fwq_in_fwq_out(&w, &b, &s_in, &s_out, k, m);
        for row in 0..k {
            for col in 0..m {
                let expect = (s_in[row] * w[row * m + col]) / s_out[col];
                assert_eq!(wt[row * m + col].to_bits(), expect.to_bits());
            }
        }
        for col in 0..m {
            assert_eq!(bt[col].to_bits(), (b[col] / s_out[col]).to_bits());
        }
        // scalar fold is the 1-D special case
        let (ws, bs) = fold_sq_output(&w, &b, s_out[0] as f64);
        assert_eq!(ws[0].to_bits(), (w[0] / s_out[0]).to_bits());
        assert_eq!(bs[0].to_bits(), (b[0] / s_out[0]).to_bits());
    });
}

#[test]
fn prop_percentile_bounds_and_max() {
    forall("percentile", 100, |r: &mut Rng| {
        let n = 1 + r.below(50);
        let v: Vec<f64> = (0..n).map(|_| r.uniform(-10.0, 10.0)).collect();
        let pct = r.uniform(0.0, 100.0);
        let p = percentile(&v, pct);
        let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(p >= lo - 1e-12 && p <= hi + 1e-12);
        assert_eq!(percentile(&v, 100.0), hi);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(r: &mut Rng, depth: usize) -> Value {
        match if depth > 3 { r.below(4) } else { r.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(r.bool()),
            2 => Value::Number((r.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = r.below(12);
                Value::String(
                    (0..n)
                        .map(|_| {
                            let opts = ['a', 'Z', '0', ' ', '"', '\\', '\n', 'é', '→'];
                            *r.choice(&opts)
                        })
                        .collect(),
                )
            }
            4 => Value::Array((0..r.below(5)).map(|_| gen_value(r, depth + 1)).collect()),
            _ => Value::Object(
                (0..r.below(5))
                    .map(|i| (format!("k{i}"), gen_value(r, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall("json-roundtrip", 200, |r: &mut Rng| {
        let v = gen_value(r, 0);
        let s = json::to_string(&v);
        let back = json::parse(&s).unwrap_or_else(|e| panic!("parse {s:?}: {e}"));
        assert_eq!(back, v, "roundtrip failed for {s}");
        // pretty form parses to the same value
        let back2 = json::parse(&json::to_string_pretty(&v)).unwrap();
        assert_eq!(back2, v);
    });
}

#[test]
fn prop_metric_ranges() {
    forall("metric-ranges", 100, |r: &mut Rng| {
        let n = 2 + r.below(100);
        let preds = r.vec_i32(n, 0, 1);
        let labels = r.vec_i32(n, 0, 1);
        let acc = metrics::accuracy(&preds, &labels);
        assert!((0.0..=1.0).contains(&acc));
        let f1 = metrics::f1_binary(&preds, &labels);
        assert!((0.0..=1.0).contains(&f1));
        let mcc = metrics::matthews_corrcoef(&preds, &labels);
        assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&mcc));
        let x: Vec<f64> = (0..n).map(|_| r.uniform(-5.0, 5.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| r.uniform(-5.0, 5.0)).collect();
        for v in [metrics::pearson(&x, &y), metrics::spearman(&x, &y)] {
            assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&v));
        }
        // self-correlation is exactly 1 when variance > 0
        if x.iter().any(|a| (a - x[0]).abs() > 1e-9) {
            assert!((metrics::pearson(&x, &x) - 1.0).abs() < 1e-12);
            assert!((metrics::spearman(&x, &x) - 1.0).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_container_roundtrip() {
    use zqhero::model::{Container, Tensor};
    forall("container-roundtrip", 60, |r: &mut Rng| {
        let mut c = Container::new();
        let n_tensors = 1 + r.below(6);
        for i in 0..n_tensors {
            let ndim = 1 + r.below(3);
            let shape: Vec<usize> = (0..ndim).map(|_| 1 + r.below(8)).collect();
            let numel: usize = shape.iter().product();
            let t = match r.below(3) {
                0 => Tensor::f32(shape, r.vec_f32(numel, -10.0, 10.0)),
                1 => Tensor::i8(shape, r.vec_i8(numel)),
                _ => Tensor::i32(shape, r.vec_i32(numel, -1000, 1000)),
            };
            c.push(&format!("tensor.{i}"), t);
        }
        let bytes = c.write_bytes();
        let back = Container::read_bytes(&bytes).unwrap();
        assert_eq!(back.len(), c.len());
        for ((an, at), (bn, bt)) in c.entries.iter().zip(&back.entries) {
            assert_eq!(an, bn);
            assert_eq!(at, bt);
        }
    });
}

#[test]
fn prop_histogram_percentile_monotone() {
    use zqhero::coordinator::Histogram;
    forall("histogram", 60, |r: &mut Rng| {
        let mut h = Histogram::new();
        let n = 1 + r.below(500);
        for _ in 0..n {
            h.record(r.range_i64(1, 10_000_000) as u64);
        }
        assert_eq!(h.count(), n as u64);
        let p50 = h.percentile_us(0.5);
        let p95 = h.percentile_us(0.95);
        let p99 = h.percentile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(h.min_us() <= h.max_us());
    });
}

#[test]
fn prop_lexer_fuzz_never_panics_and_lines_roundtrip() {
    use zqhero::lint::lexer::lex;
    // random soup of the constructs herolint's lexer special-cases: raw
    // strings with arbitrary # fence counts, byte strings, nested block
    // comments, lifetime-vs-char-literal quotes — plus unique sentinel
    // idents whose reported line must equal 1 + the '\n' count before
    // them in the source.  Then truncate at a random char boundary
    // (mid-raw-string, mid-comment) and demand the lexer still returns.
    forall("lexer-fuzz", 120, |r: &mut Rng| {
        let mut src = String::new();
        let mut sentinels: Vec<String> = Vec::new();
        let n_frags = 1 + r.below(12);
        for k in 0..n_frags {
            match r.below(8) {
                0 => {
                    // raw string; body may hold quotes closed by fewer #s
                    let f = r.below(4);
                    let h = "#".repeat(f);
                    let body = if f == 0 {
                        "plain raw body → no quotes".to_string()
                    } else {
                        format!("a \" b \"{} c\nd", "#".repeat(f - 1))
                    };
                    src.push_str(&format!("let s = r{h}\"{body}\"{h};\n"));
                }
                1 => src.push_str("let b = b\"bytes \\x41 \\\" esc\";\n"),
                2 => src.push_str("/* outer /* inner\n level */ still outer */ x();\n"),
                3 => src.push_str("fn f<'a>(x: &'a str) -> &'static str { x }\n"),
                4 => src.push_str("let c = '\\''; let d = 'x'; let e = '\\n';\n"),
                5 => src.push_str("// plain note — not an annotation\n"),
                6 => src.push_str("let q = m.lock().unwrap();\n"),
                _ => src.push('\n'),
            }
            if r.bool() {
                let name = format!("zqsent{k}");
                src.push_str(&format!("\n{name}\n"));
                sentinels.push(name);
            }
        }

        // exact line round-trip on the well-formed source
        let lexed = lex(&src);
        for name in &sentinels {
            let pos = src.find(name.as_str()).expect("sentinel is in the source");
            let want = 1 + src[..pos].matches('\n').count() as u32;
            let got = lexed
                .tokens
                .iter()
                .find(|t| t.ident() == Some(name.as_str()))
                .unwrap_or_else(|| panic!("sentinel {name} lost by the lexer"));
            assert_eq!(got.line, want, "line drifted for {name} in:\n{src}");
        }
        let total_lines = 1 + src.matches('\n').count() as u32;
        let mut prev = 1u32;
        for t in &lexed.tokens {
            assert!(t.line >= prev && t.line <= total_lines, "non-monotone line");
            prev = t.line;
        }

        // truncation at an arbitrary char boundary must never panic and
        // must keep the same line invariants on whatever tokens survive
        let chars: Vec<char> = src.chars().collect();
        let cut: String = chars[..r.below(chars.len() + 1)].iter().collect();
        let lexed = lex(&cut);
        let total_lines = 1 + cut.matches('\n').count() as u32;
        let mut prev = 1u32;
        for t in &lexed.tokens {
            assert!(t.line >= prev && t.line <= total_lines, "non-monotone line after cut");
            prev = t.line;
        }
    });
}
