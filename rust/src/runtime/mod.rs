//! PJRT runtime: loads the AOT HLO-text artifacts, keeps weights
//! device-resident, and executes inference/calibration on the hot path —
//! no Python anywhere.
//!
//! `Runtime` is intentionally single-threaded (`PjRtClient` is `Rc`-based):
//! CLI commands use it directly on the main thread; the serving coordinator
//! wraps it in dedicated engine threads (`engine.rs`) and talks to them over
//! channels, the same shape as GPU-executor threads in a production server.
//! Since the handles are not `Send`, scaling out means *replicating* the
//! runtime: `engine::EnginePool` spawns N engine threads, each owning its
//! own `Runtime` (checkpoints + executables), behind a load-aware
//! dispatcher with per-group FIFO pinning (DESIGN.md §5.7).
//!
//! Executables and checkpoints live in maps keyed by `(version, mode,
//! seq_bucket, batch_bucket)` / `(version, task, mode)`: residency
//! (DESIGN.md §5.13) loads and evicts individual grid cells on demand,
//! and hot manifest reload keeps several versions' tables side by side
//! while old in-flight work drains.  Lookup (`exe_at`, `execute_model_at`)
//! borrows `&self` so the hot path never takes a mutable borrow; the
//! compile step (`load_exe`) is split out so the engine can run it off
//! the dispatch-critical section.  The string-keyed methods remain as
//! cold-path wrappers that resolve names to `TaskId`/`ModeId` once and
//! pin everything at version 0 (the CLI single-manifest world).

pub mod engine;
pub mod residency;
pub mod staging;

pub use engine::{
    DispatchState, Engine, EngineOptions, EnginePool, FaultKind, FaultPlan, FaultSpec, PoolEvent,
    PoolEventHook, ReplicaFailed, RestartPolicy,
};
pub use residency::{Begin, CellKey, Residency, ResidencyCounters};

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::manifest::{Manifest, ModeId, TaskId};
use crate::model::tensor::{DType, Tensor};
use crate::model::Container;

/// Host copy of an executable's output tuple.
pub struct Outputs {
    pub tensors: Vec<Tensor>,
}

/// A compiled artifact plus load/compile timings (reported by `repro info`).
pub struct Exe {
    pub exe: xla::PjRtLoadedExecutable,
    pub path: String,
    pub load_ms: f64,
    pub compile_ms: f64,
}

/// Device-resident checkpoint: one buffer per parameter, in manifest order.
pub struct DeviceCheckpoint {
    pub bufs: Vec<xla::PjRtBuffer>,
    pub nbytes: usize,
}

/// Device-resident input buffers for one batch (stage 1 of the pipeline).
pub struct InputBufs {
    pub seq: usize,
    pub bucket: usize,
    ids: xla::PjRtBuffer,
    type_ids: xla::PjRtBuffer,
    mask: xla::PjRtBuffer,
}

/// In-flight execution: device output buffers that have been launched but
/// not read back (stage 2 of the pipeline).  Holding one of these while
/// uploading/launching the next batch is what overlaps the stages.
pub struct PendingOutputs {
    results: Vec<Vec<xla::PjRtBuffer>>,
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    /// Version-0 manifest (CLI paths and legacy wrappers); versioned
    /// callers pass their own manifest into `load_exe`.
    pub manifest: Manifest,
    /// `(version, mode, seq_bucket, batch_bucket)` -> compiled model
    /// executable — the residency-managed grid (DESIGN.md §5.13): cells
    /// are inserted by `insert_exe` after a demand load and removed by
    /// `remove_exe` on eviction, so the map holds only resident cells.
    exes: HashMap<(u32, u16, usize, usize), Exe>,
    /// misc executables (calibration artifact, micro benches) by path.
    raw_exes: HashMap<String, Exe>,
    /// `(version, task, mode)` -> device-resident weights.
    ckpts: HashMap<(u32, u16, u16), DeviceCheckpoint>,
}

#[allow(dead_code)]
fn elem_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I8 => xla::ElementType::S8,
        DType::I32 => xla::ElementType::S32,
    }
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: HashMap::new(),
            raw_exes: HashMap::new(),
            ckpts: HashMap::new(),
        })
    }

    // ---------------------------------------------------------------- load

    pub fn compile_hlo_file(client: &xla::PjRtClient, path: &Path) -> Result<Exe> {
        let t0 = Instant::now();
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow::anyhow!("loading {path:?}: {e}"))?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t1 = Instant::now();
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e}"))?;
        Ok(Exe {
            exe,
            path: path.display().to_string(),
            load_ms,
            compile_ms: t1.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Resident-cell lookup: `&self`, no compile — the residency-managed
    /// hot path.  `None` means the cell is cold (evicted or never
    /// loaded); the caller goes through `Residency::begin` + `load_exe`.
    pub fn exe_at(&self, version: u32, mode: ModeId, seq: usize, bucket: usize) -> Option<&Exe> {
        self.exes.get(&(version, mode.0, seq, bucket))
    }

    /// Compile one grid cell from `man`'s artifact table without
    /// inserting it — `&self`, so the load can run while the executable
    /// table is borrowed elsewhere.  Returns the executable plus the
    /// artifact's on-disk size (the residency byte ledger's input).
    pub fn load_exe(
        &self,
        man: &Manifest,
        mode: ModeId,
        seq: usize,
        bucket: usize,
    ) -> Result<(Exe, u64)> {
        man.seq_bucket_index(seq)
            .with_context(|| format!("mode {} has no seq bucket {seq}", man.mode_name(mode)))?;
        man.bucket_index(bucket)
            .with_context(|| format!("mode {} has no bucket {bucket}", man.mode_name(mode)))?;
        let spec = man.mode_by_id(mode);
        let rel = spec.artifacts.get(&(seq, bucket)).with_context(|| {
            format!(
                "mode {} has no artifact for (seq {seq}, bucket {bucket})",
                man.mode_name(mode)
            )
        })?;
        let path = man.path(rel);
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let exe = Self::compile_hlo_file(&self.client, &path)?;
        Ok((exe, bytes))
    }

    /// Make a loaded cell resident.
    pub fn insert_exe(&mut self, version: u32, mode: ModeId, seq: usize, bucket: usize, exe: Exe) {
        self.exes.insert((version, mode.0, seq, bucket), exe);
    }

    /// Evict a cell (residency LRU): drops the device-side executable.
    pub fn remove_exe(
        &mut self,
        version: u32,
        mode: ModeId,
        seq: usize,
        bucket: usize,
    ) -> Option<Exe> {
        self.exes.remove(&(version, mode.0, seq, bucket))
    }

    /// Compile (and cache) the model executable for (mode, seq, bucket).
    pub fn model_exe(&mut self, mode: &str, seq: usize, bucket: usize) -> Result<&Exe> {
        let mode = self.manifest.mode_id(mode)?;
        self.model_exe_id(mode, seq, bucket)
    }

    /// Legacy compile-inline variant (CLI / calibration paths, version
    /// 0): lookup, compiling on miss.  Serving goes through
    /// `exe_at`/`load_exe` instead so misses never hold `&mut self`.
    pub fn model_exe_id(&mut self, mode: ModeId, seq: usize, bucket: usize) -> Result<&Exe> {
        if !self.exes.contains_key(&(0, mode.0, seq, bucket)) {
            let (exe, _bytes) = {
                let man = &self.manifest;
                self.load_exe(man, mode, seq, bucket)?
            };
            self.exes.insert((0, mode.0, seq, bucket), exe);
        }
        // panic-ok: the miss arm directly above just filled this slot
        Ok(self.exes.get(&(0, mode.0, seq, bucket)).expect("just compiled"))
    }

    /// Compile (and cache) an arbitrary artifact by manifest-relative path.
    pub fn raw_exe(&mut self, rel: &str) -> Result<&Exe> {
        if !self.raw_exes.contains_key(rel) {
            let exe = Self::compile_hlo_file(&self.client, &self.manifest.path(rel))?;
            self.raw_exes.insert(rel.to_string(), exe);
        }
        Ok(&self.raw_exes[rel])
    }

    // ------------------------------------------------------------- weights

    pub fn upload_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        // NOTE: the typed `buffer_from_host_buffer::<T>` is used on purpose:
        // the crate's `buffer_from_host_raw_bytes` forwards the rust
        // `ElementType` discriminant straight to the C API, which is offset
        // from XLA's `PrimitiveType` (F32 silently becomes F16).  The typed
        // path converts via `T::TY.primitive_type()` and is correct.
        let buf = match &t.data {
            crate::model::TensorData::F32(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
            crate::model::TensorData::I8(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
            crate::model::TensorData::I32(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
        };
        buf.map_err(|e| anyhow::anyhow!("upload: {e}"))
    }

    /// Upload a checkpoint once; later executions reference the resident
    /// buffers (the per-request upload is only ids+mask — DESIGN.md §5.1).
    pub fn upload_checkpoint(&mut self, task: &str, mode: &str, ckpt: &Container) -> Result<()> {
        let task = self.manifest.task_id(task)?;
        let mode = self.manifest.mode_id(mode)?;
        self.upload_checkpoint_id(task, mode, ckpt)
    }

    pub fn upload_checkpoint_id(
        &mut self,
        task: TaskId,
        mode: ModeId,
        ckpt: &Container,
    ) -> Result<()> {
        self.upload_checkpoint_v(0, task, mode, ckpt)
    }

    /// Versioned checkpoint upload (manifest reload keeps the latest two
    /// versions' weights resident while the old one drains).
    pub fn upload_checkpoint_v(
        &mut self,
        version: u32,
        task: TaskId,
        mode: ModeId,
        ckpt: &Container,
    ) -> Result<()> {
        let mut bufs = Vec::with_capacity(ckpt.len());
        let mut nbytes = 0;
        for (_, t) in &ckpt.entries {
            bufs.push(self.upload_tensor(t)?);
            nbytes += t.nbytes();
        }
        self.ckpts.insert((version, task.0, mode.0), DeviceCheckpoint { bufs, nbytes });
        Ok(())
    }

    /// Drop checkpoints of versions older than `keep_min` — the reload
    /// drain's terminal step.  Executables are not touched here: their
    /// removal goes through the residency table (`remove_exe` per
    /// evicted cell) so metadata and device state cannot disagree.
    pub fn drop_version_ckpts(&mut self, keep_min: u32) {
        self.ckpts.retain(|(v, _, _), _| *v >= keep_min);
    }

    pub fn has_checkpoint(&self, task: &str, mode: &str) -> bool {
        match (self.manifest.task_id(task), self.manifest.mode_id(mode)) {
            (Ok(t), Ok(m)) => self.ckpts.contains_key(&(0, t.0, m.0)),
            _ => false,
        }
    }

    pub fn checkpoint_nbytes(&self, task: &str, mode: &str) -> Option<usize> {
        let t = self.manifest.task_id(task).ok()?;
        let m = self.manifest.mode_id(mode).ok()?;
        self.ckpts.get(&(0, t.0, m.0)).map(|c| c.nbytes)
    }

    // ------------------------------------------------------------- execute

    fn read_outputs(results: Vec<Vec<xla::PjRtBuffer>>) -> Result<Outputs> {
        let buf = &results
            .first()
            .context("no replica outputs")?
            .first()
            .context("no outputs")?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        // artifacts are lowered with return_tuple=True
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p.array_shape().map_err(|e| anyhow::anyhow!("shape: {e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
            let t = match shape.ty() {
                xla::ElementType::F32 => {
                    Tensor::f32(dims, p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                xla::ElementType::S8 => {
                    Tensor::i8(dims, p.to_vec::<i8>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                xla::ElementType::S32 => {
                    Tensor::i32(dims, p.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                other => bail!("unsupported output element type {other:?}"),
            };
            tensors.push(t);
        }
        Ok(Outputs { tensors })
    }

    // ---- pipelined hot path (engine thread): upload | execute | readback

    /// Stage 1: copy one batch's host arrays into fresh device buffers.
    /// `seq` is the batch's seq bucket — short batches upload (and later
    /// execute) `bucket * seq_bucket` tokens, not `bucket * max_seq`.
    /// Only `&self` — it can run while a previous batch's outputs are
    /// still pending on the device.
    pub fn upload_inputs(
        &self,
        seq: usize,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<InputBufs> {
        if ids.len() != bucket * seq {
            bail!("ids len {} != bucket {bucket} * seq {seq}", ids.len());
        }
        if type_ids.len() != bucket * seq || mask.len() != bucket * seq {
            bail!("type_ids/mask length mismatch for bucket {bucket} * seq {seq}");
        }
        let up = |e: xla::Error| anyhow::anyhow!("{e}");
        Ok(InputBufs {
            seq,
            bucket,
            ids: self.client.buffer_from_host_buffer(ids, &[bucket, seq], None).map_err(up)?,
            type_ids: self
                .client
                .buffer_from_host_buffer(type_ids, &[bucket, seq], None)
                .map_err(up)?,
            mask: self.client.buffer_from_host_buffer(mask, &[bucket, seq], None).map_err(up)?,
        })
    }

    /// Stage 2: launch the executable against resident weights + uploaded
    /// inputs.  Returns without waiting for a host copy; the caller holds
    /// the `PendingOutputs` while staging the next batch.  Legacy
    /// compile-inline wrapper (CLI, version 0).
    pub fn execute_model(
        &mut self,
        task: TaskId,
        mode: ModeId,
        inputs: &InputBufs,
    ) -> Result<PendingOutputs> {
        self.model_exe_id(mode, inputs.seq, inputs.bucket)?;
        self.execute_model_at(0, task, mode, inputs)
    }

    /// Residency-managed stage 2: `&self`, never compiles.  Errors name
    /// the missing cell — absence means residency bookkeeping and the
    /// device table disagree (or the version was dropped mid-drain),
    /// which must surface as a typed per-request failure, not a panic.
    pub fn execute_model_at(
        &self,
        version: u32,
        task: TaskId,
        mode: ModeId,
        inputs: &InputBufs,
    ) -> Result<PendingOutputs> {
        let (seq, bucket) = (inputs.seq, inputs.bucket);
        let ckpt = self.ckpts.get(&(version, task.0, mode.0)).with_context(|| {
            format!(
                "checkpoint ({},{}) not resident at version {version}",
                self.manifest.task_name(task),
                self.manifest.mode_name(mode)
            )
        })?;

        let mut args: Vec<&xla::PjRtBuffer> = ckpt.bufs.iter().collect();
        args.push(&inputs.ids);
        args.push(&inputs.type_ids);
        args.push(&inputs.mask);

        let exe = self.exes.get(&(version, mode.0, seq, bucket)).with_context(|| {
            format!(
                "executable cell (v{version}, {}, seq {seq}, bucket {bucket}) not resident",
                self.manifest.mode_name(mode)
            )
        })?;
        let results = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Ok(PendingOutputs { results })
    }

    /// Stage 3: synchronize + copy the logits back to the host.
    pub fn readback_logits(&self, pending: PendingOutputs) -> Result<Tensor> {
        let mut outputs = Self::read_outputs(pending.results)?;
        if outputs.tensors.len() != 1 {
            bail!("model artifact returned {} outputs, expected 1", outputs.tensors.len());
        }
        Ok(outputs.tensors.remove(0))
    }

    /// Run a model executable with resident weights + fresh input buffers.
    /// `ids`/`type_ids` are `[bucket * seq_bucket]` i32, `mask`
    /// `[bucket * seq_bucket]` f32 — the seq bucket is derived from the
    /// payload length (`ids.len() / bucket`) and must name a manifest seq
    /// bucket.  Cold-path convenience: resolves names, then runs the
    /// three pipeline stages back-to-back.
    pub fn infer(
        &mut self,
        task: &str,
        mode: &str,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Tensor> {
        let task = self.manifest.task_id(task)?;
        let mode = self.manifest.mode_id(mode)?;
        self.infer_ids(task, mode, bucket, ids, type_ids, mask)
    }

    pub fn infer_ids(
        &mut self,
        task: TaskId,
        mode: ModeId,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Tensor> {
        if bucket == 0 || ids.len() % bucket != 0 {
            bail!("ids len {} not a multiple of bucket {bucket}", ids.len());
        }
        let seq = ids.len() / bucket;
        self.manifest.seq_bucket_index(seq)?; // fail with the known-bucket list
        let inputs = self.upload_inputs(seq, bucket, ids, type_ids, mask)?;
        let pending = self.execute_model(task, mode, &inputs)?;
        self.readback_logits(pending)
    }

    /// Cold-path policy wrapper: resolve a precision policy (uniform mode
    /// names work too) to its executable mode, then run the three
    /// pipeline stages back-to-back.
    pub fn infer_policy(
        &mut self,
        task: &str,
        policy: &str,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Tensor> {
        let task = self.manifest.task_id(task)?;
        let exec = self.manifest.policy(policy)?.exec_mode;
        self.infer_ids(task, exec, bucket, ids, type_ids, mask)
    }

    /// Run the calibration-instrumented artifact for one batch; returns
    /// (logits, stats in manifest order).
    pub fn calibrate_batch(
        &mut self,
        fp_bufs: &[xla::PjRtBuffer],
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Outputs> {
        let seq = self.manifest.seq;
        let batch = self.manifest.calib.batch;
        if ids.len() != batch * seq {
            bail!("calibration batch must be exactly {batch} x {seq}");
        }
        let rel = self.manifest.calib.artifact.clone();
        self.raw_exe(&rel)?;

        let up = |e: xla::Error| anyhow::anyhow!("{e}");
        let ids_b = self.client.buffer_from_host_buffer(ids, &[batch, seq], None).map_err(up)?;
        let ty_b =
            self.client.buffer_from_host_buffer(type_ids, &[batch, seq], None).map_err(up)?;
        let mask_b =
            self.client.buffer_from_host_buffer(mask, &[batch, seq], None).map_err(up)?;

        let mut args: Vec<&xla::PjRtBuffer> = fp_bufs.iter().collect();
        args.push(&ids_b);
        args.push(&ty_b);
        args.push(&mask_b);

        let exe = &self.raw_exes[&rel];
        let out = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    /// Upload raw tensors (calibration fp params / micro benches).
    pub fn upload_all(&self, tensors: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        tensors.iter().map(|t| self.upload_tensor(t)).collect()
    }

    /// Execute an arbitrary artifact with host tensors (micro benches).
    pub fn run_raw(&mut self, rel: &str, inputs: &[Tensor]) -> Result<Outputs> {
        self.raw_exe(rel)?;
        let bufs = self.upload_all(inputs)?;
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let exe = &self.raw_exes[rel];
        let out = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    /// Execute an arbitrary artifact with pre-uploaded buffers (hot loop).
    pub fn run_raw_buffers(&mut self, rel: &str, args: &[&xla::PjRtBuffer]) -> Result<Outputs> {
        self.raw_exe(rel)?;
        let exe = &self.raw_exes[rel];
        let out = exe.exe.execute_b(args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    pub fn loaded_exe_count(&self) -> usize {
        self.exes.len() + self.raw_exes.len()
    }
}
