//! TCP front-end integration: JSON requests over a real socket through the
//! full serving stack.  Gated on `make artifacts`.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use zqhero::coordinator::{Coordinator, NetClient, NetServer, ServerConfig};
use zqhero::data::Split;
use zqhero::json::Value;
use zqhero::model::manifest::Manifest;

fn artifacts() -> Option<std::path::PathBuf> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("skipping net integration tests: run `make artifacts` first");
        None
    }
}

#[test]
fn tcp_round_trip_and_errors() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Arc::new(
        Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig { max_batch: 4, max_wait: Duration::from_millis(2), ..Default::default() },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();
    let mut client = NetClient::connect(&server.addr).unwrap();

    let man = Manifest::load(&dir).unwrap();
    let task = man.task("cola").unwrap();
    let split = Split::load(&man, task, "dev").unwrap();

    // several requests pipeline through the batcher
    for i in 0..6 {
        let (ids, _) = split.row(i);
        let short: Vec<i32> = ids.iter().copied().take_while(|t| *t != 0).collect();
        let resp = client.request("cola", "fp", &short).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        let logits = resp.get("logits").unwrap().as_array().unwrap();
        assert_eq!(logits.len(), man.model.num_labels);
        assert!(logits.iter().all(|v| v.as_f64().unwrap().is_finite()));
        assert!(resp.get("bucket").unwrap().as_usize().unwrap() >= 1);
    }

    // unknown task -> structured error, connection stays usable
    let resp = client.request("nope", "fp", &[1, 2, 3]).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("checkpoint"));

    // malformed json line -> error response, not a dropped connection
    {
        use std::io::{BufRead, Write};
        let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
        raw.write_all(b"this is not json\n").unwrap();
        raw.flush().unwrap();
        let mut line = String::new();
        std::io::BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
        let v = zqhero::json::parse(line.trim()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("bad json"));
    }

    // still healthy after the bad client
    let (ids, _) = split.row(0);
    let resp = client.request("cola", "fp", &ids[..10]).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert!(server.served.load(std::sync::atomic::Ordering::SeqCst) >= 8);
}

#[test]
fn oversized_request_rejected() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord =
        Arc::new(Coordinator::start(dir, &pairs, ServerConfig::default()).unwrap());
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();
    let mut client = NetClient::connect(&server.addr).unwrap();
    let huge = vec![1i32; coord.seq() + 1];
    let resp = client.request("cola", "fp", &huge).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    match resp.get("error") {
        Some(Value::String(e)) => assert!(e.contains("too many tokens"), "{e}"),
        other => panic!("expected error, got {other:?}"),
    }
}
