//! Scale folding (paper eqs. 20-23, 32) — rust mirror of the folding half
//! of `python/compile/kernels/quant_ops.py`.
//!
//! Folding is what makes the runtime hot path division-free: output scales
//! are divided *into* the weights at quantize time so every post-GeMM
//! requantization collapses to a bare `Round` (eq. 22).

/// Eq. 20-22: fold a scalar SQ output scale into W and bias.
/// NumPy computes `w_f32 / python_float` in f32 (weak-scalar promotion),
/// so we divide by the f32-cast scale.
pub fn fold_sq_output(w: &[f32], b: &[f32], s_out: f64) -> (Vec<f32>, Vec<f32>) {
    let s = s_out as f32;
    (
        w.iter().map(|x| x / s).collect(),
        b.iter().map(|x| x / s).collect(),
    )
}

/// Eq. 23 / 32: `W~ = diag(s_in) @ W @ diag(1/s_out)`, `b~ = b / s_out`.
/// `w` row-major `[k, m]`, `s_in[k]`, `s_out[m]`.
pub fn fold_fwq_in_fwq_out(
    w: &[f32],
    b: &[f32],
    s_in: &[f32],
    s_out: &[f32],
    k: usize,
    m: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(w.len(), k * m);
    assert_eq!(s_in.len(), k);
    assert_eq!(s_out.len(), m);
    assert_eq!(b.len(), m);
    let mut wt = vec![0f32; k * m];
    for row in 0..k {
        for col in 0..m {
            wt[row * m + col] = (s_in[row] * w[row * m + col]) / s_out[col];
        }
    }
    let bt = b.iter().zip(s_out).map(|(x, s)| x / s).collect();
    (wt, bt)
}

/// Mode-fallback fold: FWQ int8 activation into a high-precision GeMM —
/// only the input scale folds into the weight rows.
pub fn fold_fwq_in_f32_out(w: &[f32], s_in: &[f32], k: usize, m: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * m);
    assert_eq!(s_in.len(), k);
    let mut wt = vec![0f32; k * m];
    for row in 0..k {
        for col in 0..m {
            wt[row * m + col] = s_in[row] * w[row * m + col];
        }
    }
    wt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::schemes::quantize_weight_colwise;

    /// The folding identity the paper relies on: for any activation x_int8
    /// with FWQ scale s_in, `(x*s_in) @ W ≈ (x @ W~) * s_out` where W~ is
    /// the folded+quantized weight.  Checked against the unfolded f32 path.
    #[test]
    fn folding_preserves_gemm_semantics() {
        let k = 8;
        let m = 6;
        let w: Vec<f32> = (0..k * m).map(|i| ((i * 29 % 41) as f32 - 20.0) / 17.0).collect();
        let b: Vec<f32> = (0..m).map(|i| i as f32 * 0.1 - 0.3).collect();
        let s_in: Vec<f32> = (0..k).map(|i| 0.01 + 0.002 * i as f32).collect();
        let s_out: Vec<f32> = (0..m).map(|i| 0.05 + 0.01 * i as f32).collect();
        let x: Vec<i8> = (0..k).map(|i| (i as i8) * 13 - 50).collect();

        // reference: dequantize x, f32 GeMM, then FWQ-quantize the output
        let mut y_ref = vec![0f32; m];
        for col in 0..m {
            let mut acc = 0f32;
            for row in 0..k {
                acc += (x[row] as f32 * s_in[row]) * w[row * m + col];
            }
            y_ref[col] = acc + b[col];
        }

        // folded path: int32 GeMM with W~ then epilogue round
        let (wt, bt) = fold_fwq_in_fwq_out(&w, &b, &s_in, &s_out, k, m);
        let (wq, ws) = quantize_weight_colwise(&wt, k, m);
        for col in 0..m {
            let mut acc = 0i32;
            for row in 0..k {
                acc += x[row] as i32 * wq[row * m + col] as i32;
            }
            let y_q = (acc as f32 * ws[col] + bt[col]).round_ties_even().clamp(-127.0, 127.0);
            let y = y_q * s_out[col]; // dequantize to compare
            // error bounded by weight-quant step + output-quant step
            let tol = s_out[col] * 0.5 + 0.05;
            assert!(
                (y - y_ref[col]).abs() <= tol,
                "col {col}: folded {y} vs ref {} (tol {tol})",
                y_ref[col]
            );
        }
    }

    #[test]
    fn fold_sq_scales_bias_too() {
        let (w, b) = fold_sq_output(&[2.0, -4.0], &[1.0], 0.5);
        assert_eq!(w, vec![4.0, -8.0]);
        assert_eq!(b, vec![2.0]);
    }

    #[test]
    fn fold_fwq_in_rows() {
        let w = [1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let wt = fold_fwq_in_f32_out(&w, &[2.0, 10.0], 2, 2);
        assert_eq!(wt, vec![2.0, 4.0, 30.0, 40.0]);
    }
}
