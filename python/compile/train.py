"""Build-time trainer: fine-tunes one tiny BERT per SynGLUE task.

This stands in for the paper's off-the-shelf fine-tuned
``yoshitomo-matsubara/bert-base-uncased-*`` checkpoints (DESIGN.md §2).
Pure JAX with a hand-rolled Adam (optax is not available in this
environment).  Training runs once inside ``make artifacts``; nothing here
is on the request path.
"""

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .data import TASK_META, attn_mask
from .metrics import compute_metric
from .modeling.bert import bert_forward

TRAIN_SEQ = 48  # sentences are short; training crops to 64 for CPU speed.
                # Calibration/eval use the full seq 128 artifacts (padding
                # only affects masked-out tokens).


def crop(split, seq):
    return {k: (v[:, :seq] if v.ndim == 2 else v) for k, v in split.items()}


def loss_fn(params, cfg, batch, n_classes):
    logits = bert_forward(params, cfg, batch["input_ids"], batch["type_ids"],
                          batch["mask"])
    if n_classes == 0:
        pred = logits[:, 0]
        return jnp.mean((pred - batch["labels"]) ** 2)
    lg = logits[:, :n_classes]
    lg = lg - jax.scipy.special.logsumexp(lg, axis=-1, keepdims=True)
    nll = -jnp.take_along_axis(lg, batch["labels"][:, None], axis=-1)
    return jnp.mean(nll)


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm=1.0):
    """Standard BERT-finetuning global-norm gradient clipping."""
    total = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))
    scale = jnp.minimum(1.0, max_norm / (total + 1e-6))
    return {k: g * scale for k, g in grads.items()}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    grads = clip_by_global_norm(grads)
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    bias1 = 1.0 - b1 ** tf
    bias2 = 1.0 - b2 ** tf
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * state["m"][k] + (1 - b1) * grads[k]
        v = b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k])
        new_m[k], new_v[k] = m, v
        mhat = m / bias1
        vhat = v / bias2
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, {"m": new_m, "v": new_v, "t": t}


def lr_schedule(step, total, peak):
    warm = max(1, total // 10)
    if step < warm:
        return peak * (step + 1) / warm
    return peak * max(0.0, (total - step) / max(1, total - warm))


def predict(params, cfg, split, n_classes, seq, batch=64):
    """Dev-set predictions (classification argmax / regression score)."""
    ids = split["input_ids"][:, :seq]
    ty = split["type_ids"][:, :seq]
    n = ids.shape[0]
    preds = []
    fwd = jax.jit(lambda p, i, t, m: bert_forward(p, cfg, i, t, m))
    for lo in range(0, n, batch):
        hi = min(n, lo + batch)
        bi = ids[lo:hi]
        if bi.shape[0] < batch:  # pad the tail batch to keep one jit shape
            padn = batch - bi.shape[0]
            bi = np.concatenate([bi, np.zeros((padn, seq), np.int32)])
            bt = np.concatenate([ty[lo:hi], np.zeros((padn, seq), np.int32)])
        else:
            bt = ty[lo:hi]
        m = attn_mask(bi)
        lg = np.asarray(fwd(params, jnp.asarray(bi), jnp.asarray(bt), jnp.asarray(m)))
        lg = lg[: hi - lo]
        if n_classes == 0:
            preds.append(lg[:, 0])
        else:
            preds.append(np.argmax(lg[:, :n_classes], axis=-1))
    return np.concatenate(preds)


def evaluate(params, cfg, split, task, seq=128):
    meta = TASK_META[task]
    preds = predict(params, cfg, split, meta["classes"], seq)
    labels = split.get("labels_i32", split.get("labels_f32"))
    return {m: compute_metric(m, preds, labels) for m in meta["metrics"]}


def train_task(task, splits, cfg: ModelConfig, init_params, *, epochs=3,
               batch=32, lr=5e-4, seed=0, log=print):
    """Returns (trained params dict of np arrays, dev metrics dict)."""
    meta = TASK_META[task]
    n_classes = meta["classes"]
    tr = crop(splits["train"], TRAIN_SEQ)
    ids, ty = tr["input_ids"], tr["type_ids"]
    labels = tr.get("labels_i32", tr.get("labels_f32"))
    n = ids.shape[0]
    steps = max(1, (n // batch) * epochs)

    params = {k: jnp.asarray(v) for k, v in init_params.items()}
    state = adam_init(params)

    @jax.jit
    def step_fn(params, state, b_ids, b_ty, b_mask, b_labels, lr_now):
        batch_d = {"input_ids": b_ids, "type_ids": b_ty, "mask": b_mask,
                   "labels": b_labels}
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch_d, n_classes)
        params, state = adam_update(params, grads, state, lr_now)
        return params, state, loss

    r = np.random.default_rng(seed)
    t0 = time.time()
    losses = []
    for s in range(steps):
        idx = r.integers(0, n, size=batch)
        b_ids = jnp.asarray(ids[idx])
        b_ty = jnp.asarray(ty[idx])
        b_mask = jnp.asarray(attn_mask(ids[idx]))
        lab = labels[idx]
        b_labels = jnp.asarray(lab if n_classes else lab.astype(np.float32))
        lr_now = jnp.float32(lr_schedule(s, steps, lr))
        params, state, loss = step_fn(params, state, b_ids, b_ty, b_mask,
                                      b_labels, lr_now)
        losses.append(float(loss))
        if s % 50 == 0 or s == steps - 1:
            log(f"  [{task}] step {s}/{steps} loss {np.mean(losses[-50:]):.4f} "
                f"({time.time() - t0:.0f}s)")
    dev = evaluate(params, cfg, splits["dev"], task)
    log(f"  [{task}] dev {dev}")
    np_params = {k: np.asarray(v) for k, v in params.items()}
    return np_params, dev
