//! Executable residency manager (DESIGN.md §5.13).
//!
//! The executable table is a `[version][mode][seq_bucket][batch_bucket]`
//! grid; eagerly materializing the whole cross-product per replica
//! multiplies startup time and resident memory by the grid size
//! (ROADMAP item 5).  `Residency` replaces eager preload with cache
//! semantics over grid *cells*:
//!
//!   * a configurable **pin set** is loaded synchronously at startup and
//!     is never evicted;
//!   * every other cell is compiled/uploaded on first demand with
//!     **single-flight** dedup — concurrent requests for one cell block
//!     on the one in-progress load instead of compiling twice;
//!   * cold cells are **LRU-evicted** under a cell-count and/or byte
//!     budget, so resident memory is bounded regardless of grid growth;
//!   * a manifest reload **repins** to the new version's pin set; the
//!     old version's cells unpin and age out through the same LRU.
//!
//! `Residency` holds only *metadata* (states, LRU stamps, byte sizes,
//! counters); the compiled executables themselves live in the replica's
//! `Runtime`, which is not `Send`.  The engine thread is the only
//! loader; the coordinator reads `any_resident` to keep a governed
//! downgrade from stalling on a cold rung, and the supervisor calls
//! `clear` when a slot is terminally excluded.  The protocol per cell:
//!
//! ```text
//!   begin(key) -> Hit            # resident; LRU stamp refreshed
//!   begin(key) -> Load           # caller owns the load:
//!       ... compile/upload ...
//!       complete(key, bytes, pinned) -> evicted cells   # or
//!       fail(key)                # waiters retry and re-claim the load
//! ```
//!
//! Eviction runs at `complete`, *before* the arriving cell is inserted
//! (make room first), so the resident count never exceeds
//! `max(budget, pinned cells)` and the arriving cell is never its own
//! victim.  Cells mid-`Loading` are never eviction candidates.

use std::collections::HashMap;

use crate::sync::{Condvar, Mutex, MutexGuard};

/// One executable grid cell: a compiled `(mode, seq bucket, batch
/// bucket)` variant of one manifest version.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CellKey {
    pub version: u32,
    /// `ModeId` index (kept raw so the key stays `Ord` + trivially
    /// hashable).
    pub mode: u16,
    pub seq: usize,
    pub bucket: usize,
}

#[derive(Debug, Clone, Copy)]
enum CellState {
    /// One loader owns an in-progress compile/upload; other callers of
    /// `begin` block on the condvar (single-flight).
    Loading,
    Resident { pinned: bool, last_used: u64, bytes: usize },
}

/// What `begin` resolved a cell to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Begin {
    /// Resident; the LRU stamp was refreshed.
    Hit,
    /// The caller owns the load and must call `complete` or `fail`.
    Load,
}

#[derive(Default)]
struct ResidencyInner {
    cells: HashMap<CellKey, CellState>,
    /// Logical LRU clock: bumped on every hit/insert.
    tick: u64,
    hits: u64,
    misses: u64,
    loads: u64,
    evictions: u64,
    peak_resident: usize,
    resident_bytes: usize,
}

impl ResidencyInner {
    fn resident(&self) -> usize {
        self.cells.values().filter(|c| matches!(c, CellState::Resident { .. })).count()
    }

    fn pinned(&self) -> usize {
        self.cells
            .values()
            .filter(|c| matches!(c, CellState::Resident { pinned: true, .. }))
            .count()
    }
}

/// Counter snapshot (ledgered per replica by the Recorder's residency
/// table; asserted by the property tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyCounters {
    pub hits: u64,
    pub misses: u64,
    /// Completed loads (misses that reached `complete`).
    pub loads: u64,
    pub evictions: u64,
    pub resident: usize,
    pub pinned: usize,
    /// High-water mark of the resident cell count.
    pub peak_resident: usize,
    pub resident_bytes: usize,
}

/// Thread-safe residency metadata for one replica's executable grid.
pub struct Residency {
    /// Max resident cells (`None` = unbounded).  Pinned cells override
    /// the budget: they are never evicted even when the pin set alone
    /// exceeds it.
    max_cells: Option<usize>,
    /// Max resident bytes (`None` = unbounded), measured by artifact
    /// size as reported at `complete`.
    max_bytes: Option<usize>,
    inner: Mutex<ResidencyInner>,
    cv: Condvar,
}

impl Residency {
    pub fn new(max_cells: Option<usize>, max_bytes: Option<usize>) -> Self {
        Residency {
            max_cells,
            max_bytes,
            inner: Mutex::new(ResidencyInner::default()),
            cv: Condvar::new(),
        }
    }

    /// Lock the metadata, recovering from poisoning: the table is pure
    /// bookkeeping (no torn invariants a panicking holder could leave
    /// half-applied that later ops cannot reconcile), and the serving
    /// path must keep resolving cells even if an introspection caller
    /// panicked.
    fn lock(&self) -> MutexGuard<'_, ResidencyInner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Resolve `key`: `Hit` if resident (LRU refreshed), `Load` if this
    /// caller now owns the cell's load.  Blocks while another loader has
    /// the cell in flight; if that load fails, a waiter wakes, finds the
    /// cell absent, and claims the load itself (retry-on-failure).
    pub fn begin(&self, key: CellKey) -> Begin {
        let mut g = self.lock();
        loop {
            match g.cells.get(&key).copied() {
                Some(CellState::Resident { pinned, bytes, .. }) => {
                    g.tick += 1;
                    let last_used = g.tick;
                    g.cells.insert(key, CellState::Resident { pinned, last_used, bytes });
                    g.hits += 1;
                    return Begin::Hit;
                }
                Some(CellState::Loading) => {
                    // single-flight: park until the owning loader calls
                    // complete (-> Hit) or fail (-> claim the load)
                    g = match self.cv.wait(g) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
                None => {
                    g.cells.insert(key, CellState::Loading);
                    g.misses += 1;
                    return Begin::Load;
                }
            }
        }
    }

    /// Mark an owned load done: the cell becomes resident (stamped most
    /// recently used), waiters wake as hits, and LRU eviction makes room
    /// first.  Returns the evicted cells; the caller must drop their
    /// device-side executables.
    pub fn complete(&self, key: CellKey, bytes: usize, pinned: bool) -> Vec<CellKey> {
        let mut g = self.lock();
        // make room before inserting: the arriving cell is never its own
        // eviction victim, and the budget holds post-insert
        let evicted = self.evict_for(&mut g, 1, bytes);
        g.cells.remove(&key);
        g.tick += 1;
        let last_used = g.tick;
        g.cells.insert(key, CellState::Resident { pinned, last_used, bytes });
        g.loads += 1;
        g.resident_bytes += bytes;
        let resident = g.resident();
        g.peak_resident = g.peak_resident.max(resident);
        self.cv.notify_all();
        evicted
    }

    /// Abandon an owned load (compile/upload error): the `Loading`
    /// marker is removed and waiters wake to retry.
    pub fn fail(&self, key: CellKey) {
        let mut g = self.lock();
        if matches!(g.cells.get(&key), Some(CellState::Loading)) {
            g.cells.remove(&key);
        }
        self.cv.notify_all();
    }

    /// Replace the pin set (manifest reload): every resident cell's pin
    /// flag is recomputed against `pins` — the old version's pins unpin
    /// and become LRU candidates — then eviction reconciles any budget
    /// overshoot the old pin set was excusing.  LRU stamps are kept, so
    /// unpinned-but-hot cells age out last.
    pub fn repin(&self, pins: &[CellKey]) -> Vec<CellKey> {
        let mut g = self.lock();
        let keys: Vec<CellKey> = g.cells.keys().copied().collect();
        for k in keys {
            if let Some(CellState::Resident { last_used, bytes, .. }) = g.cells.get(&k).copied() {
                let pinned = pins.contains(&k);
                g.cells.insert(k, CellState::Resident { pinned, last_used, bytes });
            }
        }
        self.evict_for(&mut g, 0, 0)
    }

    /// Evict least-recently-used unpinned resident cells until
    /// `incoming_cells`/`incoming_bytes` more fit the budgets.  Stops
    /// when only pinned (or mid-load) cells remain: pins always win over
    /// the budget.
    fn evict_for(
        &self,
        g: &mut ResidencyInner,
        incoming_cells: usize,
        incoming_bytes: usize,
    ) -> Vec<CellKey> {
        let mut evicted = Vec::new();
        loop {
            let over_cells =
                self.max_cells.is_some_and(|m| g.resident() + incoming_cells > m);
            let over_bytes =
                self.max_bytes.is_some_and(|m| g.resident_bytes + incoming_bytes > m);
            if !over_cells && !over_bytes {
                return evicted;
            }
            let victim = g
                .cells
                .iter()
                .filter_map(|(k, c)| match c {
                    CellState::Resident { pinned: false, last_used, bytes } => {
                        Some((*k, *last_used, *bytes))
                    }
                    _ => None,
                })
                .min_by_key(|(_, last_used, _)| *last_used);
            match victim {
                Some((k, _, bytes)) => {
                    g.cells.remove(&k);
                    g.resident_bytes = g.resident_bytes.saturating_sub(bytes);
                    g.evictions += 1;
                    evicted.push(k);
                }
                None => return evicted,
            }
        }
    }

    /// Drop every *resident* cell of versions older than `keep_min`
    /// (reload drain: with current + previous kept, anything older has
    /// no in-flight work left).  Returns the dropped keys so the caller
    /// removes their device-side executables too; cells mid-`Loading`
    /// are left for their owner to complete (they age out via LRU).
    pub fn drop_versions_below(&self, keep_min: u32) -> Vec<CellKey> {
        let mut g = self.lock();
        let stale: Vec<CellKey> = g
            .cells
            .iter()
            .filter_map(|(k, c)| {
                (k.version < keep_min && matches!(c, CellState::Resident { .. })).then_some(*k)
            })
            .collect();
        for k in &stale {
            if let Some(CellState::Resident { bytes, .. }) = g.cells.remove(k) {
                g.resident_bytes = g.resident_bytes.saturating_sub(bytes);
                g.evictions += 1;
            }
        }
        self.cv.notify_all();
        stale
    }

    pub fn is_resident(&self, key: CellKey) -> bool {
        matches!(self.lock().cells.get(&key), Some(CellState::Resident { .. }))
    }

    /// Whether *any* batch-bucket cell of `(version, mode, seq)` is
    /// resident — the coordinator's governed-downgrade probe: a rung
    /// with no resident cell would stall the pressure path on a compile,
    /// so the governor serves the resident rung and warms this one
    /// asynchronously instead.
    pub fn any_resident(&self, version: u32, mode: u16, seq: usize) -> bool {
        self.lock().cells.iter().any(|(k, c)| {
            k.version == version
                && k.mode == mode
                && k.seq == seq
                && matches!(c, CellState::Resident { .. })
        })
    }

    /// Drop every cell (terminal slot exclusion: the device state is
    /// gone, so the metadata must not claim residency).  Counters are
    /// kept — the ledger survives the teardown.
    pub fn clear(&self) {
        let mut g = self.lock();
        g.cells.clear();
        g.resident_bytes = 0;
        // wake any waiter so it re-resolves (and fails fast against the
        // dead incarnation rather than parking forever)
        self.cv.notify_all();
    }

    /// Fresh-incarnation reset (supervised restart): a new `Runtime` has
    /// nothing resident and the per-incarnation ledger starts at zero —
    /// `startup loads == pinned cells` is asserted against this state.
    pub fn reset(&self) {
        let mut g = self.lock();
        *g = ResidencyInner::default();
        self.cv.notify_all();
    }

    pub fn counters(&self) -> ResidencyCounters {
        let g = self.lock();
        ResidencyCounters {
            hits: g.hits,
            misses: g.misses,
            loads: g.loads,
            evictions: g.evictions,
            resident: g.resident(),
            pinned: g.pinned(),
            peak_resident: g.peak_resident,
            resident_bytes: g.resident_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Rng};
    use crate::sync::Arc;

    fn cell(mode: u16, seq: usize, bucket: usize) -> CellKey {
        CellKey { version: 0, mode, seq, bucket }
    }

    #[test]
    fn hit_miss_and_lru_eviction() {
        let r = Residency::new(Some(2), None);
        assert_eq!(r.begin(cell(0, 16, 4)), Begin::Load);
        assert!(r.complete(cell(0, 16, 4), 10, false).is_empty());
        assert_eq!(r.begin(cell(0, 16, 4)), Begin::Hit);
        assert_eq!(r.begin(cell(0, 32, 4)), Begin::Load);
        assert!(r.complete(cell(0, 32, 4), 10, false).is_empty());
        // touch the first cell so the second is the LRU victim
        assert_eq!(r.begin(cell(0, 16, 4)), Begin::Hit);
        assert_eq!(r.begin(cell(1, 16, 4)), Begin::Load);
        let evicted = r.complete(cell(1, 16, 4), 10, false);
        assert_eq!(evicted, vec![cell(0, 32, 4)], "LRU cell evicted");
        let c = r.counters();
        assert_eq!((c.hits, c.misses, c.loads, c.evictions), (2, 3, 3, 1));
        assert_eq!(c.resident, 2);
        assert_eq!(c.peak_resident, 2, "make-room-first never overshoots");
        assert!(!r.is_resident(cell(0, 32, 4)));
    }

    #[test]
    fn pinned_cells_survive_budget_pressure_and_repin_releases_them() {
        let r = Residency::new(Some(1), None);
        assert_eq!(r.begin(cell(0, 16, 4)), Begin::Load);
        assert!(r.complete(cell(0, 16, 4), 5, true).is_empty());
        // budget 1 is full of pin: a demand load still lands (pins
        // override the budget) and the pin is never the victim
        assert_eq!(r.begin(cell(0, 32, 4)), Begin::Load);
        assert!(r.complete(cell(0, 32, 4), 5, false).is_empty());
        assert_eq!(r.counters().resident, 2);
        assert_eq!(r.begin(cell(0, 64, 4)), Begin::Load);
        let evicted = r.complete(cell(0, 64, 4), 5, false);
        assert_eq!(evicted, vec![cell(0, 32, 4)], "unpinned LRU evicted, pin kept");
        // reload: the new pin set drops the old pin, which now evicts
        let evicted = r.repin(&[cell(0, 64, 4)]);
        assert_eq!(evicted, vec![cell(0, 16, 4)], "old pin unpinned and reconciled");
        let c = r.counters();
        assert_eq!((c.resident, c.pinned), (1, 1));
    }

    #[test]
    fn byte_budget_evicts_and_failed_loads_retry() {
        let r = Residency::new(None, Some(100));
        assert_eq!(r.begin(cell(0, 16, 4)), Begin::Load);
        assert!(r.complete(cell(0, 16, 4), 60, false).is_empty());
        assert_eq!(r.begin(cell(0, 32, 4)), Begin::Load);
        let evicted = r.complete(cell(0, 32, 4), 60, false);
        assert_eq!(evicted, vec![cell(0, 16, 4)], "byte budget forced the LRU out");
        assert_eq!(r.counters().resident_bytes, 60);
        // a failed load leaves no residue: the next begin re-claims it
        assert_eq!(r.begin(cell(1, 16, 4)), Begin::Load);
        r.fail(cell(1, 16, 4));
        assert_eq!(r.begin(cell(1, 16, 4)), Begin::Load);
        r.fail(cell(1, 16, 4));
        assert_eq!(r.counters().misses, 4);
        assert_eq!(r.counters().loads, 2);
    }

    #[test]
    fn single_flight_one_loader_many_hits() {
        // N threads race begin() on one cold cell: exactly one owns the
        // load, everyone else blocks and resolves to a hit — the cell is
        // never compiled twice
        let r = Arc::new(Residency::new(None, None));
        let key = cell(0, 128, 16);
        let loads = Arc::new(crate::sync::atomic::AtomicUsize::new(0));
        let hits = Arc::new(crate::sync::atomic::AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..8 {
            let r = Arc::clone(&r);
            let loads = Arc::clone(&loads);
            let hits = Arc::clone(&hits);
            joins.push(crate::sync::thread::spawn(move || match r.begin(key) {
                Begin::Load => {
                    // hold the load long enough that the other threads
                    // pile up on the condvar
                    crate::sync::thread::sleep(std::time::Duration::from_millis(20));
                    loads.fetch_add(1, crate::sync::atomic::Ordering::SeqCst);
                    r.complete(key, 1, false);
                }
                Begin::Hit => {
                    hits.fetch_add(1, crate::sync::atomic::Ordering::SeqCst);
                }
            }));
        }
        for j in joins {
            j.join().expect("residency race thread");
        }
        assert_eq!(loads.load(crate::sync::atomic::Ordering::SeqCst), 1);
        assert_eq!(hits.load(crate::sync::atomic::Ordering::SeqCst), 7);
        let c = r.counters();
        assert_eq!((c.hits, c.misses, c.loads), (7, 1, 1));
    }

    #[test]
    fn prop_budget_pins_and_ledger_reconcile() {
        forall("residency-invariants", 60, |rng: &mut Rng| {
            let budget = 1 + rng.below(6);
            // pins fit the budget (the serving config derives them that
            // way); the invariant under test is then a hard bound
            let npins = rng.below(budget + 1);
            let grid: Vec<CellKey> = (0..3u16)
                .flat_map(|m| [16usize, 32, 64].into_iter().map(move |s| cell(m, s, 4)))
                .collect();
            let mut pins: Vec<CellKey> = grid.clone();
            // deterministic shuffle via random swaps
            for i in (1..pins.len()).rev() {
                let j = rng.below(i + 1);
                pins.swap(i, j);
            }
            pins.truncate(npins);
            let r = Residency::new(Some(budget), None);
            for p in &pins {
                assert_eq!(r.begin(*p), Begin::Load, "fresh pin must be a miss");
                r.complete(*p, 1 + rng.below(10), true);
            }
            let c = r.counters();
            assert_eq!(c.loads, npins as u64, "startup loads == pinned cells");
            assert_eq!(c.pinned, npins);
            let mut begins = npins as u64;
            let mut evicted_log: Vec<CellKey> = Vec::new();
            for _ in 0..rng.below(200) {
                let k = *rng.choice(&grid);
                begins += 1;
                match r.begin(k) {
                    Begin::Hit => {}
                    Begin::Load => {
                        if rng.below(10) == 0 {
                            r.fail(k);
                        } else {
                            evicted_log.extend(r.complete(k, 1 + rng.below(10), false));
                        }
                    }
                }
                let c = r.counters();
                assert!(
                    c.resident <= budget,
                    "resident {} exceeded budget {budget}",
                    c.resident
                );
                assert_eq!(c.hits + c.misses, begins, "every begin is a hit or a miss");
                for p in &pins {
                    assert!(r.is_resident(*p), "pinned cell {p:?} went missing");
                }
            }
            assert!(
                evicted_log.iter().all(|k| !pins.contains(k)),
                "a pinned cell was evicted"
            );
            assert!(r.counters().peak_resident <= budget);
            // reload to an empty pin set: everything becomes evictable
            // and the budget still holds
            r.repin(&[]);
            assert_eq!(r.counters().pinned, 0);
            assert!(r.counters().resident <= budget);
            r.clear();
            let c = r.counters();
            assert_eq!((c.resident, c.resident_bytes), (0, 0));
        });
    }
}
