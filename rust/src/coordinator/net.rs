//! Network front-end: newline-delimited JSON over TCP, served by the
//! coordinator (`repro serve --port N`).  Two frame versions:
//!
//! v1 (compat shim — whole-model string mode, desugars to the mode's
//! uniform policy):
//!   {"task": "sst2", "mode": "m3", "ids": [...], "type_ids": [...]}
//!   -> {"ok": true, "logits": [...], "queue_us": .., "exec_us": ..,
//!       "bucket": ..} | {"ok": false, "error": "..."}
//!
//! v2 (typed precision policy, by name or inline spec):
//!   {"v": 2, "task": "sst2", "policy": "attn-out-fp", "ids": [...]}
//!   {"v": 2, "task": "sst2",
//!    "policy": {"base": "m3", "overrides": [["attn_output", "fp"]],
//!               "fallback": ["m2", "m1", "fp"]}, "ids": [...]}
//!   -> v1 fields plus {"v": 2, "policy": <interned name>,
//!      "mode": <executable mode>}
//!
//! In both versions `type_ids` is optional (zeros) and `ids` stay
//! *unpadded* — the request's real length picks its sequence-length
//! bucket at admission (DESIGN.md §5.9), so a short request rides a
//! short executable; successful replies name the `seq_bucket` the batch
//! executed at.  A v2 frame with no `policy`
//! routes through the manifest's first mode; a v1 frame must name its
//! `mode` — the pre-v2 implicit "m3" fallback is gone, and an explicit
//! error beats silently serving a different precision.  Mixing `mode`
//! into a v2 frame (or `policy` into a v1 frame) is an error, not a
//! guess.
//!
//! Overload control on the wire (DESIGN.md §5.8): v2 frames may carry
//! `"deadline_ms"`; a request shed at the admission bound answers
//! `{"ok": false, "busy": true, ...}` (retry later) and one whose
//! deadline passed before execution answers
//! `{"ok": false, "expired": true, ...}` — both distinct from terminal
//! errors.  The per-connection read timeout and per-frame byte cap come
//! from `ServerConfig` (`net_read_timeout`, `max_frame_bytes`).
//!
//! One OS thread per connection (requests within a connection pipeline
//! through the dynamic batcher like any other); shutdown via the returned
//! handle.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};
use crate::model::manifest::{Manifest, PolicyDraft};
use crate::sync::mpsc::Receiver;

use super::request::{PolicyRef, RequestSpec, Response};
use super::server::{Coordinator, SubmitError};

/// What the wire layer needs from whatever sits behind it.  Two
/// implementors: the single-process `Coordinator` (admission straight
/// into the local batcher) and the two-tier `FrontEnd` (admission into
/// the node router, DESIGN.md §5.14).  `NetServer` is generic over this
/// trait so the same accept loop, framing, and response mapping serve
/// both deployments — the client cannot tell them apart.
pub trait Admission: Send + Sync {
    /// Admit one typed request; the receiver yields exactly one terminal
    /// `Response` unless the server is torn down mid-flight.
    fn submit_spec(&self, spec: RequestSpec)
        -> std::result::Result<Receiver<Response>, SubmitError>;
    /// Manifest for name <-> id mapping in v2 responses.
    fn manifest(&self) -> &Manifest;
    /// Model max sequence length (wire-level ids bounds check).
    fn seq(&self) -> usize;
    /// Per-connection socket read timeout.
    fn net_read_timeout(&self) -> Duration;
    /// Per-frame byte cap.
    fn max_frame_bytes(&self) -> usize;
}

impl Admission for Coordinator {
    fn submit_spec(
        &self,
        spec: RequestSpec,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        self.submit(spec)
    }

    fn manifest(&self) -> &Manifest {
        Coordinator::manifest(self)
    }

    fn seq(&self) -> usize {
        Coordinator::seq(self)
    }

    fn net_read_timeout(&self) -> Duration {
        self.config.net_read_timeout
    }

    fn max_frame_bytes(&self) -> usize {
        self.config.max_frame_bytes
    }
}

pub struct NetServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<std::thread::JoinHandle<()>>,
    pub connections: Arc<AtomicU64>,
    pub served: Arc<AtomicU64>,
}

impl NetServer {
    /// Bind `host:port` (port 0 = ephemeral) and serve until dropped.
    pub fn start<A: Admission + 'static>(coord: Arc<A>, host: &str, port: u16) -> Result<NetServer> {
        let listener =
            TcpListener::bind((host, port)).with_context(|| format!("bind {host}:{port}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let served = Arc::new(AtomicU64::new(0));

        let t_stop = Arc::clone(&stop);
        let t_conns = Arc::clone(&connections);
        let t_served = Arc::clone(&served);
        let accept_join = std::thread::Builder::new()
            .name("zqh-accept".into())
            .spawn(move || {
                let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !t_stop.load(Ordering::SeqCst) {
                    // reap finished connection threads as connections
                    // close — a long-lived server must not accumulate one
                    // JoinHandle per connection it ever accepted
                    let mut i = 0;
                    while i < workers.len() {
                        if workers[i].is_finished() {
                            let _ = workers.swap_remove(i).join();
                        } else {
                            i += 1;
                        }
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            t_conns.fetch_add(1, Ordering::SeqCst);
                            let coord = Arc::clone(&coord);
                            let served = Arc::clone(&t_served);
                            let stop = Arc::clone(&t_stop);
                            workers.push(std::thread::spawn(move || {
                                let _ = handle_conn(stream, &coord, &served, &stop);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for w in workers {
                    let _ = w.join();
                }
            })
            .context("spawn acceptor")?;

        Ok(NetServer { addr, stop, accept_join: Some(accept_join), connections, served })
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

/// Parse a token array, bounds-checked against the model max but left
/// *unpadded*: the request's real length is what admission buckets on
/// (DESIGN.md §5.9) — padding here would silently put every wire request
/// in the top seq class.
fn ids_from(v: &Value, key: &str, seq: usize) -> Result<Option<Vec<i32>>> {
    match v.get(key) {
        None => Ok(None),
        Some(arr) => {
            let a = arr.as_array().context("ids must be an array")?;
            anyhow::ensure!(a.len() <= seq, "too many tokens ({} > seq {seq})", a.len());
            let mut out = Vec::with_capacity(a.len());
            for x in a {
                out.push(x.as_f64().context("token not a number")? as i32);
            }
            Ok(Some(out))
        }
    }
}

/// Parse one wire frame into a typed spec plus the protocol version to
/// answer with.  v1 frames (`mode`) desugar to uniform policies — the
/// compatibility shim; v2 frames carry a `policy` by name or inline spec.
pub fn parse_request(req: &Value, seq: usize) -> Result<(RequestSpec, u8)> {
    let version = match req.get("v") {
        None => {
            // versionless: infer from the route field, defaulting to v1
            if req.get("policy").is_some() {
                2
            } else {
                1
            }
        }
        Some(v) => match v.as_usize().context("\"v\" not a number")? {
            1 => 1,
            2 => 2,
            other => bail!("unsupported protocol version {other} (supported: 1, 2)"),
        },
    };
    let task = req.get("task").and_then(Value::as_str).unwrap_or_default().to_string();
    let policy = if version == 1 {
        anyhow::ensure!(
            req.get("policy").is_none(),
            "\"policy\" requires a v2 frame (set \"v\": 2)"
        );
        anyhow::ensure!(
            req.get("deadline_ms").is_none(),
            "\"deadline_ms\" requires a v2 frame (set \"v\": 2)"
        );
        // the old implicit "m3" default is gone: silently serving a
        // different precision than the client assumed is worse than an
        // error that names the fix
        let mode = req
            .get("mode")
            .context("v1 frame missing \"mode\" (name a mode, or send a v2 policy frame)")?;
        Some(PolicyRef::Named(mode.as_str().context("mode not a string")?.to_string()))
    } else {
        anyhow::ensure!(req.get("mode").is_none(), "v2 frames use \"policy\", not \"mode\"");
        match req.get("policy") {
            None => None,
            Some(Value::String(name)) => Some(PolicyRef::Named(name.clone())),
            Some(obj @ Value::Object(_)) => Some(PolicyRef::Inline(
                PolicyDraft::from_json(obj).context("inline policy spec")?,
            )),
            Some(_) => bail!("policy must be a name or an inline spec object"),
        }
    };
    let ids = ids_from(req, "ids", seq)?.context("missing ids")?;
    let type_ids = ids_from(req, "type_ids", seq)?;
    let deadline = match req.get("deadline_ms") {
        None => None,
        Some(v) => {
            let ms = v.as_f64().context("deadline_ms not a number")?;
            // a sub-millisecond budget would truncate to 0 — an
            // expire-on-arrival trap, not a deadline
            anyhow::ensure!(ms >= 1.0, "deadline_ms must be at least 1");
            Some(std::time::Duration::from_millis(ms as u64))
        }
    };
    Ok((RequestSpec { task, policy, ids, type_ids, deadline }, version))
}

/// Serialize a typed spec as a v2 wire frame (the client side of
/// `parse_request`; `NetClient::request` still emits bare v1 frames).
pub fn request_to_json(spec: &RequestSpec) -> Value {
    let mut pairs = vec![
        ("v", json::num(2.0)),
        ("task", Value::String(spec.task.clone())),
    ];
    match &spec.policy {
        None => {}
        Some(PolicyRef::Named(name)) => pairs.push(("policy", Value::String(name.clone()))),
        Some(PolicyRef::Inline(draft)) => pairs.push(("policy", draft.to_json())),
    }
    pairs.push(("ids", Value::Array(spec.ids.iter().map(|x| json::num(*x as f64)).collect())));
    if let Some(tys) = &spec.type_ids {
        pairs.push(("type_ids", Value::Array(tys.iter().map(|x| json::num(*x as f64)).collect())));
    }
    if let Some(d) = spec.deadline {
        pairs.push(("deadline_ms", json::num(d.as_millis() as f64)));
    }
    json::obj(pairs)
}

/// Map a terminal `Response` to its wire shape.  This is the *single*
/// definition of the outcome-class -> wire-field mapping: `process_line`
/// uses it to answer clients, and the engine-node link (DESIGN.md §5.14)
/// uses the same function so `busy` / `expired` / `failed` cross the
/// tier boundary as the exact fields the client already understands —
/// the front end re-types them from flags, never by parsing error
/// strings.
pub fn response_to_json(resp: &Response, version: u8, man: &Manifest) -> Value {
    let flagged = |flag: &'static str, msg: String| {
        let mut pairs = vec![
            ("ok", Value::Bool(false)),
            (flag, Value::Bool(true)),
            ("error", Value::String(msg)),
        ];
        if version >= 2 {
            pairs.push(("v", json::num(version as f64)));
        }
        json::obj(pairs)
    };
    if resp.busy {
        // remote-tier backpressure (a node shed the request after the
        // front end admitted it): same wire shape as a local Busy
        return flagged("busy", resp.error.clone().unwrap_or_else(|| "busy".into()));
    }
    match &resp.error {
        // deadline expiry is a distinct outcome class, not a server
        // fault: the flag lets clients count it apart
        Some(e) if resp.expired => flagged("expired", e.clone()),
        // replica failure (DESIGN.md §5.10): the server swept the
        // request off a dead engine — retryable, unlike a terminal
        // request error, so it gets its own wire flag
        Some(e) if resp.failed => flagged("failed", e.clone()),
        Some(e) => {
            json::obj(vec![("ok", Value::Bool(false)), ("error", Value::String(e.clone()))])
        }
        None => {
            let mut pairs = vec![
                ("ok", Value::Bool(true)),
                ("logits", json::arr_f32(&resp.logits)),
                ("queue_us", json::num(resp.timing.queue_us as f64)),
                ("exec_us", json::num(resp.timing.exec_us as f64)),
                ("bucket", json::num(resp.timing.bucket as f64)),
                ("seq_bucket", json::num(resp.timing.seq_bucket as f64)),
                ("batch", json::num(resp.timing.batch_real as f64)),
            ];
            if version >= 2 {
                // admission already interned the policy; map the id
                // back to names without re-resolving
                pairs.push(("v", json::num(version as f64)));
                pairs.push((
                    "policy",
                    Value::String(man.policy_name(resp.policy).to_string()),
                ));
                let exec = man.policy_by_id(resp.policy).exec_mode;
                pairs.push(("mode", Value::String(man.mode_name(exec).to_string())));
            }
            json::obj(pairs)
        }
    }
}

fn process_line<A: Admission>(line: &str, coord: &A) -> Value {
    let fail = |msg: String| {
        json::obj(vec![("ok", Value::Bool(false)), ("error", Value::String(msg))])
    };
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return fail(format!("bad json: {e}")),
    };
    // route strings die here — admission interns them to TaskId/PolicyId
    // (DESIGN.md §5.2, §6.3)
    let (spec, version) = match parse_request(&req, coord.seq()) {
        Ok(x) => x,
        Err(e) => return fail(format!("{e:#}")),
    };
    let rx = match coord.submit_spec(spec) {
        Ok(rx) => rx,
        // explicit backpressure gets its own wire shape: "busy" tells the
        // client to back off and retry, unlike a terminal error
        Err(e @ SubmitError::Busy { .. }) => {
            let mut pairs = vec![
                ("ok", Value::Bool(false)),
                ("busy", Value::Bool(true)),
                ("error", Value::String(e.to_string())),
            ];
            if version >= 2 {
                pairs.push(("v", json::num(version as f64)));
            }
            return json::obj(pairs);
        }
        Err(e) => return fail(e.to_string()),
    };
    match rx.recv() {
        Err(_) => fail("coordinator dropped request".into()),
        Ok(resp) => response_to_json(&resp, version, coord.manifest()),
    }
}

/// Read one newline-terminated frame into `line`, which may already hold
/// a partial frame from a previous timed-out read.  Returns `true` when
/// `line` holds a frame to process; `false` on clean EOF, stop, or a hard
/// I/O error.  Read timeouts (`WouldBlock`/`TimedOut`) keep whatever
/// bytes have already been buffered — the old loop cleared `line` at the
/// top of every iteration, silently dropping the head of any frame that
/// straddled the 200 ms timeout window.  The buffer is raw bytes
/// (`read_until`, not `read_line`): `read_line`'s UTF-8 guard discards a
/// call's appended bytes when an error lands mid-way through a
/// multi-byte character, which would re-introduce the drop for non-ASCII
/// frames split at exactly the wrong byte.
/// The per-frame byte cap and the socket read timeout both come from
/// `ServerConfig` (`max_frame_bytes`, default 1 MiB; `net_read_timeout`,
/// default 200 ms).  The largest legitimate frame is a few KB of token
/// ids, so anything near the cap with no newline is a runaway or
/// malicious stream; without a cap, one connection could buffer the
/// server into an OOM (the payload-size checks in parsing only run on
/// complete frames).
fn read_frame(
    reader: &mut impl BufRead,
    line: &mut Vec<u8>,
    stop: &AtomicBool,
    max_frame: usize,
) -> bool {
    loop {
        if stop.load(Ordering::SeqCst) {
            return false;
        }
        // read through a `Take` so even a firehose with no newline
        // cannot grow the buffer past the cap inside one read_until call
        let budget = (max_frame.saturating_sub(line.len()) + 1) as u64;
        match (&mut *reader).take(budget).read_until(b'\n', line) {
            // EOF: a peer that closed mid-frame without a trailing
            // newline still gets its buffered final frame processed
            Ok(0) => return !line.is_empty(),
            Ok(_) => {
                if line.last() != Some(&b'\n') && line.len() > max_frame {
                    // budget exhausted with no frame boundary in sight:
                    // drop the connection instead of buffering forever
                    return false;
                }
                return true;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return false,
        }
    }
}

fn handle_conn<A: Admission>(
    stream: TcpStream,
    coord: &A,
    served: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    // both knobs ride ServerConfig so deployments can tune them without
    // a rebuild-level constant (a client slower than the read timeout
    // still completes — partial frames survive across timeouts)
    stream.set_read_timeout(Some(coord.net_read_timeout()))?;
    let max_frame = coord.max_frame_bytes();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = Vec::new();
    while read_frame(&mut reader, &mut line, stop, max_frame) {
        {
            // invalid UTF-8 falls through to process_line's "bad json"
            // error response rather than killing the connection
            let text = String::from_utf8_lossy(&line);
            let trimmed = text.trim();
            if !trimmed.is_empty() {
                let resp = process_line(trimmed, coord);
                writer.write_all(json::to_string(&resp).as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                served.fetch_add(1, Ordering::SeqCst);
            }
        }
        // a full frame was consumed; partial frames only survive inside
        // read_frame, across timeouts
        line.clear();
    }
    Ok(())
}

/// Pure jittered-exponential backoff schedule for `NetClient` retries:
/// attempt `k` sleeps `base * 2^k` capped at `max`, scaled by a
/// deterministic jitter in [0.5, 1.0) hashed from `(seed, attempt)` —
/// stateless, so the schedule is testable as plain values with no clock
/// or RNG plumbing (and two clients with different seeds never
/// thundering-herd in lockstep).
#[derive(Debug, Clone, Copy)]
pub struct BackoffSchedule {
    pub base: Duration,
    pub max: Duration,
    pub seed: u64,
}

impl Default for BackoffSchedule {
    fn default() -> Self {
        BackoffSchedule {
            base: Duration::from_millis(10),
            max: Duration::from_secs(1),
            seed: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

impl BackoffSchedule {
    /// Sleep before retry number `attempt` (0-based).
    pub fn delay(&self, attempt: u32) -> Duration {
        let full = self.base.saturating_mul(1u32 << attempt.min(16)).min(self.max);
        // splitmix64 finalizer over (seed, attempt): stateless jitter
        let mut z =
            self.seed.wrapping_add((attempt as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        let frac = 0.5 + (z >> 11) as f64 / (1u64 << 53) as f64 / 2.0; // [0.5, 1.0)
        full.mul_f64(frac)
    }
}

/// Minimal blocking client for examples/tests.  The versioned surface:
/// `request` emits legacy v1 string-mode frames (the shim keeps old
/// clients working), `request_spec` emits v2 typed-policy frames.
///
/// Retry is opt-in: `retries(n)` arms up to `n` transparent retries on
/// `busy` responses (explicit backpressure) and on connection loss
/// (reset/EOF mid-round-trip, with an automatic reconnect) — the
/// hand-rolled retry loops the bench drivers used to carry.  Each retry
/// sleeps a `BackoffSchedule` step (`backoff(base, max)` to tune).
/// Terminal errors (`ok: false` without `busy`) are never retried.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    addr: std::net::SocketAddr,
    retries: u32,
    backoff: BackoffSchedule,
}

impl NetClient {
    /// Highest protocol version this client speaks (`request_spec`).
    pub const PROTOCOL: u8 = 2;

    pub fn connect(addr: &std::net::SocketAddr) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(NetClient {
            reader: BufReader::new(stream),
            writer,
            addr: *addr,
            retries: 0,
            backoff: BackoffSchedule::default(),
        })
    }

    /// Retry up to `n` times on `busy` responses and connection loss.
    pub fn retries(mut self, n: u32) -> NetClient {
        self.retries = n;
        self
    }

    /// Tune the retry backoff schedule (jitter seed stays the default).
    pub fn backoff(mut self, base: Duration, max: Duration) -> NetClient {
        self.backoff.base = base;
        self.backoff.max = max;
        self
    }

    /// Legacy v1 frame: whole-model mode by name (server desugars it to
    /// the mode's uniform policy).
    pub fn request(&mut self, task: &str, mode: &str, ids: &[i32]) -> Result<Value> {
        let req = json::obj(vec![
            ("task", Value::String(task.into())),
            ("mode", Value::String(mode.into())),
            ("ids", Value::Array(ids.iter().map(|x| json::num(*x as f64)).collect())),
        ]);
        self.round_trip(&req)
    }

    /// v2 frame: typed request spec with a policy by name or inline.
    pub fn request_spec(&mut self, spec: &RequestSpec) -> Result<Value> {
        self.round_trip(&request_to_json(spec))
    }

    fn round_trip(&mut self, req: &Value) -> Result<Value> {
        let frame = json::to_string(req);
        let mut attempt = 0u32;
        loop {
            match self.send_recv(&frame) {
                Ok(v) => {
                    let busy = v.get("busy").and_then(Value::as_bool) == Some(true);
                    if !busy || attempt >= self.retries {
                        return Ok(v);
                    }
                }
                // connection loss mid-round-trip: reconnect, then retry
                // through the same backoff schedule; anything else (or a
                // failed reconnect) propagates
                Err(e) => {
                    if attempt >= self.retries || !self.reconnect() {
                        return Err(e);
                    }
                }
            }
            std::thread::sleep(self.backoff.delay(attempt));
            attempt += 1;
        }
    }

    fn reconnect(&mut self) -> bool {
        let Ok(stream) = TcpStream::connect(self.addr) else { return false };
        let Ok(writer) = stream.try_clone() else { return false };
        self.reader = BufReader::new(stream);
        self.writer = writer;
        true
    }

    fn send_recv(&mut self, frame: &str) -> Result<Value> {
        self.writer.write_all(frame.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "connection closed before a response arrived");
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_stay_unpadded_and_bounds_checked() {
        // the wire layer must not pad: the real length is the batching
        // signal (padding here would pin every request to the top class)
        let v = json::parse(r#"{"ids": [1, 2, 3]}"#).unwrap();
        let ids = ids_from(&v, "ids", 6).unwrap().unwrap();
        assert_eq!(ids, vec![1, 2, 3]);
        let too_long = json::parse(r#"{"ids": [1,2,3,4,5,6,7]}"#).unwrap();
        assert!(ids_from(&too_long, "ids", 6).is_err());
        assert!(ids_from(&v, "type_ids", 6).unwrap().is_none());
        // deliberate v1 contract change rider: `"ids": []` used to be
        // padded to a full-PAD row and served garbage logits; it now
        // stays empty here and admission rejects it with a typed error
        let empty = json::parse(r#"{"ids": []}"#).unwrap();
        assert_eq!(ids_from(&empty, "ids", 6).unwrap().unwrap(), Vec::<i32>::new());
    }

    #[test]
    fn v1_shim_desugars_to_uniform_policy() {
        let v = json::parse(r#"{"task": "sst2", "mode": "m3", "ids": [1, 2]}"#).unwrap();
        let (spec, version) = parse_request(&v, 4).unwrap();
        assert_eq!(version, 1);
        assert_eq!(spec.task, "sst2");
        assert_eq!(spec.policy, Some(PolicyRef::Named("m3".into())));
        assert_eq!(spec.ids, vec![1, 2], "v1 frames keep their real length too");
        assert!(spec.type_ids.is_none());

        // a v1 frame with no mode is an error (no silent precision guess)
        let v = json::parse(r#"{"task": "sst2", "ids": [1]}"#).unwrap();
        let err = format!("{:#}", parse_request(&v, 4).unwrap_err());
        assert!(err.contains("missing \"mode\""), "{err}");

        // a v2 frame may omit the policy: default route, explicit version
        let v = json::parse(r#"{"v": 2, "task": "sst2", "ids": [1]}"#).unwrap();
        let (spec, version) = parse_request(&v, 4).unwrap();
        assert_eq!(version, 2);
        assert!(spec.policy.is_none());
    }

    #[test]
    fn v2_named_and_inline_policy_frames() {
        let v = json::parse(
            r#"{"v": 2, "task": "sst2", "policy": "attn-out-fp", "ids": [1, 2]}"#,
        )
        .unwrap();
        let (spec, version) = parse_request(&v, 4).unwrap();
        assert_eq!(version, 2);
        assert_eq!(spec.policy, Some(PolicyRef::Named("attn-out-fp".into())));

        let v = json::parse(
            r#"{"v": 2, "task": "sst2",
                "policy": {"base": "m3", "overrides": [["attn_output", "fp"]],
                           "fallback": ["m1", "fp"]},
                "ids": [1], "type_ids": [0]}"#,
        )
        .unwrap();
        let (spec, version) = parse_request(&v, 4).unwrap();
        assert_eq!(version, 2);
        let want = PolicyDraft::base("m3")
            .with_override("attn_output", "fp")
            .with_fallback("m1")
            .with_fallback("fp");
        assert_eq!(spec.policy, Some(PolicyRef::Inline(want)));
        assert_eq!(spec.type_ids, Some(vec![0]));
    }

    #[test]
    fn v1_to_v2_round_trip_through_serializer() {
        // v1 frame -> spec -> v2 frame -> spec: same route, same payload
        let v1 = json::parse(r#"{"task": "cola", "mode": "m1", "ids": [5, 6]}"#).unwrap();
        let (spec1, ver1) = parse_request(&v1, 3).unwrap();
        assert_eq!(ver1, 1);
        let v2 = request_to_json(&spec1);
        let (spec2, ver2) = parse_request(&v2, 3).unwrap();
        assert_eq!(ver2, 2);
        assert_eq!(spec2.task, spec1.task);
        assert_eq!(spec2.policy, spec1.policy);
        assert_eq!(spec2.ids, spec1.ids);
    }

    #[test]
    fn read_frame_keeps_partial_frame_across_read_timeouts() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // first half of the frame, then a silence longer than the
            // server's 200 ms read timeout, then the rest plus a second
            // frame — the regression dropped the first half on timeout
            s.write_all(b"{\"task\":\"s").unwrap();
            s.flush().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(550));
            s.write_all(b"st2\"}\n{\"second\":1}\n").unwrap();
            s.flush().unwrap();
            s
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(std::time::Duration::from_millis(200))).unwrap();
        let stop = AtomicBool::new(false);
        let mut reader = BufReader::new(stream);
        let mut line = Vec::new();
        assert!(read_frame(&mut reader, &mut line, &stop, 1 << 20));
        assert_eq!(std::str::from_utf8(&line).unwrap().trim(), "{\"task\":\"sst2\"}");
        line.clear();
        assert!(read_frame(&mut reader, &mut line, &stop, 1 << 20));
        assert_eq!(std::str::from_utf8(&line).unwrap().trim(), "{\"second\":1}");
        line.clear();
        // peer closes: clean EOF, no frame
        drop(writer.join().unwrap());
        assert!(!read_frame(&mut reader, &mut line, &stop, 1 << 20));
        assert!(line.is_empty());
    }

    #[test]
    fn read_frame_survives_timeout_inside_multibyte_char() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // "café" split between the two bytes of the 'é' (0xC3 0xA9):
            // a String-based read_line would discard the whole appended
            // head when the timeout fires on the dangling 0xC3
            s.write_all(b"{\"task\":\"caf\xc3").unwrap();
            s.flush().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(550));
            s.write_all(b"\xa9\"}\n").unwrap();
            s.flush().unwrap();
            s
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(std::time::Duration::from_millis(200))).unwrap();
        let stop = AtomicBool::new(false);
        let mut reader = BufReader::new(stream);
        let mut line = Vec::new();
        assert!(read_frame(&mut reader, &mut line, &stop, 1 << 20));
        assert_eq!(std::str::from_utf8(&line).unwrap().trim(), "{\"task\":\"café\"}");
        drop(writer.join().unwrap());
    }

    #[test]
    fn read_frame_rejects_runaway_unterminated_frame() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // stream well past the frame cap without ever sending a
            // newline; the write fails once the server hangs up
            let chunk = vec![b'a'; 64 * 1024];
            for _ in 0..40 {
                if s.write_all(&chunk).is_err() {
                    break;
                }
            }
            let _ = s.flush();
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(std::time::Duration::from_millis(200))).unwrap();
        let stop = AtomicBool::new(false);
        let mut reader = BufReader::new(stream);
        let mut line = Vec::new();
        assert!(
            !read_frame(&mut reader, &mut line, &stop, 1 << 20),
            "runaway frame must be rejected"
        );
        assert!(line.len() <= (1 << 20) + 1);
        drop(reader); // hang up so the writer unblocks
        writer.join().unwrap();
    }

    #[test]
    fn read_frame_returns_final_unterminated_frame_at_eof() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"{\"no\":\"newline\"}").unwrap();
            s.flush().unwrap();
            // close without a trailing newline
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(std::time::Duration::from_millis(200))).unwrap();
        let stop = AtomicBool::new(false);
        let mut reader = BufReader::new(stream);
        let mut line = Vec::new();
        writer.join().unwrap();
        assert!(read_frame(&mut reader, &mut line, &stop, 1 << 20));
        assert_eq!(std::str::from_utf8(&line).unwrap().trim(), "{\"no\":\"newline\"}");
        line.clear();
        assert!(!read_frame(&mut reader, &mut line, &stop, 1 << 20));
    }

    #[test]
    fn read_frame_with_configured_short_timeout_still_completes() {
        use std::io::Write;
        // a 40 ms configured timeout (ServerConfig::net_read_timeout is
        // plumbed to the socket in handle_conn) with a client pausing
        // 150 ms mid-frame: several timeouts fire, the partial frame
        // survives them all
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"{\"task\":\"s").unwrap();
            s.flush().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(150));
            s.write_all(b"st2\"}\n").unwrap();
            s.flush().unwrap();
            s
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(std::time::Duration::from_millis(40))).unwrap();
        let stop = AtomicBool::new(false);
        let mut reader = BufReader::new(stream);
        let mut line = Vec::new();
        assert!(read_frame(&mut reader, &mut line, &stop, 1 << 20));
        assert_eq!(std::str::from_utf8(&line).unwrap().trim(), "{\"task\":\"sst2\"}");
        drop(writer.join().unwrap());
    }

    #[test]
    fn read_frame_respects_configured_frame_cap() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // 100 bytes, no newline: over a 64-byte cap, under the default
            let _ = s.write_all(&[b'x'; 100]);
            let _ = s.flush();
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(std::time::Duration::from_millis(40))).unwrap();
        let stop = AtomicBool::new(false);
        let mut reader = BufReader::new(stream);
        let mut line = Vec::new();
        assert!(
            !read_frame(&mut reader, &mut line, &stop, 64),
            "configured 64-byte cap must reject the frame"
        );
        assert!(line.len() <= 65);
        drop(reader);
        writer.join().unwrap();
    }

    #[test]
    fn deadline_ms_is_v2_only_and_round_trips() {
        let seq = 4;
        let v = json::parse(r#"{"v": 2, "task": "t", "ids": [1], "deadline_ms": 250}"#).unwrap();
        let (spec, version) = parse_request(&v, seq).unwrap();
        assert_eq!(version, 2);
        assert_eq!(spec.deadline, Some(std::time::Duration::from_millis(250)));
        // the client serializer emits it back out
        let frame = request_to_json(&spec);
        assert_eq!(frame.get("deadline_ms").unwrap().as_usize(), Some(250));
        let (again, _) = parse_request(&frame, seq).unwrap();
        assert_eq!(again.deadline, spec.deadline);

        // v1 frames do not grow new fields through the shim
        let v1 =
            json::parse(r#"{"task": "t", "mode": "fp", "ids": [1], "deadline_ms": 250}"#).unwrap();
        let err = format!("{:#}", parse_request(&v1, seq).unwrap_err());
        assert!(err.contains("deadline_ms") && err.contains("v2"), "{err}");

        // zero / sub-millisecond budgets are nonsense, not "no deadline"
        // (0.5 would truncate to an expire-on-arrival 0 ms budget)
        for bad in [
            r#"{"v": 2, "task": "t", "ids": [1], "deadline_ms": 0}"#,
            r#"{"v": 2, "task": "t", "ids": [1], "deadline_ms": 0.5}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(parse_request(&v, seq).is_err(), "{bad}");
        }
    }

    #[test]
    fn backoff_schedule_is_bounded_deterministic_jittered_exponential() {
        let b = BackoffSchedule {
            base: Duration::from_millis(10),
            max: Duration::from_millis(200),
            seed: 42,
        };
        // each attempt's delay sits in [full/2, full], full = base*2^k
        // capped at max — the whole schedule checked as pure values
        for k in 0..10u32 {
            let full =
                Duration::from_millis(10).saturating_mul(1u32 << k).min(Duration::from_millis(200));
            let d = b.delay(k);
            assert!(d >= full.mul_f64(0.5), "attempt {k}: {d:?} under half of {full:?}");
            assert!(d <= full, "attempt {k}: {d:?} over {full:?}");
        }
        // deterministic per (seed, attempt); different seeds de-correlate
        assert_eq!(b.delay(3), b.delay(3));
        let c = BackoffSchedule { seed: 43, ..b };
        assert_ne!(b.delay(3), c.delay(3), "seeds 42/43 jitter identically");
        // deep attempts stay capped (and the shift never overflows)
        assert!(b.delay(40) <= Duration::from_millis(200));
    }

    #[test]
    fn client_retries_busy_then_reconnects_on_reset() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // connection 1: answer busy once, then hang up mid-request
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            s.write_all(b"{\"ok\":false,\"busy\":true,\"error\":\"busy\"}\n").unwrap();
            s.flush().unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            drop(s); // connection reset before a response
            // connection 2 (the client's reconnect): answer ok
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            s.write_all(b"{\"ok\":true,\"logits\":[]}\n").unwrap();
            s.flush().unwrap();
        });
        let mut c = NetClient::connect(&addr)
            .unwrap()
            .retries(4)
            .backoff(Duration::from_millis(1), Duration::from_millis(4));
        let resp = c.request("t", "m", &[1]).unwrap();
        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true));
        server.join().unwrap();
    }

    #[test]
    fn client_without_retries_surfaces_busy_verbatim() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            s.write_all(b"{\"ok\":false,\"busy\":true,\"error\":\"busy\"}\n").unwrap();
            s.flush().unwrap();
        });
        let mut c = NetClient::connect(&addr).unwrap(); // retry is opt-in
        let resp = c.request("t", "m", &[1]).unwrap();
        assert_eq!(resp.get("busy").and_then(Value::as_bool), Some(true));
        server.join().unwrap();
    }

    #[test]
    fn frame_version_errors() {
        let seq = 4;
        let bad_ver = json::parse(r#"{"v": 3, "task": "t", "mode": "fp", "ids": [1]}"#).unwrap();
        let err = parse_request(&bad_ver, seq).unwrap_err().to_string();
        assert!(err.contains("unsupported protocol version 3"), "{err}");

        // mixing route fields across versions is an error, not a guess
        let v1_policy =
            json::parse(r#"{"v": 1, "task": "t", "policy": "p", "ids": [1]}"#).unwrap();
        assert!(parse_request(&v1_policy, seq).is_err());
        let v2_mode = json::parse(r#"{"v": 2, "task": "t", "mode": "m3", "ids": [1]}"#).unwrap();
        assert!(parse_request(&v2_mode, seq).is_err());

        // versionless frame with a policy infers v2
        let v = json::parse(r#"{"task": "t", "policy": "p", "ids": [1]}"#).unwrap();
        assert_eq!(parse_request(&v, seq).unwrap().1, 2);

        let missing_ids = json::parse(r#"{"task": "t", "mode": "fp"}"#).unwrap();
        assert!(parse_request(&missing_ids, seq).is_err());
    }
}
