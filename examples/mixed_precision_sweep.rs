//! Mixed-precision trade-off sweep (paper §2.3): for one task, sweep the
//! quantization level (FP/M1/M2/M3) x calibration budget x clipping
//! percentile and print the accuracy / projected-A100-latency frontier.
//!
//!     cargo run --release --example mixed_precision_sweep [task]

use anyhow::Result;
use zqhero::bench::Table;
use zqhero::calib::truncate_history;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::perfmodel;
use zqhero::runtime::Runtime;

fn main() -> Result<()> {
    let tname = std::env::args().nth(1).unwrap_or_else(|| "cola".into());
    let dir = std::path::PathBuf::from("artifacts");
    let mut rt = Runtime::new(Manifest::load(&dir)?)?;
    let task = rt.manifest.task(&tname)?.clone();
    let hist = eh::ensure_calibration(&mut rt, &task, 100, false)?;

    let bert = perfmodel::bert_base();
    let mode_switches: std::collections::BTreeMap<String, zqhero::model::Switches> =
        rt.manifest.modes.iter().map(|(k, v)| (k.clone(), v.switches)).collect();
    let proj = move |mode: &str| {
        perfmodel::model_time_us(&bert, &mode_switches[mode], 16, 128)
    };

    fn fmt_metrics(vals: &std::collections::BTreeMap<String, f64>) -> String {
        vals.iter()
            .map(|(k, v)| format!("{k}={:.2}", v * 100.0))
            .collect::<Vec<_>>()
            .join(" ")
    }

    println!("== mixed-precision sweep on {tname} (paper §2.3) ==\n");
    let mut t = Table::new(&[
        "mode", "calib batches", "clip pct", "metrics", "proj A100 us (BERT_base b16)",
        "proj speedup",
    ]);
    let fp_us = proj("fp");

    // FP row
    {
        let vals = eh::eval_task(&mut rt, &task, "fp", 100, 100.0)?;
        t.row(vec![
            eh::mode_label("fp"),
            "-".into(),
            "-".into(),
            fmt_metrics(&vals),
            format!("{fp_us:.0}"),
            "1.00x".into(),
        ]);
    }

    for mode in ["m1", "m2", "m3"] {
        for (batches, pct) in [(100usize, 100.0f64), (5, 100.0), (100, 99.9)] {
            let h = truncate_history(&hist, batches);
            let ckpt = eh::quantize_task(&mut rt, &task, mode, &h, pct,
                                         Some(&format!("sweep{batches}p{pct}")))?;
            rt.upload_checkpoint(&task.name, mode, &ckpt)?;
            let mut vals = std::collections::BTreeMap::new();
            for split in task.splits.keys().filter(|s| *s != "train") {
                for (k, v) in eh::eval_split(&mut rt, &task, mode, split)? {
                    vals.insert(if split == "dev" { k } else { format!("{k}_mm") }, v);
                }
            }
            let us = proj(mode);
            t.row(vec![
                eh::mode_label(mode),
                batches.to_string(),
                format!("{pct}"),
                fmt_metrics(&vals),
                format!("{us:.0}"),
                format!("{:.2}x", fp_us / us),
            ]);
        }
    }
    t.print();
    println!("\n(accuracy: SynGLUE dev; latency: analytic A100 roofline, DESIGN.md §2)");
    Ok(())
}
