//! heromck's instrumented sync primitives: drop-in doubles for the
//! `std::sync` surface the serving spine uses, wired into the
//! deterministic scheduler.
//!
//! Every type wraps its real `std` counterpart for storage, and only
//! consults the model when the calling thread belongs to an active
//! model run (`mck::current()`); outside a run the wrappers degrade to
//! plain `std` behaviour, so code paths that construct these types in
//! ordinary tests keep working under `--features heromck`.
//!
//! Objects register with the run lazily, at first modeled use, under
//! the scheduler baton — so object ids (and therefore decision traces)
//! are identical across replays of the same schedule.  Registrations
//! carry the run's epoch and go stale with it; an object that outlives
//! one run re-registers in the next.
//!
//! Fidelity notes (documented in DESIGN.md §5.12):
//! * atomics keep full store histories with vector clocks — `Relaxed`
//!   loads may observe any coherence-visible store (an explorer value
//!   decision), `Acquire` loads join the clock of `Release`/`SeqCst`
//!   stores, `SeqCst` loads read the newest store (an approximation of
//!   the single total order);
//! * `recv_timeout` never parks: with the queue empty it returns
//!   `Timeout` immediately (timeouts are not modeled as time);
//! * condvars do not produce spurious wakeups;
//! * poisoning never happens inside a model run — a panicking model
//!   thread fails the whole schedule instead.

use std::sync::{Condvar as StdCondvar, Mutex as StdMutex, RwLock as StdRwLock};
use std::sync::{LockResult, PoisonError};

use super::sched::{BlockReason, Inner, PointKind, Status, Step};
use super::{current, RunHandle};

/// (epoch, object id) of the run this object last registered with.
pub(crate) type Reg = StdMutex<(u64, usize)>;

pub(crate) fn reg_new() -> Reg {
    StdMutex::new((0, 0))
}

fn reg_get(reg: &Reg, epoch: u64) -> Option<usize> {
    let g = match reg.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    if g.0 == epoch {
        Some(g.1)
    } else {
        None
    }
}

fn reg_set(reg: &Reg, epoch: u64, id: usize) {
    let mut g = match reg.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    *g = (epoch, id);
}

fn ensure_mutex(inner: &mut Inner, epoch: u64, reg: &Reg, class: Option<&'static str>) -> usize {
    match reg_get(reg, epoch) {
        Some(id) => id,
        None => {
            let id = inner.model.alloc_mutex(class);
            reg_set(reg, epoch, id);
            id
        }
    }
}

fn ensure_rwlock(inner: &mut Inner, epoch: u64, reg: &Reg, class: Option<&'static str>) -> usize {
    match reg_get(reg, epoch) {
        Some(id) => id,
        None => {
            let id = inner.model.alloc_rwlock(class);
            reg_set(reg, epoch, id);
            id
        }
    }
}

fn ensure_condvar(inner: &mut Inner, epoch: u64, reg: &Reg) -> usize {
    match reg_get(reg, epoch) {
        Some(id) => id,
        None => {
            let id = inner.model.alloc_condvar();
            reg_set(reg, epoch, id);
            id
        }
    }
}

// ------------------------------------------------------------------ Mutex

pub struct Mutex<T> {
    class: Option<&'static str>,
    reg: Reg,
    data: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Mutex<T> {
        Mutex { class: None, reg: reg_new(), data: StdMutex::new(t) }
    }

    /// A mutex carrying a herolint lock-class name, so acquisitions feed
    /// the runtime lock-order witness.  Model-test only: production code
    /// keeps its classes in `.expect("label")` strings, which herolint
    /// reads statically.
    pub fn new_named(class: &'static str, t: T) -> Mutex<T> {
        Mutex { class: Some(class), reg: reg_new(), data: StdMutex::new(t) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some(h) = current() {
            let epoch = h.ctl.epoch;
            let tid = h.tid;
            let id = h.ctl.op(tid, "mutex.lock", |inner, _| {
                let id = ensure_mutex(inner, epoch, &self.reg, self.class);
                if inner.model.mutexes[id].holder.is_none() {
                    inner.model.lock_mutex(tid, id);
                    Step::Done(id)
                } else {
                    Step::Block(BlockReason::MutexLock(id))
                }
            });
            // the model admitted us, so the real lock is uncontended
            let real = match self.data.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            Ok(MutexGuard { lock: self, real: Some(real), model: Some((h, id)) })
        } else {
            match self.data.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, real: Some(g), model: None }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    real: Some(p.into_inner()),
                    model: None,
                })),
            }
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.data.into_inner()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.data.get_mut()
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.data.fmt(f)
    }
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    real: Option<std::sync::MutexGuard<'a, T>>,
    model: Option<(RunHandle, usize)>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.real.as_ref().expect("guard holds the real lock")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.real.as_mut().expect("guard holds the real lock")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((h, id)) = self.model.take() {
            h.ctl.op_release(h.tid, "mutex.unlock", |inner| {
                inner.model.unlock_mutex(h.tid, id);
                inner.wake_where(|r| matches!(r, BlockReason::MutexLock(i) if *i == id));
            });
        }
        // the real guard (if any) drops with the struct, after the
        // model released — the next holder is only scheduled later
    }
}

// ---------------------------------------------------------------- Condvar

pub struct Condvar {
    reg: Reg,
    real: StdCondvar,
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar { reg: reg_new(), real: StdCondvar::new() }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match guard.model.take() {
            Some((h, mid)) => {
                let lock = guard.lock;
                // release the real mutex before parking in the model, so
                // the model-admitted next holder can take it for real
                guard.real = None;
                drop(guard);
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                h.ctl.op(tid, "condvar.wait", |inner, attempt| {
                    if attempt == 0 {
                        let cid = ensure_condvar(inner, epoch, &self.reg);
                        inner.model.unlock_mutex(tid, mid);
                        inner.wake_where(|r| matches!(r, BlockReason::MutexLock(i) if *i == mid));
                        inner.model.condvars[cid].waiting.push((tid, mid));
                        Step::Block(BlockReason::CondWait(cid))
                    } else if inner.model.mutexes[mid].holder.is_none() {
                        // notified; reacquire the paired mutex
                        inner.model.lock_mutex(tid, mid);
                        Step::Done(())
                    } else {
                        Step::Block(BlockReason::MutexLock(mid))
                    }
                });
                let real = match lock.data.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                Ok(MutexGuard { lock, real: Some(real), model: Some((h, mid)) })
            }
            None => {
                let lock = guard.lock;
                let real = guard.real.take().expect("guard holds the real lock");
                drop(guard);
                match self.real.wait(real) {
                    Ok(g) => Ok(MutexGuard { lock, real: Some(g), model: None }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        lock,
                        real: Some(p.into_inner()),
                        model: None,
                    })),
                }
            }
        }
    }

    pub fn notify_one(&self) {
        if let Some(h) = current() {
            let epoch = h.ctl.epoch;
            let tid = h.tid;
            h.ctl.op(tid, "condvar.notify_one", |inner, _| {
                let cid = ensure_condvar(inner, epoch, &self.reg);
                let n = inner.model.condvars[cid].waiting.len();
                if n > 0 {
                    // which waiter wakes is a value decision
                    let idx = inner.decide(PointKind::Value, n, false, &[]);
                    let (wtid, _mid) = inner.model.condvars[cid].waiting.remove(idx);
                    inner.threads[wtid].status = Status::Ready;
                }
                Step::Done(())
            });
        } else {
            self.real.notify_one();
        }
    }

    pub fn notify_all(&self) {
        if let Some(h) = current() {
            let epoch = h.ctl.epoch;
            let tid = h.tid;
            h.ctl.op(tid, "condvar.notify_all", |inner, _| {
                let cid = ensure_condvar(inner, epoch, &self.reg);
                let waiters = std::mem::take(&mut inner.model.condvars[cid].waiting);
                for (wtid, _mid) in waiters {
                    inner.threads[wtid].status = Status::Ready;
                }
                Step::Done(())
            });
        } else {
            self.real.notify_all();
        }
    }
}

// ----------------------------------------------------------------- RwLock

pub struct RwLock<T> {
    class: Option<&'static str>,
    reg: Reg,
    data: StdRwLock<T>,
}

impl<T> RwLock<T> {
    pub fn new(t: T) -> RwLock<T> {
        RwLock { class: None, reg: reg_new(), data: StdRwLock::new(t) }
    }

    pub fn new_named(class: &'static str, t: T) -> RwLock<T> {
        RwLock { class: Some(class), reg: reg_new(), data: StdRwLock::new(t) }
    }

    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        if let Some(h) = current() {
            let epoch = h.ctl.epoch;
            let tid = h.tid;
            let id = h.ctl.op(tid, "rwlock.read", |inner, _| {
                let id = ensure_rwlock(inner, epoch, &self.reg, self.class);
                if inner.model.rwlocks[id].writer.is_none() {
                    inner.model.lock_rw_read(tid, id);
                    Step::Done(id)
                } else {
                    Step::Block(BlockReason::RwRead(id))
                }
            });
            let real = match self.data.read() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            Ok(RwLockReadGuard { real: Some(real), model: Some((h, id)) })
        } else {
            match self.data.read() {
                Ok(g) => Ok(RwLockReadGuard { real: Some(g), model: None }),
                Err(p) => Err(PoisonError::new(RwLockReadGuard {
                    real: Some(p.into_inner()),
                    model: None,
                })),
            }
        }
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        if let Some(h) = current() {
            let epoch = h.ctl.epoch;
            let tid = h.tid;
            let id = h.ctl.op(tid, "rwlock.write", |inner, _| {
                let id = ensure_rwlock(inner, epoch, &self.reg, self.class);
                let rw = &inner.model.rwlocks[id];
                if rw.writer.is_none() && rw.readers.is_empty() {
                    inner.model.lock_rw_write(tid, id);
                    Step::Done(id)
                } else {
                    Step::Block(BlockReason::RwWrite(id))
                }
            });
            let real = match self.data.write() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            Ok(RwLockWriteGuard { real: Some(real), model: Some((h, id)) })
        } else {
            match self.data.write() {
                Ok(g) => Ok(RwLockWriteGuard { real: Some(g), model: None }),
                Err(p) => Err(PoisonError::new(RwLockWriteGuard {
                    real: Some(p.into_inner()),
                    model: None,
                })),
            }
        }
    }
}

pub struct RwLockReadGuard<'a, T> {
    real: Option<std::sync::RwLockReadGuard<'a, T>>,
    model: Option<(RunHandle, usize)>,
}

impl<T> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.real.as_ref().expect("guard holds the real lock")
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((h, id)) = self.model.take() {
            h.ctl.op_release(h.tid, "rwlock.read-unlock", |inner| {
                inner.model.unlock_rw_read(h.tid, id);
                if inner.model.rwlocks[id].readers.is_empty() {
                    inner.wake_where(|r| matches!(r, BlockReason::RwWrite(i) if *i == id));
                }
            });
        }
    }
}

pub struct RwLockWriteGuard<'a, T> {
    real: Option<std::sync::RwLockWriteGuard<'a, T>>,
    model: Option<(RunHandle, usize)>,
}

impl<T> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.real.as_ref().expect("guard holds the real lock")
    }
}

impl<T> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.real.as_mut().expect("guard holds the real lock")
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((h, id)) = self.model.take() {
            h.ctl.op_release(h.tid, "rwlock.write-unlock", |inner| {
                inner.model.unlock_rw_write(h.tid, id);
                inner.wake_where(|r| {
                    matches!(r, BlockReason::RwWrite(i) if *i == id)
                        || matches!(r, BlockReason::RwRead(i) if *i == id)
                });
            });
        }
    }
}

// ---------------------------------------------------------------- atomics

pub mod atomic {
    pub use std::sync::atomic::Ordering;

    use std::sync::atomic as std_atomic;

    use super::super::current;
    use super::super::sched::{StoreRec, Step};
    use super::{reg_new, reg_get, reg_set, Reg};

    fn is_acquire(ord: Ordering) -> bool {
        matches!(ord, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
    }

    fn is_release(ord: Ordering) -> bool {
        matches!(ord, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
    }

    fn ensure_atomic(
        inner: &mut super::Inner,
        epoch: u64,
        reg: &Reg,
        init: &mut Option<impl FnOnce() -> u64>,
    ) -> usize {
        match reg_get(reg, epoch) {
            Some(id) => id,
            None => {
                let v = init.take().map(|f| f()).unwrap_or(0);
                let id = inner.model.alloc_atomic(v);
                reg_set(reg, epoch, id);
                id
            }
        }
    }

    /// Modeled load; `None` when the caller is not a model thread.
    fn model_load(reg: &Reg, init: impl FnOnce() -> u64, ord: Ordering) -> Option<u64> {
        let h = current()?;
        let epoch = h.ctl.epoch;
        let tid = h.tid;
        let mut init = Some(init);
        Some(h.ctl.op(tid, "atomic.load", move |inner, _| {
            let id = ensure_atomic(inner, epoch, reg, &mut init);
            // visibility floor: newest store this thread has observed
            // (coherence) or that happens-before it (anything older is
            // hidden by an intervening hb store)
            let (first, len) = {
                let a = &inner.model.atomics[id];
                let my = &inner.model.clocks[tid];
                let mut first = a.seen(tid);
                for (j, s) in a.stores.iter().enumerate() {
                    if j > first && s.clock.leq(my) {
                        first = j;
                    }
                }
                (first, a.stores.len())
            };
            let idx = if matches!(ord, Ordering::SeqCst) {
                // approximation of the SC total order: the newest store
                len - 1
            } else {
                let cands: Vec<usize> = (first..len).rev().collect();
                inner.decide_store(&cands)
            };
            let (val, rel_clock) = {
                let s = &inner.model.atomics[id].stores[idx];
                let rel = if s.release && is_acquire(ord) { Some(s.clock.clone()) } else { None };
                (s.val, rel)
            };
            if let Some(c) = rel_clock {
                inner.model.clocks[tid].join(&c);
            }
            inner.model.atomics[id].note_seen(tid, idx);
            Step::Done(val)
        }))
    }

    /// Modeled store; returns false when not in a model run.
    fn model_store(reg: &Reg, init: impl FnOnce() -> u64, val: u64, ord: Ordering) -> bool {
        let h = match current() {
            Some(h) => h,
            None => return false,
        };
        let epoch = h.ctl.epoch;
        let tid = h.tid;
        let mut init = Some(init);
        h.ctl.op(tid, "atomic.store", move |inner, _| {
            let id = ensure_atomic(inner, epoch, reg, &mut init);
            inner.model.clocks[tid].tick(tid);
            let clock = inner.model.clocks[tid].clone();
            let a = &mut inner.model.atomics[id];
            a.stores.push(StoreRec { val, clock, release: is_release(ord) });
            let idx = a.stores.len() - 1;
            a.note_seen(tid, idx);
            Step::Done(())
        });
        true
    }

    /// Modeled read-modify-write (reads the newest store, like the real
    /// thing); returns the old value, or `None` when not in a model run.
    fn model_rmw(reg: &Reg, init: impl FnOnce() -> u64, ord: Ordering, f: impl Fn(u64) -> u64) -> Option<u64> {
        let h = current()?;
        let epoch = h.ctl.epoch;
        let tid = h.tid;
        let mut init = Some(init);
        Some(h.ctl.op(tid, "atomic.rmw", move |inner, _| {
            let id = ensure_atomic(inner, epoch, reg, &mut init);
            let (old, rel_clock) = {
                let s = inner.model.atomics[id].stores.last().expect("atomic has an initial store");
                let rel = if s.release && is_acquire(ord) { Some(s.clock.clone()) } else { None };
                (s.val, rel)
            };
            if let Some(c) = rel_clock {
                inner.model.clocks[tid].join(&c);
            }
            inner.model.clocks[tid].tick(tid);
            let clock = inner.model.clocks[tid].clone();
            let a = &mut inner.model.atomics[id];
            a.stores.push(StoreRec { val: f(old), clock, release: is_release(ord) });
            let idx = a.stores.len() - 1;
            a.note_seen(tid, idx);
            Step::Done(old)
        }))
    }

    macro_rules! int_atomic {
        ($name:ident, $prim:ty, $std:ty) => {
            pub struct $name {
                real: $std,
                reg: Reg,
            }

            impl $name {
                pub fn new(v: $prim) -> $name {
                    $name { real: <$std>::new(v), reg: reg_new() }
                }

                pub fn load(&self, ord: Ordering) -> $prim {
                    match model_load(&self.reg, || self.real.load(Ordering::SeqCst) as u64, ord) {
                        Some(v) => v as $prim,
                        None => self.real.load(ord),
                    }
                }

                pub fn store(&self, v: $prim, ord: Ordering) {
                    if model_store(&self.reg, || self.real.load(Ordering::SeqCst) as u64, v as u64, ord) {
                        // mirror so fallback readers and re-registration
                        // see the newest store
                        self.real.store(v, Ordering::SeqCst);
                    } else {
                        self.real.store(v, ord);
                    }
                }

                pub fn swap(&self, v: $prim, ord: Ordering) -> $prim {
                    match model_rmw(&self.reg, || self.real.load(Ordering::SeqCst) as u64, ord, |_| v as u64) {
                        Some(old) => {
                            self.real.store(v, Ordering::SeqCst);
                            old as $prim
                        }
                        None => self.real.swap(v, ord),
                    }
                }

                pub fn fetch_add(&self, v: $prim, ord: Ordering) -> $prim {
                    match model_rmw(&self.reg, || self.real.load(Ordering::SeqCst) as u64, ord, |old| {
                        (old as $prim).wrapping_add(v) as u64
                    }) {
                        Some(old) => {
                            self.real.store((old as $prim).wrapping_add(v), Ordering::SeqCst);
                            old as $prim
                        }
                        None => self.real.fetch_add(v, ord),
                    }
                }

                pub fn fetch_sub(&self, v: $prim, ord: Ordering) -> $prim {
                    match model_rmw(&self.reg, || self.real.load(Ordering::SeqCst) as u64, ord, |old| {
                        (old as $prim).wrapping_sub(v) as u64
                    }) {
                        Some(old) => {
                            self.real.store((old as $prim).wrapping_sub(v), Ordering::SeqCst);
                            old as $prim
                        }
                        None => self.real.fetch_sub(v, ord),
                    }
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    self.real.fmt(f)
                }
            }
        };
    }

    int_atomic!(AtomicU16, u16, std_atomic::AtomicU16);
    int_atomic!(AtomicU64, u64, std_atomic::AtomicU64);
    int_atomic!(AtomicUsize, usize, std_atomic::AtomicUsize);

    pub struct AtomicBool {
        real: std_atomic::AtomicBool,
        reg: Reg,
    }

    impl AtomicBool {
        pub fn new(v: bool) -> AtomicBool {
            AtomicBool { real: std_atomic::AtomicBool::new(v), reg: reg_new() }
        }

        pub fn load(&self, ord: Ordering) -> bool {
            match model_load(&self.reg, || self.real.load(Ordering::SeqCst) as u64, ord) {
                Some(v) => v != 0,
                None => self.real.load(ord),
            }
        }

        pub fn store(&self, v: bool, ord: Ordering) {
            if model_store(&self.reg, || self.real.load(Ordering::SeqCst) as u64, v as u64, ord) {
                self.real.store(v, Ordering::SeqCst);
            } else {
                self.real.store(v, ord);
            }
        }

        pub fn swap(&self, v: bool, ord: Ordering) -> bool {
            match model_rmw(&self.reg, || self.real.load(Ordering::SeqCst) as u64, ord, |_| v as u64) {
                Some(old) => {
                    self.real.store(v, Ordering::SeqCst);
                    old != 0
                }
                None => self.real.swap(v, ord),
            }
        }
    }

    impl std::fmt::Debug for AtomicBool {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            self.real.fmt(f)
        }
    }
}

// --------------------------------------------------------------- channels

pub mod mpsc {
    pub use std::sync::mpsc::{RecvError, RecvTimeoutError, SendError, TryRecvError, TrySendError};

    use std::sync::Arc;
    use std::time::Duration;

    use super::super::current;
    use super::super::sched::{BlockReason, Step};
    use super::{reg_new, reg_get, Reg};

    struct ChanCtl {
        reg: Reg,
        cap: Option<usize>,
    }

    fn ensure_channel(inner: &mut super::Inner, epoch: u64, ctl: &ChanCtl) -> usize {
        match reg_get(&ctl.reg, epoch) {
            Some(id) => id,
            None => {
                let id = inner.model.alloc_channel(ctl.cap);
                super::reg_set(&ctl.reg, epoch, id);
                id
            }
        }
    }

    pub struct Sender<T> {
        real: std::sync::mpsc::Sender<T>,
        ctl: Arc<ChanCtl>,
    }

    pub struct SyncSender<T> {
        real: std::sync::mpsc::SyncSender<T>,
        ctl: Arc<ChanCtl>,
    }

    pub struct Receiver<T> {
        real: std::sync::mpsc::Receiver<T>,
        ctl: Arc<ChanCtl>,
    }

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let (tx, rx) = std::sync::mpsc::channel();
        let ctl = Arc::new(ChanCtl { reg: reg_new(), cap: None });
        (Sender { real: tx, ctl: ctl.clone() }, Receiver { real: rx, ctl })
    }

    /// Bounded channel.  The model treats `cap == 0` (rendezvous) as
    /// capacity 1 — the spine never uses rendezvous channels.
    pub fn sync_channel<T>(cap: usize) -> (SyncSender<T>, Receiver<T>) {
        let (tx, rx) = std::sync::mpsc::sync_channel(cap.max(1));
        let ctl = Arc::new(ChanCtl { reg: reg_new(), cap: Some(cap.max(1)) });
        (SyncSender { real: tx, ctl: ctl.clone() }, Receiver { real: rx, ctl })
    }

    impl<T> Sender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                let mut slot = Some(t);
                h.ctl.op(tid, "chan.send", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    if !inner.model.channels[id].rx_alive {
                        return Step::Done(Err(SendError(slot.take().expect("send payload"))));
                    }
                    inner.model.clocks[tid].tick(tid);
                    let clock = inner.model.clocks[tid].clone();
                    let ch = &mut inner.model.channels[id];
                    ch.len += 1;
                    ch.msg_clocks.push_back(clock);
                    let _ = self.real.send(slot.take().expect("send payload"));
                    inner.wake_where(|r| matches!(r, BlockReason::ChanRecv(i) if *i == id));
                    Step::Done(Ok(()))
                })
            } else {
                self.real.send(t)
            }
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Sender<T> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                h.ctl.op(tid, "chan.clone", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    inner.model.channels[id].senders += 1;
                    Step::Done(())
                });
            }
            Sender { real: self.real.clone(), ctl: self.ctl.clone() }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            drop_sender_side(&self.ctl);
        }
    }

    impl<T> SyncSender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                let mut slot = Some(t);
                h.ctl.op(tid, "chan.send", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    let ch = &inner.model.channels[id];
                    if !ch.rx_alive {
                        return Step::Done(Err(SendError(slot.take().expect("send payload"))));
                    }
                    if let Some(cap) = ch.cap {
                        if ch.len >= cap {
                            return Step::Block(BlockReason::ChanSend(id));
                        }
                    }
                    inner.model.clocks[tid].tick(tid);
                    let clock = inner.model.clocks[tid].clone();
                    let ch = &mut inner.model.channels[id];
                    ch.len += 1;
                    ch.msg_clocks.push_back(clock);
                    let _ = self.real.try_send(slot.take().expect("send payload"));
                    inner.wake_where(|r| matches!(r, BlockReason::ChanRecv(i) if *i == id));
                    Step::Done(Ok(()))
                })
            } else {
                self.real.send(t)
            }
        }

        pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                let mut slot = Some(t);
                h.ctl.op(tid, "chan.try_send", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    let ch = &inner.model.channels[id];
                    if !ch.rx_alive {
                        return Step::Done(Err(TrySendError::Disconnected(
                            slot.take().expect("send payload"),
                        )));
                    }
                    if let Some(cap) = ch.cap {
                        if ch.len >= cap {
                            return Step::Done(Err(TrySendError::Full(
                                slot.take().expect("send payload"),
                            )));
                        }
                    }
                    inner.model.clocks[tid].tick(tid);
                    let clock = inner.model.clocks[tid].clone();
                    let ch = &mut inner.model.channels[id];
                    ch.len += 1;
                    ch.msg_clocks.push_back(clock);
                    let _ = self.real.try_send(slot.take().expect("send payload"));
                    inner.wake_where(|r| matches!(r, BlockReason::ChanRecv(i) if *i == id));
                    Step::Done(Ok(()))
                })
            } else {
                self.real.try_send(t)
            }
        }
    }

    impl<T> Clone for SyncSender<T> {
        fn clone(&self) -> SyncSender<T> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                h.ctl.op(tid, "chan.clone", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    inner.model.channels[id].senders += 1;
                    Step::Done(())
                });
            }
            SyncSender { real: self.real.clone(), ctl: self.ctl.clone() }
        }
    }

    impl<T> Drop for SyncSender<T> {
        fn drop(&mut self) {
            drop_sender_side(&self.ctl);
        }
    }

    fn drop_sender_side(ctl: &ChanCtl) {
        if let Some(h) = current() {
            if let Some(id) = reg_get(&ctl.reg, h.ctl.epoch) {
                h.ctl.op_release(h.tid, "chan.tx-drop", |inner| {
                    let ch = &mut inner.model.channels[id];
                    ch.senders = ch.senders.saturating_sub(1);
                    if ch.senders == 0 {
                        inner.wake_where(|r| matches!(r, BlockReason::ChanRecv(i) if *i == id));
                    }
                });
            }
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                h.ctl.op(tid, "chan.recv", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    let ch = &inner.model.channels[id];
                    if ch.len > 0 {
                        let clock = {
                            let ch = &mut inner.model.channels[id];
                            ch.len -= 1;
                            ch.msg_clocks.pop_front().unwrap_or_default()
                        };
                        inner.model.clocks[tid].join(&clock);
                        let v = self.real.try_recv().expect("model says a message is queued");
                        inner.wake_where(|r| matches!(r, BlockReason::ChanSend(i) if *i == id));
                        Step::Done(Ok(v))
                    } else if ch.senders == 0 {
                        Step::Done(Err(RecvError))
                    } else {
                        Step::Block(BlockReason::ChanRecv(id))
                    }
                })
            } else {
                self.real.recv()
            }
        }

        /// In a model run timeouts are not time: an empty queue returns
        /// `Timeout` immediately instead of parking the thread.
        pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                h.ctl.op(tid, "chan.recv_timeout", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    let ch = &inner.model.channels[id];
                    if ch.len > 0 {
                        let clock = {
                            let ch = &mut inner.model.channels[id];
                            ch.len -= 1;
                            ch.msg_clocks.pop_front().unwrap_or_default()
                        };
                        inner.model.clocks[tid].join(&clock);
                        let v = self.real.try_recv().expect("model says a message is queued");
                        inner.wake_where(|r| matches!(r, BlockReason::ChanSend(i) if *i == id));
                        Step::Done(Ok(v))
                    } else if ch.senders == 0 {
                        Step::Done(Err(RecvTimeoutError::Disconnected))
                    } else {
                        Step::Done(Err(RecvTimeoutError::Timeout))
                    }
                })
            } else {
                self.real.recv_timeout(timeout)
            }
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            if let Some(h) = current() {
                let epoch = h.ctl.epoch;
                let tid = h.tid;
                h.ctl.op(tid, "chan.try_recv", |inner, _| {
                    let id = ensure_channel(inner, epoch, &self.ctl);
                    let ch = &inner.model.channels[id];
                    if ch.len > 0 {
                        let clock = {
                            let ch = &mut inner.model.channels[id];
                            ch.len -= 1;
                            ch.msg_clocks.pop_front().unwrap_or_default()
                        };
                        inner.model.clocks[tid].join(&clock);
                        let v = self.real.try_recv().expect("model says a message is queued");
                        inner.wake_where(|r| matches!(r, BlockReason::ChanSend(i) if *i == id));
                        Step::Done(Ok(v))
                    } else if ch.senders == 0 {
                        Step::Done(Err(TryRecvError::Disconnected))
                    } else {
                        Step::Done(Err(TryRecvError::Empty))
                    }
                })
            } else {
                self.real.try_recv()
            }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            if let Some(h) = current() {
                if let Some(id) = reg_get(&self.ctl.reg, h.ctl.epoch) {
                    h.ctl.op_release(h.tid, "chan.rx-drop", |inner| {
                        inner.model.channels[id].rx_alive = false;
                        inner.wake_where(|r| matches!(r, BlockReason::ChanSend(i) if *i == id));
                    });
                }
            }
        }
    }
}
