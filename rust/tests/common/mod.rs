//! Shared fixtures for the artifact-gated integration suites.  Each test
//! crate compiles its own copy (`mod common;`), so helpers unused by a
//! particular crate are expected.
#![allow(dead_code)]

use std::path::{Path, PathBuf};

use zqhero::model::manifest::Manifest;
use zqhero::runtime::Runtime;

/// The generated artifacts dir, or None (self-skip) on a bare checkout.
pub fn artifacts() -> Option<PathBuf> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("skipping artifact-gated tests: run `make artifacts` first");
        None
    }
}

/// Ensure the quantized checkpoint for (task, mode) exists on disk
/// (small 4-batch calibration — fixture speed over fidelity).
pub fn ensure_quantized(dir: &Path, task: &str, mode: &str) {
    use zqhero::evalharness as eh;
    let mut rt = Runtime::new(Manifest::load(dir).unwrap()).unwrap();
    let spec = rt.manifest.task(task).unwrap().clone();
    if !rt.manifest.path(&spec.checkpoint_rel(mode)).exists() {
        let hist = eh::ensure_calibration(&mut rt, &spec, 4, false).unwrap();
        eh::quantize_task(&mut rt, &spec, mode, &hist, 100.0, None).unwrap();
    }
}
