//! The deterministic cooperative scheduler at the heart of heromck.
//!
//! Model threads are real OS threads, but a single *baton* — handed out
//! by the [`Controller`] — guarantees that exactly one of them executes
//! at any moment.  Every modeled operation (lock, unlock, atomic
//! load/store, channel send/recv, spawn, join, condvar wait/notify) is a
//! *schedule point*: the thread arrives, surrenders the baton, and a
//! scheduling decision picks who runs next.  Decisions are indices into
//! a deterministically ordered candidate list, so a recorded decision
//! sequence — the *schedule token* — replays the exact interleaving.
//!
//! Two decision kinds exist: *thread* decisions (who runs next) and
//! *value* decisions (which coherence-visible store a relaxed atomic
//! load observes, which condvar waiter a `notify_one` wakes).  Both are
//! recorded in the same trace and replayed the same way.
//!
//! The scheduler also keeps the model-level state — mutexes, rwlocks,
//! condvars, channel occupancy, atomic store histories with vector
//! clocks, per-thread held-lock stacks — and derives two reports from
//! it: the per-schedule lock-acquisition-order edges (cross-checked
//! against herolint's static `lock_edges`), and, when every live thread
//! is blocked, a deadlock report carrying the schedule and the held-lock
//! set of each thread.

use std::collections::{BTreeSet, VecDeque};
use std::sync::{Condvar as StdCondvar, Mutex as StdMutex};

use crate::prop::Rng;

/// Panic payload used to unwind model threads during teardown after a
/// failure was recorded.  Not itself a failure.
pub(crate) struct MckAbort;

/// Decision kinds, stored per trace point (diagnostics only — replay
/// consumes the index stream without caring which kind produced it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum PointKind {
    Thread,
    Value,
}

/// One recorded scheduling decision.
#[derive(Clone, Debug)]
pub(crate) struct TracePoint {
    pub options: usize,
    pub chosen: usize,
    pub kind: PointKind,
    /// Whether non-default alternatives at this point cost a preemption
    /// (true iff the previously running thread was itself a candidate).
    pub preempting_alts: bool,
    /// Cumulative preemptions spent before this decision.
    pub preempts_before: u32,
}

/// Why a thread cannot currently run.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum BlockReason {
    MutexLock(usize),
    RwRead(usize),
    RwWrite(usize),
    CondWait(usize),
    ChanRecv(usize),
    ChanSend(usize),
    Join(usize),
}

#[derive(Clone, Debug, PartialEq)]
pub(crate) enum Status {
    Ready,
    Blocked(BlockReason),
    Finished,
}

/// A vector clock over model-thread ids; the happens-before backbone for
/// the atomic visibility rules.
#[derive(Clone, Debug, Default, PartialEq)]
pub(crate) struct VClock(pub Vec<u32>);

impl VClock {
    pub fn tick(&mut self, tid: usize) {
        if self.0.len() <= tid {
            self.0.resize(tid + 1, 0);
        }
        self.0[tid] += 1;
    }

    pub fn join(&mut self, other: &VClock) {
        if self.0.len() < other.0.len() {
            self.0.resize(other.0.len(), 0);
        }
        for (i, v) in other.0.iter().enumerate() {
            if self.0[i] < *v {
                self.0[i] = *v;
            }
        }
    }

    /// `self` happens-before-or-equals `other`.
    pub fn leq(&self, other: &VClock) -> bool {
        self.0
            .iter()
            .enumerate()
            .all(|(i, v)| *v == 0 || other.0.get(i).copied().unwrap_or(0) >= *v)
    }
}

// ------------------------------------------------------------ model state

#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum HeldLock {
    M(usize),
    R(usize),
    W(usize),
}

pub(crate) struct MutexObj {
    pub holder: Option<usize>,
    pub class: Option<&'static str>,
    /// Clock released by the last unlock; joined on acquire.
    pub clock: VClock,
}

pub(crate) struct RwObj {
    pub writer: Option<usize>,
    pub readers: Vec<usize>,
    pub class: Option<&'static str>,
    /// Clock released by the last write unlock.
    pub clock: VClock,
    /// Join of reader clocks since the last write lock.
    pub readers_clock: VClock,
}

pub(crate) struct CvObj {
    /// (tid, mutex id) pairs parked in `wait`, not yet notified.
    pub waiting: Vec<(usize, usize)>,
}

pub(crate) struct ChanObj {
    pub len: usize,
    pub cap: Option<usize>,
    pub senders: usize,
    pub rx_alive: bool,
    /// Per-message send clocks, FIFO with the payloads (which live in
    /// the wrapped real channel).
    pub msg_clocks: VecDeque<VClock>,
}

pub(crate) struct StoreRec {
    pub val: u64,
    /// The storing thread's clock at store time (visibility: a store
    /// that happens-before a load hides everything older).
    pub clock: VClock,
    /// Whether an acquire load may synchronize with this store
    /// (Release / AcqRel / SeqCst stores and RMWs).
    pub release: bool,
}

pub(crate) struct AtomObj {
    pub stores: Vec<StoreRec>,
    /// Coherence floor per thread: the newest store index each thread
    /// has observed (reads may never go backwards).
    pub last_seen: Vec<usize>,
}

impl AtomObj {
    pub fn seen(&self, tid: usize) -> usize {
        self.last_seen.get(tid).copied().unwrap_or(0)
    }

    pub fn note_seen(&mut self, tid: usize, idx: usize) {
        if self.last_seen.len() <= tid {
            self.last_seen.resize(tid + 1, 0);
        }
        if self.last_seen[tid] < idx {
            self.last_seen[tid] = idx;
        }
    }
}

/// All modeled objects of one schedule execution.  Object ids are
/// allocated in first-use order under the baton, so they are identical
/// across replays of the same decision sequence.
#[derive(Default)]
pub(crate) struct Model {
    pub mutexes: Vec<MutexObj>,
    pub rwlocks: Vec<RwObj>,
    pub condvars: Vec<CvObj>,
    pub channels: Vec<ChanObj>,
    pub atomics: Vec<AtomObj>,
    /// Per-thread vector clocks.
    pub clocks: Vec<VClock>,
    /// Per-thread stacks of held locks, in acquisition order.
    pub held: Vec<Vec<HeldLock>>,
    /// Named lock-order edges observed this schedule: `(outer, inner)`
    /// whenever a named lock is acquired while another named lock is
    /// held.  Cross-checked against herolint's static `lock_edges`.
    pub edges: BTreeSet<(String, String)>,
}

impl Model {
    fn lock_class(&self, l: HeldLock) -> Option<&'static str> {
        match l {
            HeldLock::M(i) => self.mutexes[i].class,
            HeldLock::R(i) | HeldLock::W(i) => self.rwlocks[i].class,
        }
    }

    /// Record lock-order edges for acquiring `acq` with `held` stacks.
    fn note_acquire_edges(&mut self, tid: usize, acq: HeldLock) {
        let to = match self.lock_class(acq) {
            Some(c) => c,
            None => return,
        };
        let outers: Vec<&'static str> = self.held[tid]
            .iter()
            .filter_map(|h| self.lock_class(*h))
            .collect();
        for from in outers {
            if from != to {
                self.edges.insert((from.to_string(), to.to_string()));
            }
        }
    }

    pub fn alloc_mutex(&mut self, class: Option<&'static str>) -> usize {
        self.mutexes.push(MutexObj { holder: None, class, clock: VClock::default() });
        self.mutexes.len() - 1
    }

    pub fn alloc_rwlock(&mut self, class: Option<&'static str>) -> usize {
        self.rwlocks.push(RwObj {
            writer: None,
            readers: Vec::new(),
            class,
            clock: VClock::default(),
            readers_clock: VClock::default(),
        });
        self.rwlocks.len() - 1
    }

    pub fn alloc_condvar(&mut self) -> usize {
        self.condvars.push(CvObj { waiting: Vec::new() });
        self.condvars.len() - 1
    }

    pub fn alloc_channel(&mut self, cap: Option<usize>) -> usize {
        self.channels.push(ChanObj {
            len: 0,
            cap,
            senders: 1,
            rx_alive: true,
            msg_clocks: VecDeque::new(),
        });
        self.channels.len() - 1
    }

    pub fn alloc_atomic(&mut self, init: u64) -> usize {
        self.atomics.push(AtomObj {
            stores: vec![StoreRec { val: init, clock: VClock::default(), release: false }],
            last_seen: Vec::new(),
        });
        self.atomics.len() - 1
    }

    /// Acquire `id` for `tid`; the caller checked it is free.
    pub fn lock_mutex(&mut self, tid: usize, id: usize) {
        self.mutexes[id].holder = Some(tid);
        let clock = self.mutexes[id].clock.clone();
        self.clocks[tid].join(&clock);
        self.note_acquire_edges(tid, HeldLock::M(id));
        self.held[tid].push(HeldLock::M(id));
    }

    /// Release `id`; publishes the holder's clock to the next acquirer.
    pub fn unlock_mutex(&mut self, tid: usize, id: usize) {
        self.clocks[tid].tick(tid);
        self.mutexes[id].clock = self.clocks[tid].clone();
        self.mutexes[id].holder = None;
        if let Some(pos) = self.held[tid].iter().rposition(|h| *h == HeldLock::M(id)) {
            self.held[tid].remove(pos);
        }
    }

    /// Acquire the read side of rwlock `id`; the caller checked no
    /// writer holds it.
    pub fn lock_rw_read(&mut self, tid: usize, id: usize) {
        self.rwlocks[id].readers.push(tid);
        let clock = self.rwlocks[id].clock.clone();
        self.clocks[tid].join(&clock);
        self.note_acquire_edges(tid, HeldLock::R(id));
        self.held[tid].push(HeldLock::R(id));
    }

    pub fn unlock_rw_read(&mut self, tid: usize, id: usize) {
        self.clocks[tid].tick(tid);
        let clock = self.clocks[tid].clone();
        let rw = &mut self.rwlocks[id];
        rw.readers_clock.join(&clock);
        if let Some(pos) = rw.readers.iter().position(|r| *r == tid) {
            rw.readers.remove(pos);
        }
        if let Some(pos) = self.held[tid].iter().rposition(|h| *h == HeldLock::R(id)) {
            self.held[tid].remove(pos);
        }
    }

    /// Acquire the write side of rwlock `id`; the caller checked it is
    /// entirely free.
    pub fn lock_rw_write(&mut self, tid: usize, id: usize) {
        self.rwlocks[id].writer = Some(tid);
        let clock = self.rwlocks[id].clock.clone();
        self.clocks[tid].join(&clock);
        let readers = self.rwlocks[id].readers_clock.clone();
        self.clocks[tid].join(&readers);
        self.note_acquire_edges(tid, HeldLock::W(id));
        self.held[tid].push(HeldLock::W(id));
    }

    pub fn unlock_rw_write(&mut self, tid: usize, id: usize) {
        self.clocks[tid].tick(tid);
        let clock = self.clocks[tid].clone();
        let rw = &mut self.rwlocks[id];
        rw.clock = clock.clone();
        rw.readers_clock = clock;
        rw.writer = None;
        if let Some(pos) = self.held[tid].iter().rposition(|h| *h == HeldLock::W(id)) {
            self.held[tid].remove(pos);
        }
    }

    /// Render the held-lock stacks of every thread, for failure reports.
    pub fn render_held(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (tid, held) in self.held.iter().enumerate() {
            if held.is_empty() {
                continue;
            }
            let names: Vec<String> = held
                .iter()
                .map(|h| match h {
                    HeldLock::M(i) => match self.mutexes[*i].class {
                        Some(c) => format!("mutex {i} \"{c}\""),
                        None => format!("mutex {i}"),
                    },
                    HeldLock::R(i) => format!("rwlock {i} (read)"),
                    HeldLock::W(i) => format!("rwlock {i} (write)"),
                })
                .collect();
            out.push(format!("t{tid} holds [{}]", names.join(", ")));
        }
        out
    }
}

// --------------------------------------------------------------- failures

/// What went wrong in a failing schedule.  Carried out of the run and
/// rendered (with token and schedule) by the explorer.
#[derive(Clone, Debug)]
pub(crate) struct RunFailure {
    pub kind: String,
    pub message: String,
    /// The replay token for this exact interleaving.
    pub token: String,
    /// Rendered schedule steps (tail, bounded).
    pub schedule: Vec<String>,
    /// Held-lock stacks at failure time.
    pub held: Vec<String>,
    pub depth: usize,
}

/// Everything the explorer needs from one completed schedule execution.
pub(crate) struct RunRecord {
    pub trace: Vec<TracePoint>,
    pub failure: Option<RunFailure>,
    pub edges: BTreeSet<(String, String)>,
}

// ------------------------------------------------------------- scheduling

/// How decisions beyond the forced prefix are made.
pub(crate) enum DecideMode {
    /// Default-first: index 0 (continue the previous thread when it is a
    /// candidate; read the newest store).  The DFS explorer enumerates
    /// the alternatives by growing the forced prefix.
    Dfs,
    /// PCT-style randomized: threads carry random priorities, the
    /// highest-priority ready thread runs, and a bounded number of
    /// random change points demote the running thread.  Value decisions
    /// are uniform.  Fully determined by the seed.
    Pct { rng: Rng, change_points: Vec<usize>, priorities: Vec<u64> },
}

pub(crate) struct ThreadSlot {
    pub status: Status,
}

const STEP_TAIL: usize = 160;

pub(crate) struct Inner {
    pub threads: Vec<ThreadSlot>,
    /// The thread currently holding the baton.
    pub running: Option<usize>,
    /// The thread that held the baton before the current decision.
    pub last_running: Option<usize>,
    pub preemptions: u32,
    pub max_preemptions: u32,
    pub max_depth: usize,
    pub forced: Vec<usize>,
    pub mode: DecideMode,
    pub trace: Vec<TracePoint>,
    pub steps: VecDeque<String>,
    pub failure: Option<RunFailure>,
    pub aborting: bool,
    pub finished: usize,
    pub model: Model,
}

impl Inner {
    /// Record a failure (first one wins) and start teardown.
    pub fn fail(&mut self, kind: &str, message: String) {
        if self.failure.is_none() {
            self.failure = Some(RunFailure {
                kind: kind.to_string(),
                message,
                token: super::encode_token(&self.trace),
                schedule: self.steps.iter().cloned().collect(),
                held: self.model.render_held(),
                depth: self.trace.len(),
            });
        }
        self.aborting = true;
    }

    pub fn note_step(&mut self, tid: usize, label: &str) {
        if self.steps.len() == STEP_TAIL {
            self.steps.pop_front();
        }
        self.steps.push_back(format!("t{tid} {label}"));
    }

    /// Wake every blocked thread whose reason satisfies `pred`.
    pub fn wake_where(&mut self, pred: impl Fn(&BlockReason) -> bool) {
        for t in self.threads.iter_mut() {
            if let Status::Blocked(r) = &t.status {
                if pred(r) {
                    t.status = Status::Ready;
                }
            }
        }
    }

    /// One scheduling decision over `options` alternatives; returns the
    /// chosen index.  `cands` carries the candidate tids for thread
    /// decisions (empty for value decisions).
    pub fn decide(&mut self, kind: PointKind, options: usize, preempting_alts: bool, cands: &[usize]) -> usize {
        debug_assert!(options > 0);
        if options == 1 {
            // no choice — keep forced tokens and traces free of padding
            return 0;
        }
        if self.trace.len() >= self.max_depth {
            self.fail(
                "depth-exceeded",
                format!("schedule exceeded {} decisions — livelock or unbounded retry loop", self.max_depth),
            );
            return 0;
        }
        let pos = self.trace.len();
        let chosen = if pos < self.forced.len() {
            let c = self.forced[pos];
            if c >= options {
                self.fail(
                    "stale-token",
                    format!("replay token decision {pos} picks alternative {c} of {options} — the model diverged from the recorded schedule"),
                );
                0
            } else {
                c
            }
        } else {
            match &mut self.mode {
                DecideMode::Dfs => 0,
                DecideMode::Pct { rng, change_points, priorities } => match kind {
                    PointKind::Value => (rng.next_u64() % options as u64) as usize,
                    PointKind::Thread => {
                        let idx = cands
                            .iter()
                            .enumerate()
                            .max_by_key(|(_, tid)| priorities.get(**tid).copied().unwrap_or(0))
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        if change_points.contains(&pos) {
                            let tid = cands[idx];
                            if let Some(p) = priorities.get_mut(tid) {
                                *p = 0;
                            }
                        }
                        idx
                    }
                },
            }
        };
        self.trace.push(TracePoint {
            options,
            chosen,
            kind,
            preempting_alts,
            preempts_before: self.preemptions,
        });
        chosen
    }

    /// Decide which coherence-visible store index to read, given the
    /// candidates ordered newest-first.  Returns the store index.
    pub fn decide_store(&mut self, cands: &[usize]) -> usize {
        if cands.len() == 1 {
            return cands[0];
        }
        let idx = self.decide(PointKind::Value, cands.len(), false, &[]);
        cands[idx]
    }
}

/// One value of this exists per schedule execution.  `epoch` is globally
/// unique, so lazily registered objects can tell a fresh run from a
/// stale registration.
pub(crate) struct Controller {
    pub epoch: u64,
    inner: StdMutex<Inner>,
    cv: StdCondvar,
}

pub(crate) enum Step<R> {
    Done(R),
    Block(BlockReason),
}

impl Controller {
    pub fn new(epoch: u64, forced: Vec<usize>, mode: DecideMode, max_preemptions: u32, max_depth: usize) -> Controller {
        Controller {
            epoch,
            inner: StdMutex::new(Inner {
                threads: Vec::new(),
                running: None,
                last_running: None,
                preemptions: 0,
                max_preemptions,
                max_depth,
                forced,
                mode,
                trace: Vec::new(),
                steps: VecDeque::new(),
                failure: None,
                aborting: false,
                finished: 0,
                model: Model::default(),
            }),
            cv: StdCondvar::new(),
        }
    }

    fn guard(&self) -> std::sync::MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Register a new model thread; returns its tid.  The main thread is
    /// registered before the run starts; children are registered from
    /// their parent's `spawn` schedule point (under the baton, so tids
    /// are deterministic).
    pub fn register_thread(inner: &mut Inner, parent: Option<usize>) -> usize {
        let tid = inner.threads.len();
        inner.threads.push(ThreadSlot { status: Status::Ready });
        let mut clock = match parent {
            Some(p) => {
                inner.model.clocks[p].tick(p);
                inner.model.clocks[p].clone()
            }
            None => VClock::default(),
        };
        clock.tick(tid);
        inner.model.clocks.push(clock);
        inner.model.held.push(Vec::new());
        if let DecideMode::Pct { rng, priorities, .. } = &mut inner.mode {
            // 1.. so a demoted thread (priority 0) ranks below everyone
            priorities.push(1 + rng.next_u64() % 1_000_000);
        }
        tid
    }

    pub fn register_main(&self) -> usize {
        let mut inner = self.guard();
        let tid = Self::register_thread(&mut inner, None);
        inner.running = Some(tid);
        inner.last_running = Some(tid);
        tid
    }

    /// If no thread holds the baton, make a scheduling decision (or
    /// report a deadlock when nothing is runnable).
    fn pick_if_idle(&self, inner: &mut Inner) {
        if inner.running.is_some() || inner.aborting {
            return;
        }
        let cands: Vec<usize> = inner
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Ready)
            .map(|(i, _)| i)
            .collect();
        if cands.is_empty() {
            if inner.finished < inner.threads.len() {
                let blocked: Vec<String> = inner
                    .threads
                    .iter()
                    .enumerate()
                    .filter_map(|(i, t)| match &t.status {
                        Status::Blocked(r) => Some(format!("t{i} blocked on {r:?}")),
                        _ => None,
                    })
                    .collect();
                inner.fail(
                    "deadlock",
                    format!("every live model thread is blocked: {}", blocked.join("; ")),
                );
            }
            return;
        }
        // candidate order: previously running thread first (so the
        // default decision never preempts), then ascending tid
        let mut ordered = cands;
        let prev_is_cand = match inner.last_running {
            Some(p) => {
                if let Some(pos) = ordered.iter().position(|&t| t == p) {
                    ordered.remove(pos);
                    ordered.insert(0, p);
                    true
                } else {
                    false
                }
            }
            None => false,
        };
        let idx = inner.decide(PointKind::Thread, ordered.len(), prev_is_cand, &ordered);
        if inner.aborting {
            return;
        }
        let chosen = ordered[idx];
        if prev_is_cand && Some(chosen) != inner.last_running {
            inner.preemptions += 1;
        }
        inner.last_running = Some(chosen);
        inner.running = Some(chosen);
    }

    /// Execute one modeled operation for `tid`.  `f` runs under the
    /// baton with the model state borrowed; returning `Block` parks the
    /// thread until another operation wakes it, after which `f` is
    /// retried with an incremented attempt counter.
    pub(crate) fn op<R>(
        &self,
        tid: usize,
        label: &'static str,
        mut f: impl FnMut(&mut Inner, usize) -> Step<R>,
    ) -> R {
        let mut inner = self.guard();
        // arrival: surrender the baton, forcing a decision
        inner.threads[tid].status = Status::Ready;
        if inner.running == Some(tid) {
            inner.running = None;
        }
        self.pick_if_idle(&mut inner);
        self.cv.notify_all();
        let mut attempt = 0usize;
        loop {
            while inner.running != Some(tid) {
                if inner.aborting {
                    drop(inner);
                    std::panic::panic_any(MckAbort);
                }
                inner = match self.cv.wait(inner) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
            if inner.aborting {
                drop(inner);
                std::panic::panic_any(MckAbort);
            }
            inner.note_step(tid, label);
            match f(&mut inner, attempt) {
                Step::Done(r) => return r,
                Step::Block(reason) => {
                    inner.threads[tid].status = Status::Blocked(reason);
                    inner.running = None;
                    self.pick_if_idle(&mut inner);
                    self.cv.notify_all();
                    attempt += 1;
                }
            }
        }
    }

    /// A non-blocking operation that tolerates teardown: used from
    /// `Drop` impls, where panicking would abort the process.  Returns
    /// `None` when the run is already aborting.
    pub(crate) fn op_release<R>(
        &self,
        tid: usize,
        label: &'static str,
        f: impl FnOnce(&mut Inner) -> R,
    ) -> Option<R> {
        let mut inner = self.guard();
        inner.threads[tid].status = Status::Ready;
        if inner.running == Some(tid) {
            inner.running = None;
        }
        self.pick_if_idle(&mut inner);
        self.cv.notify_all();
        loop {
            if inner.aborting {
                return None;
            }
            if inner.running == Some(tid) {
                break;
            }
            inner = match self.cv.wait(inner) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        inner.note_step(tid, label);
        Some(f(&mut inner))
    }

    /// Mark `tid` finished.  A non-`MckAbort` panic payload records a
    /// failure; joiners are woken either way.
    pub fn thread_finished(&self, tid: usize, panic_msg: Option<String>) {
        let mut inner = self.guard();
        inner.threads[tid].status = Status::Finished;
        inner.finished += 1;
        if inner.running == Some(tid) {
            inner.running = None;
        }
        if let Some(msg) = panic_msg {
            inner.fail("panic", format!("t{tid} panicked: {msg}"));
        }
        inner.wake_where(|r| *r == BlockReason::Join(tid));
        self.pick_if_idle(&mut inner);
        self.cv.notify_all();
    }

    /// Block until every registered thread has finished, then extract
    /// the run record.  Called by the explorer after the main body's OS
    /// thread has been joined.
    pub fn wait_all_finished(&self) -> RunRecord {
        let mut inner = self.guard();
        while inner.finished < inner.threads.len() {
            // a failure already tore the run down; stragglers see
            // `aborting` at their next schedule point and unwind
            inner = match self.cv.wait(inner) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        RunRecord {
            trace: std::mem::take(&mut inner.trace),
            failure: inner.failure.take(),
            edges: std::mem::take(&mut inner.model.edges),
        }
    }
}
