//! ZQHERO named-tensor container — rust side of the format defined in
//! `python/compile/container.py`.  Byte-exact parity is enforced by
//! golden-file tests against python-written containers.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{DType, Tensor};

const MAGIC: &[u8; 8] = b"ZQHERO01";

pub struct Container {
    /// Name -> tensor, in file order.
    pub entries: Vec<(String, Tensor)>,
}

impl Container {
    pub fn new() -> Self {
        Container { entries: Vec::new() }
    }

    pub fn push(&mut self, name: &str, t: Tensor) {
        self.entries.push((name.to_string(), t));
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(n, _)| n.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn read_file(path: &Path) -> Result<Self> {
        let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::read_bytes(&raw).with_context(|| format!("parsing {path:?}"))
    }

    pub fn read_bytes(raw: &[u8]) -> Result<Self> {
        let mut r = Cursor { buf: raw, pos: 0 };
        if r.take(8)? != MAGIC.as_slice() {
            bail!("bad magic");
        }
        let count = r.u32()? as usize;
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())?;
            let dtype = DType::from_code(r.u8()?)?;
            let ndim = r.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            let nbytes = r.u64()? as usize;
            let data = r.take(nbytes)?;
            entries.push((name, Tensor::from_raw_bytes(dtype, shape, data)?));
        }
        if r.pos != raw.len() {
            bail!("trailing bytes in container");
        }
        Ok(Container { entries })
    }

    pub fn write_file(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, t) in &self.entries {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&[t.dtype().code(), t.shape.len() as u8])?;
            for d in &t.shape {
                f.write_all(&(*d as u32).to_le_bytes())?;
            }
            let raw = t.raw_bytes();
            f.write_all(&(raw.len() as u64).to_le_bytes())?;
            f.write_all(&raw)?;
        }
        f.flush()?;
        Ok(())
    }

    pub fn write_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, t) in &self.entries {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            out.push(t.dtype().code());
            out.push(t.shape.len() as u8);
            for d in &t.shape {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            let raw = t.raw_bytes();
            out.extend_from_slice(&(raw.len() as u64).to_le_bytes());
            out.extend_from_slice(&raw);
        }
        out
    }
}

impl Container {
    /// Reorder entries to match a parameter-spec list (name/shape/dtype
    /// validated).  Needed because JAX flattens dict pytrees in sorted-key
    /// order, so trained checkpoints arrive alphabetized while the HLO
    /// parameter order follows the manifest specs.
    pub fn reordered(&self, specs: &[crate::model::manifest::ParamSpec]) -> Result<Container> {
        let mut out = Container::new();
        for spec in specs {
            let t = self
                .get(&spec.name)
                .with_context(|| format!("checkpoint missing param {}", spec.name))?;
            if t.shape != spec.shape {
                bail!("{}: shape {:?} != spec {:?}", spec.name, t.shape, spec.shape);
            }
            if t.dtype() != spec.dtype {
                bail!("{}: dtype {:?} != spec {:?}", spec.name, t.dtype(), spec.dtype);
            }
            out.push(&spec.name, t.clone());
        }
        if out.len() != self.len() {
            bail!(
                "checkpoint has {} tensors but specs list {}",
                self.len(),
                specs.len()
            );
        }
        Ok(out)
    }
}

impl Default for Container {
    fn default() -> Self {
        Self::new()
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated container (want {n} bytes at {})", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

#[allow(unused)]
fn _read_to_end_unused<R: Read>(_r: R) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let mut c = Container::new();
        c.push("w", Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        c.push("q", Tensor::i8(vec![4], vec![-1, 2, -3, 4]));
        c.push("ids", Tensor::i32(vec![2], vec![7, -9]));
        let bytes = c.write_bytes();
        let r = Container::read_bytes(&bytes).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(r.get("w").unwrap().as_f32().unwrap()[4], 5.0);
        assert_eq!(r.get("q").unwrap().as_i8().unwrap(), &[-1, 2, -3, 4]);
        assert_eq!(r.get("ids").unwrap().as_i32().unwrap(), &[7, -9]);
        // order preserved
        let names: Vec<_> = r.names().collect();
        assert_eq!(names, vec!["w", "q", "ids"]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Container::read_bytes(b"NOTMAGIC\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut c = Container::new();
        c.push("w", Tensor::f32(vec![2], vec![1., 2.]));
        let bytes = c.write_bytes();
        assert!(Container::read_bytes(&bytes[..bytes.len() - 3]).is_err());
    }
}
