//! Analytic A100 performance model — the "hardware-enhanced" analysis of
//! the paper, reproduced as a first-class artifact (DESIGN.md §2: we have
//! no A100; the paper's hardware argument is analytic — data volumes and
//! tensor-core math rates over the exact dataflow we implement — so we
//! compute those same quantities from the model config).
//!
//! Rates are A100-SXM4-80GB public specs; the roofline uses
//! max(bytes / BW, flops / rate) per op with a fixed kernel-launch floor.

use crate::model::manifest::{ModelCfg, Switches};

/// A100 SXM4 80GB.
pub const HBM_BW_GBS: f64 = 2039.0; // GB/s
pub const FP16_TFLOPS: f64 = 312.0; // tensor core dense
pub const INT8_TOPS: f64 = 624.0; // tensor core dense
pub const KERNEL_FLOOR_US: f64 = 4.0; // launch + tail latency floor

/// The paper's TWQ caveat (§2.1): fusing on-the-fly per-token reduction
/// into a *compute-bound* GeMM raises register pressure / adds work per
/// MMA; we model it as a math-efficiency penalty when (and only when) a
/// TWQ quantize is forced into a GeMM epilogue instead of an LN.
pub const TWQ_IN_GEMM_PENALTY: f64 = 0.85;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    MemoryBound,
    ComputeBound,
}

#[derive(Debug, Clone)]
pub struct OpCost {
    pub name: String,
    pub class: OpClass,
    pub bytes: f64,
    pub flops: f64,
    /// math efficiency in [0,1] applied to the compute term
    pub efficiency: f64,
    pub int8: bool,
}

impl OpCost {
    /// Roofline time on the modeled device, microseconds.
    pub fn time_us(&self) -> f64 {
        let mem_us = self.bytes / (HBM_BW_GBS * 1e3); // bytes / (GB/s) -> us is bytes/1e3/GBps
        let rate = if self.int8 { INT8_TOPS } else { FP16_TFLOPS };
        let math_us = self.flops / (rate * 1e6) / self.efficiency.max(1e-6);
        mem_us.max(math_us).max(KERNEL_FLOOR_US)
    }
}

/// Byte/FLOP inventory for one transformer layer at (batch*seq = n tokens).
///
/// Precisions follow the switch set exactly (Table 1): an INT8 module reads
/// /writes 1-byte activations and int8 weights; an FP module uses 2-byte
/// (fp16) activations/weights — the paper's baseline precision.
pub fn layer_ops(cfg: &ModelCfg, sw: &Switches, n: usize, seq_len: usize) -> Vec<OpCost> {
    let d = cfg.hidden as f64;
    let f = cfg.ffn as f64;
    let nn = n as f64;
    let heads = cfg.heads as f64;
    let seq = seq_len as f64;
    let mut ops = Vec::new();

    let act = |int8: bool| if int8 { 1.0 } else { 2.0 };

    // --- QKV GeMM (3x [n,d]x[d,d])
    {
        let int8 = sw.qkv;
        let a = act(int8);
        // out precision: int8 if attention is int8 (SQ out), else fp16
        let out_b = act(sw.attn && int8);
        ops.push(OpCost {
            name: "qkv_gemm".into(),
            class: OpClass::ComputeBound,
            bytes: 3.0 * (nn * d * a + d * d * a + nn * d * out_b),
            flops: 3.0 * 2.0 * nn * d * d,
            efficiency: 1.0,
            int8,
        });
    }

    // --- attention core: QK^T [h,n,dh]x[h,dh,n], softmax, PV
    {
        let int8 = sw.attn;
        let a = act(int8);
        // scores A stay fp16 (paper: A unquantized); P int8 if attn int8
        let p_b = act(int8);
        ops.push(OpCost {
            name: "attn_qk".into(),
            class: OpClass::ComputeBound,
            bytes: 2.0 * nn * d * a + heads * seq * seq * 2.0 / heads.max(1.0),
            flops: 2.0 * nn * seq * d,
            efficiency: 1.0,
            int8,
        });
        ops.push(OpCost {
            name: "softmax".into(),
            class: OpClass::MemoryBound,
            // read A fp16, write P (int8 when quantized: paper's volume win)
            bytes: nn * seq * 2.0 + nn * seq * p_b,
            flops: 5.0 * nn * seq,
            efficiency: 1.0,
            int8: false,
        });
        ops.push(OpCost {
            name: "attn_pv".into(),
            class: OpClass::ComputeBound,
            bytes: nn * seq * p_b + nn * d * a + nn * d * act(sw.attn_output && int8),
            flops: 2.0 * nn * seq * d,
            efficiency: 1.0,
            int8,
        });
    }

    // --- attention output GeMM [n,d]x[d,d]
    {
        let int8 = sw.attn_output;
        let a_in = act(sw.attn && int8); // X_attn precision
        let a = act(int8);
        // TWQ penalty: if the *input* to this int8 GeMM was fp (attn off),
        // an on-the-fly quantize rides the GeMM (the paper's "no fusion
        // opportunity" case for the attention output linear layer).
        let eff = if int8 && !sw.attn { TWQ_IN_GEMM_PENALTY } else { 1.0 };
        ops.push(OpCost {
            name: "attn_out_gemm".into(),
            class: OpClass::ComputeBound,
            bytes: nn * d * a_in + d * d * a + nn * d * a,
            flops: 2.0 * nn * d * d,
            efficiency: eff,
            int8,
        });
    }

    // --- LN1 (fused residual + quant): reads X_in + X_o, writes X (int8 if fc1)
    {
        let in_b = act(sw.qkv) + act(sw.attn_output);
        let out_b = act(sw.fc1);
        ops.push(OpCost {
            name: "ln1".into(),
            class: OpClass::MemoryBound,
            bytes: nn * d * (in_b + out_b),
            flops: 8.0 * nn * d,
            efficiency: 1.0,
            int8: false,
        });
    }

    // --- FC1 [n,d]x[d,f] (X_1 stays fp)
    {
        let int8 = sw.fc1;
        let a = act(int8);
        ops.push(OpCost {
            name: "fc1_gemm".into(),
            class: OpClass::ComputeBound,
            bytes: nn * d * a + d * f * a + nn * f * 2.0,
            flops: 2.0 * nn * d * f,
            efficiency: 1.0,
            int8,
        });
    }

    // --- GELU (+FWQ quant when fc2 int8): reads X_1 fp, writes A
    {
        let out_b = act(sw.fc2);
        ops.push(OpCost {
            name: "gelu".into(),
            class: OpClass::MemoryBound,
            bytes: nn * f * (2.0 + out_b),
            flops: 10.0 * nn * f,
            efficiency: 1.0,
            int8: false,
        });
    }

    // --- FC2 [n,f]x[f,d]
    {
        let int8 = sw.fc2;
        let a = act(int8);
        ops.push(OpCost {
            name: "fc2_gemm".into(),
            class: OpClass::ComputeBound,
            bytes: nn * f * a + f * d * a + nn * d * a,
            flops: 2.0 * nn * f * d,
            efficiency: 1.0,
            int8,
        });
    }

    // --- LN2
    {
        let in_b = act(sw.fc1) + act(sw.fc2);
        let out_b = act(sw.qkv);
        ops.push(OpCost {
            name: "ln2".into(),
            class: OpClass::MemoryBound,
            bytes: nn * d * (in_b + out_b),
            flops: 8.0 * nn * d,
            efficiency: 1.0,
            int8: false,
        });
    }

    ops
}

/// Embedding stage ops (paper §2.2.1: TWQ on X_t and X_emb halves the LN
/// traffic).
pub fn embedding_ops(cfg: &ModelCfg, sw: &Switches, n: usize) -> Vec<OpCost> {
    let d = cfg.hidden as f64;
    let nn = n as f64;
    let a = if sw.embedding { 1.0 } else { 2.0 };
    vec![
        OpCost {
            name: "emb_gather".into(),
            class: OpClass::MemoryBound,
            bytes: nn * d * 2.0 + nn * d * a, // table read fp16, write X_t
            flops: 0.0,
            efficiency: 1.0,
            int8: false,
        },
        OpCost {
            name: "emb_ln".into(),
            class: OpClass::MemoryBound,
            bytes: nn * d * a + nn * d * 2.0 + nn * d * a, // X_t + pos/type + X_emb
            flops: 8.0 * nn * d,
            efficiency: 1.0,
            int8: false,
        },
    ]
}

/// Full-model projected time for `n = batch * seq` tokens, microseconds.
pub fn model_time_us(cfg: &ModelCfg, sw: &Switches, batch: usize, seq: usize) -> f64 {
    let n = batch * seq;
    let mut t: f64 = embedding_ops(cfg, sw, n).iter().map(OpCost::time_us).sum();
    let per_layer: f64 = layer_ops(cfg, sw, n, seq).iter().map(OpCost::time_us).sum();
    t += per_layer * cfg.layers as f64;
    t
}

/// Scale the model to BERT_base dimensions for the paper-facing numbers.
pub fn bert_base() -> ModelCfg {
    ModelCfg {
        vocab_size: 30522,
        hidden: 768,
        layers: 12,
        heads: 12,
        ffn: 3072,
        max_seq: 512,
        type_vocab: 2,
        num_labels: 2,
        ln_eps: 1e-12,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sw(tag: &str) -> Switches {
        let b: Vec<bool> = tag.chars().map(|c| c == '1').collect();
        Switches {
            embedding: b[0],
            qkv: b[1],
            attn: b[2],
            attn_output: b[3],
            fc1: b[4],
            fc2: b[5],
        }
    }

    #[test]
    fn int8_is_faster_than_fp_everywhere() {
        let cfg = bert_base();
        let (b, s) = (16, 128);
        let fp = model_time_us(&cfg, &sw("000000"), b, s);
        let m1 = model_time_us(&cfg, &sw("110010"), b, s);
        let m2 = model_time_us(&cfg, &sw("111110"), b, s);
        let m3 = model_time_us(&cfg, &sw("111111"), b, s);
        assert!(m1 < fp, "m1 {m1} !< fp {fp}");
        assert!(m2 < m1, "m2 {m2} !< m1 {m1}");
        assert!(m3 < m2, "m3 {m3} !< m2 {m2}");
        // headline claim sanity: full INT8 beats FP16 by >1.3x on big batches
        assert!(fp / m3 > 1.3, "speedup {}", fp / m3);
    }

    #[test]
    fn ln_volume_halves_with_quant() {
        let cfg = bert_base();
        let n = 2048;
        let fp_ops = layer_ops(&cfg, &sw("000000"), n, 128);
        let q_ops = layer_ops(&cfg, &sw("111111"), n, 128);
        let fp_ln = fp_ops.iter().find(|o| o.name == "ln1").unwrap().bytes;
        let q_ln = q_ops.iter().find(|o| o.name == "ln1").unwrap().bytes;
        // paper §2.2.1: roughly 2x data-volume reduction
        let ratio = fp_ln / q_ln;
        assert!(ratio > 1.8 && ratio < 2.2, "LN volume ratio {ratio}");
    }

    #[test]
    fn twq_penalty_applies_only_unfused() {
        let cfg = bert_base();
        // attn off + attn_output on: the unfused quantize case
        let unfused = layer_ops(&cfg, &sw("110110"), 2048, 128);
        let o = unfused.iter().find(|o| o.name == "attn_out_gemm").unwrap();
        assert_eq!(o.efficiency, TWQ_IN_GEMM_PENALTY);
        // fully fused M2: no penalty
        let fused = layer_ops(&cfg, &sw("111110"), 2048, 128);
        let o2 = fused.iter().find(|o| o.name == "attn_out_gemm").unwrap();
        assert_eq!(o2.efficiency, 1.0);
    }

    #[test]
    fn kernel_floor_respected() {
        let cfg = bert_base();
        for op in layer_ops(&cfg, &sw("111111"), 128, 128) {
            assert!(op.time_us() >= KERNEL_FLOOR_US);
        }
    }
}
