"""Quantization primitives (paper §2.1) and the scale-folding algebra
(paper eqs. 20-23, 32).

This module is the *reference implementation* of every numeric transform in
the PTQ pipeline.  The rust engine (`rust/src/quant/`) re-implements the
same transforms for the production path; parity is enforced by golden-file
tests (python writes a quantized checkpoint, cargo tests re-derive it from
the same fp32 checkpoint + calibration stats and compare bit-exactly).

Conventions (match the paper):
  * weights:      column-wise symmetric int8, ``W = W_int8 * S_w``,
                  ``S_w in R^{1 x m}`` (eq. 2).
  * TWQ:          per-token symmetric, ``X = S_x * X_int8``, ``S_x in R^{n x 1}``.
  * FWQ:          per-feature symmetric, ``X = X_int8 * S_x``, ``S_x in R^{1 x d}``.
  * SQ:           scalar symmetric.
  * Softmax out:  scalar *asymmetric* with fixed zero point -128
                  (softmax is non-negative), ``P = (P_q - zp) * s_p``.
  * Round:        round-half-to-even (matches XLA's round_nearest_even and
                  rust's ``f32::round_ties_even``).
"""

import numpy as np

from ..config import QMAX, ASYM_LEVELS, ASYM_ZERO_POINT

# --------------------------------------------------------------------------
# scalar/array primitives (numpy; jnp versions live inside the kernels)
# --------------------------------------------------------------------------


def round_ties_even(x):
    """Round half to even, the rounding mode used across all three layers."""
    return np.round(x)  # numpy rounds half-to-even


def sym_quantize(x, scale):
    """x / scale, rounded and clamped to [-127, 127] (symmetric int8)."""
    q = round_ties_even(np.asarray(x, np.float64) / np.asarray(scale, np.float64))
    return np.clip(q, -QMAX, QMAX).astype(np.int8)


def sym_dequantize(q, scale):
    return q.astype(np.float32) * np.asarray(scale, np.float32)


def asym_quantize_nonneg(x, scale):
    """Asymmetric int8 for non-negative tensors, zero point -128."""
    q = round_ties_even(np.asarray(x, np.float64) / np.asarray(scale, np.float64))
    q = q + ASYM_ZERO_POINT
    return np.clip(q, -128, 127).astype(np.int8)


def asym_dequantize_nonneg(q, scale):
    return (q.astype(np.float32) - ASYM_ZERO_POINT) * np.asarray(scale, np.float32)


def scale_from_absmax(absmax, qmax=QMAX, floor=1e-10):
    """Symmetric scale; ``floor`` guards all-zero calibration slices."""
    return np.maximum(np.asarray(absmax, np.float64), floor) / qmax


def scale_from_max_nonneg(maxval, floor=1e-10):
    """Asymmetric non-negative scale over the full 255-level range."""
    return np.maximum(np.asarray(maxval, np.float64), floor) / ASYM_LEVELS


# --------------------------------------------------------------------------
# weight quantization (eq. 2)
# --------------------------------------------------------------------------


def quantize_weight_colwise(w):
    """Column-wise symmetric int8 weight quantization.

    Returns ``(w_int8 [k,m], s_w [m])`` with ``w ~= w_int8 * s_w[None, :]``.
    """
    w = np.asarray(w, np.float32)
    absmax = np.abs(w).max(axis=0)
    s_w = scale_from_absmax(absmax)
    return sym_quantize(w, s_w[None, :]), s_w.astype(np.float32)


# --------------------------------------------------------------------------
# scale folding (eqs. 20-23, 32)
# --------------------------------------------------------------------------


def fold_sq_output(w, b, s_out):
    """Eq. 20-22: fold a *scalar* output scale ``s_out`` into W and bias so
    the post-GeMM requantization is a bare Round.

    ``W~ = W / s_out``; ``b~ = b / s_out``.  After column quantization of
    ``W~``, the GeMM epilogue ``round(acc * S_in * S_w~ + b~)`` directly
    yields ``X_int8`` with ``X = X_int8 * s_out``.
    """
    s = float(s_out)
    return np.asarray(w, np.float32) / s, np.asarray(b, np.float32) / s


def fold_fwq_in_fwq_out(w, b, s_in, s_out):
    """Eq. 23 / 32: fold a per-feature *input* scale (rows) and a per-feature
    *output* scale (columns) into W:  ``W~ = diag(s_in) @ W @ diag(1/s_out)``.

    Used for ``W~_o = S_attn W_o / S_o`` and ``W~_2 = S_a W_2 / S_x2``.
    The bias belongs to the output feature space: ``b~ = b / s_out``.
    """
    s_in = np.asarray(s_in, np.float32).reshape(-1)
    s_out = np.asarray(s_out, np.float32).reshape(-1)
    w = np.asarray(w, np.float32)
    assert w.shape == (s_in.size, s_out.size), (w.shape, s_in.size, s_out.size)
    return (s_in[:, None] * w) / s_out[None, :], np.asarray(b, np.float32) / s_out


def fold_fwq_in_f32_out(w, s_in):
    """FWQ-int8 input feeding a high-precision GeMM (mode fallback):
    fold the input scale into the weight rows so the int8 activation can be
    consumed directly: ``W~ = diag(s_in) @ W``."""
    s_in = np.asarray(s_in, np.float32).reshape(-1)
    return np.asarray(s_in[:, None], np.float32) * np.asarray(w, np.float32)


# --------------------------------------------------------------------------
# calibration-stat -> scale derivation
# --------------------------------------------------------------------------


def clip_absmax(absmax_hist, pct):
    """Percentile clipping of per-batch abs-max samples (Discussion (b)).

    ``absmax_hist``: array [num_batches, ...] of per-batch maxima.
    ``pct`` = 100 reproduces plain running-max calibration.
    """
    a = np.asarray(absmax_hist, np.float64)
    if pct >= 100.0:
        return a.max(axis=0)
    return np.percentile(a, pct, axis=0)


class LayerScales:
    """Derived activation scales for one transformer layer."""

    __slots__ = ("sq_q", "sq_k", "sq_v", "sp", "s_attn", "s_o", "s_a", "s_x2")

    def __init__(self, sq_q, sq_k, sq_v, sp, s_attn, s_o, s_a, s_x2):
        self.sq_q = float(sq_q)    # SQ scalar for X_q
        self.sq_k = float(sq_k)    # SQ scalar for X_k
        self.sq_v = float(sq_v)    # SQ scalar for X_v
        self.sp = float(sp)        # asymmetric scalar for P (softmax out)
        self.s_attn = np.asarray(s_attn, np.float32)  # FWQ [d] for X_attn
        self.s_o = np.asarray(s_o, np.float32)        # FWQ [d] for X_o
        self.s_a = np.asarray(s_a, np.float32)        # FWQ [ffn] for GELU out
        self.s_x2 = np.asarray(s_x2, np.float32)      # FWQ [d] for X_2


def derive_layer_scales(stats, pct=100.0):
    """stats: dict with per-batch histories (see calibration.py for keys).

    Returns a LayerScales with SQ/FWQ scales per paper §2.2.
    """
    return LayerScales(
        sq_q=scale_from_absmax(clip_absmax(stats["q_absmax"], pct)),
        sq_k=scale_from_absmax(clip_absmax(stats["k_absmax"], pct)),
        sq_v=scale_from_absmax(clip_absmax(stats["v_absmax"], pct)),
        sp=scale_from_max_nonneg(clip_absmax(stats["p_max"], pct)),
        s_attn=scale_from_absmax(clip_absmax(stats["attn_absmax"], pct)),
        s_o=scale_from_absmax(clip_absmax(stats["o_absmax"], pct)),
        s_a=scale_from_absmax(clip_absmax(stats["gelu_absmax"], pct)),
        s_x2=scale_from_absmax(clip_absmax(stats["x2_absmax"], pct)),
    )
