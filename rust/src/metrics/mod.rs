//! GLUE metrics (Table 2 columns): accuracy, binary F1, Matthews
//! correlation, Pearson and Spearman correlation — rust mirror of
//! `python/compile/metrics.py`.

/// Classification accuracy.
pub fn accuracy(preds: &[i32], labels: &[i32]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if preds.is_empty() {
        return 0.0;
    }
    let hits = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    hits as f64 / preds.len() as f64
}

fn confusion(preds: &[i32], labels: &[i32]) -> (f64, f64, f64, f64) {
    let mut tp = 0f64;
    let mut tn = 0f64;
    let mut fp = 0f64;
    let mut fnn = 0f64;
    for (p, l) in preds.iter().zip(labels) {
        match (*p, *l) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => {}
        }
    }
    (tp, tn, fp, fnn)
}

/// Binary F1 on the positive class.
pub fn f1_binary(preds: &[i32], labels: &[i32]) -> f64 {
    let (tp, _tn, fp, fnn) = confusion(preds, labels);
    let denom = 2.0 * tp + fp + fnn;
    if denom > 0.0 {
        2.0 * tp / denom
    } else {
        0.0
    }
}

/// Matthews correlation coefficient (the CoLA metric).
pub fn matthews_corrcoef(preds: &[i32], labels: &[i32]) -> f64 {
    let (tp, tn, fp, fnn) = confusion(preds, labels);
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom > 0.0 {
        (tp * tn - fp * fnn) / denom
    } else {
        0.0
    }
}

/// Pearson correlation.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        let xc = a - mx;
        let yc = b - my;
        sxy += xc * yc;
        sxx += xc * xc;
        syy += yc * yc;
    }
    let denom = (sxx * syy).sqrt();
    if denom > 0.0 {
        sxy / denom
    } else {
        0.0
    }
}

/// Average ranks with tie handling (matches scipy/our python `_ranks`).
fn ranks(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).unwrap());
    let mut out = vec![0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && x[order[j + 1]] == x[order[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[order[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// Dispatch by metric name (manifest task metadata).
pub enum MetricInput<'a> {
    Class { preds: &'a [i32], labels: &'a [i32] },
    Reg { scores: &'a [f64], labels: &'a [f64] },
}

pub fn compute(name: &str, input: &MetricInput) -> f64 {
    match (name, input) {
        ("acc", MetricInput::Class { preds, labels }) => accuracy(preds, labels),
        ("f1", MetricInput::Class { preds, labels }) => f1_binary(preds, labels),
        ("mcc", MetricInput::Class { preds, labels }) => matthews_corrcoef(preds, labels),
        ("pearson", MetricInput::Reg { scores, labels }) => pearson(scores, labels),
        ("spearman", MetricInput::Reg { scores, labels }) => spearman(scores, labels),
        _ => panic!("metric {name} with wrong input kind"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acc_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_known_case() {
        // tp=2, fp=1, fn=1 -> f1 = 4/6
        let preds = [1, 1, 1, 0, 0];
        let labels = [1, 1, 0, 1, 0];
        assert!((f1_binary(&preds, &labels) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f1_degenerate_all_negative() {
        assert_eq!(f1_binary(&[0, 0], &[0, 0]), 0.0);
    }

    #[test]
    fn mcc_perfect_and_inverse() {
        assert!((matthews_corrcoef(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        assert!((matthews_corrcoef(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn mcc_degenerate_single_class_pred() {
        // all-1 predictions: denominator zero -> 0 by convention
        assert_eq!(matthews_corrcoef(&[1, 1, 1], &[1, 0, 1]), 0.0);
    }

    #[test]
    fn pearson_linear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotonic_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone -> rho = 1
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_ties() {
        // ties get averaged ranks; compare against a hand-computed case
        let x = [1.0, 1.0, 2.0];
        let r = ranks(&x);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }
}
