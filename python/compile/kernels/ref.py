"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here computes *exactly* the same math as the corresponding
Pallas kernel, written in the most obvious dense-jnp way.  pytest asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-driven shape and
scale sweeps.

All oracles operate on 2-D token-major activations ``[n, d]`` (n = batch *
seq flattened) except the attention core, which is ``[bh, n, dh]``.
"""

import jax.numpy as jnp

QMAX = 127.0
MASK_BIG = 1e9


def round_clamp_i8(x):
    """Symmetric int8 requantization epilogue: Round then clamp to +-127."""
    return jnp.clip(jnp.round(x), -QMAX, QMAX).astype(jnp.int8)


# --------------------------------------------------------------------------
# TWQ quantize (standalone)
# --------------------------------------------------------------------------


def twq_quantize(x):
    """Per-token symmetric quantization: returns (x_int8 [n,d], s [n,1])."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(absmax, 1e-10) / QMAX
    return round_clamp_i8(x / s), s.astype(jnp.float32)


# --------------------------------------------------------------------------
# LN^quant family (paper eq. 7, 19, 31)
# --------------------------------------------------------------------------


def _ln(x, gamma, beta, eps):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def ln_quant(a, b, gamma, beta, *, a_scale=None, b_scale=None, quantize_out=True,
             eps=1e-12):
    """Fused residual LayerNorm with quantization-aware inputs/outputs.

    ``a`` is the residual stream input: f32 [n,d], or int8 with TWQ scale
    ``a_scale`` [n,1].  ``b`` is the branch output: f32 [n,d], or int8 with
    FWQ scale ``b_scale`` [1,d].  Output is (y_int8, s [n,1]) when
    ``quantize_out`` else f32 y.
    """
    af = a.astype(jnp.float32) * a_scale if a_scale is not None else a.astype(jnp.float32)
    bf = b.astype(jnp.float32) * b_scale if b_scale is not None else b.astype(jnp.float32)
    y = _ln(af + bf, gamma, beta, eps)
    if not quantize_out:
        return y
    return twq_quantize(y)


def ln_quant_embed(x_t, x_pb, gamma, beta, *, t_scale=None, quantize_out=True,
                   eps=1e-12):
    """Embedding LN (eq. 7): ``LN(X_t + X_p + X_s)`` where X_t may arrive as
    TWQ int8 (t_scale [n,1]) and position+type embeddings ``x_pb`` are f32."""
    tf = x_t.astype(jnp.float32) * t_scale if t_scale is not None else x_t.astype(jnp.float32)
    y = _ln(tf + x_pb, gamma, beta, eps)
    if not quantize_out:
        return y
    return twq_quantize(y)


# --------------------------------------------------------------------------
# GeMM^quant family (eqs. 14, 18, 22, 28, 30)
# --------------------------------------------------------------------------


def _int_matmul(x_i8, w_i8):
    return jnp.matmul(x_i8.astype(jnp.int32), w_i8.astype(jnp.int32))


def gemm_twq_to_i8(x_i8, w_i8, x_scale, w_scale, bias):
    """TWQ-int8 activation x folded int8 weight -> int8 output (eq. 22).

    ``x_scale`` [n,1] (runtime TWQ scales), ``w_scale`` [1,m] (column scales
    of the folded weight), ``bias`` [1,m] pre-divided by the output scale.
    Output int8 in the folded output-scale domain: Round(acc*Sx*Sw + b~).
    """
    acc = _int_matmul(x_i8, w_i8).astype(jnp.float32)
    return round_clamp_i8(acc * x_scale * w_scale + bias)


def gemm_twq_to_f32(x_i8, w_i8, x_scale, w_scale, bias):
    """TWQ-int8 activation x int8 weight -> f32 (dequant epilogue; eq. 28)."""
    acc = _int_matmul(x_i8, w_i8).astype(jnp.float32)
    return acc * x_scale * w_scale + bias


def gemm_folded_to_i8(x_i8, w_i8, w_scale, bias):
    """Folded-FWQ int8 activation (input scale already inside W~, eq. 23/32)
    -> int8 output: Round(acc * Sw~ + b~)."""
    acc = _int_matmul(x_i8, w_i8).astype(jnp.float32)
    return round_clamp_i8(acc * w_scale + bias)


def gemm_folded_to_f32(x_i8, w_i8, w_scale, bias):
    """Folded int8 activation -> f32 output (mode-fallback dequant)."""
    acc = _int_matmul(x_i8, w_i8).astype(jnp.float32)
    return acc * w_scale + bias


# --------------------------------------------------------------------------
# GELU^quant (eq. 29)
# --------------------------------------------------------------------------


def gelu(x):
    """tanh-approximation GELU (matches the kernel and the FP model)."""
    c = jnp.float32(0.7978845608028654)  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def gelu_quant(x, s_a):
    """f32 FC1 output -> GELU -> FWQ int8 (scale ``s_a`` [1, ffn]).

    Matches the kernel bit-for-bit: the kernel receives the precomputed
    reciprocal (folded, no runtime division), so the oracle multiplies by
    the same reciprocal rather than dividing.
    """
    inv = (1.0 / s_a).astype(jnp.float32)
    return round_clamp_i8(gelu(x) * inv)


# --------------------------------------------------------------------------
# Softmax^quant (eq. 16) + INT8 attention core (eqs. 15-17)
# --------------------------------------------------------------------------


def softmax_quant(a, s_p):
    """Row softmax then asymmetric int8 with zero point -128.

    ``a`` [.., n] f32 logits (mask already applied); ``s_p`` scalar.
    Returns int8 in [-128, 127]; dequant = (q + 128) * s_p.
    """
    a = a - jnp.max(a, axis=-1, keepdims=True)
    e = jnp.exp(a)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    q = jnp.round(p / s_p) - 128.0
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def attention_quant(q_i8, k_i8, v_i8, mask, qk_scale, s_p, pv_scale):
    """INT8 attention core, vectorized over the leading (batch*head) axis.

    q/k/v_i8: [bh, n, dh] int8 (SQ).  mask: [bh, n] f32 in {0,1} over keys.
    qk_scale: scalar  = S_q * S_k / sqrt(dh)  (folded, eq. 15).
    s_p:      scalar  = softmax output scale.
    pv_scale: [bh, 1, dh] = s_p * S_v / S_attn  (per-feature epilogue).
    Returns X_attn int8 [bh, n, dh] with X_attn = X_attn_i8 * S_attn.
    """
    acc = jnp.einsum(
        "bnd,bmd->bnm", q_i8.astype(jnp.int32), k_i8.astype(jnp.int32)
    ).astype(jnp.float32)
    a = acc * qk_scale + (mask[:, None, :] - 1.0) * MASK_BIG
    p_q = softmax_quant(a, s_p)  # int8, zp -128
    p_shift = p_q.astype(jnp.int32) + 128  # [0, 255]
    acc2 = jnp.einsum("bnm,bmd->bnd", p_shift, v_i8.astype(jnp.int32)).astype(jnp.float32)
    return round_clamp_i8(acc2 * pv_scale)


def attention_fp(q, k, v, mask, inv_sqrt_dh):
    """FP attention core (mode fallback + FP baseline): [bh, n, dh] f32."""
    a = jnp.einsum("bnd,bmd->bnm", q, k) * inv_sqrt_dh
    a = a + (mask[:, None, :] - 1.0) * MASK_BIG
    a = a - jnp.max(a, axis=-1, keepdims=True)
    e = jnp.exp(a)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("bnm,bmd->bnd", p, v)
