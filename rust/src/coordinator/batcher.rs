//! Dynamic batcher: groups requests by interned (task, policy) and
//! sequence-length class, flushes a class when it reaches `max_batch` or
//! its oldest request has waited `max_wait`, and culls deadline-expired
//! requests at de-queue time — batch formation is the last moment a
//! request can be cancelled (DESIGN.md §5.8); once a batch leaves the
//! batcher its members execute.
//!
//! Length-aware formation (DESIGN.md §5.9): each (task, policy) group is
//! partitioned into sequence-length classes — one per manifest seq
//! bucket, assigned at admission as the smallest bucket that fits the
//! request's real length.  Batches form per (group, class), so a batch's
//! seq bucket is the smallest that fits its longest member by
//! construction, and a 16-token request never pays a 128-token batch's
//! memory traffic just because it shares a route with long requests.
//! FIFO is preserved within (group, class); across classes of one group
//! the batcher is free to reorder — that freedom is exactly what lets
//! short requests stop waiting behind long ones.
//!
//! The core is a pure state machine (`push`/`tick` return a `Drained` of
//! ready batches plus expired requests), which makes the invariants
//! property-testable without threads:
//!   * no batch exceeds `max_batch`;
//!   * no batch mixes seq classes, and no member is longer than the
//!     batch's seq bucket;
//!   * a request is emitted exactly once — in a batch or as expired —
//!     in FIFO order within its (group, class) (expiry culls preserve
//!     the survivors' relative order);
//!   * no live request waits longer than `max_wait` once `tick` is called.
//!
//! Classes live in a flat `Vec` scanned linearly: the class count is the
//! handful of admitted (task, policy) routes times the few seq buckets
//! they actually use, for which three-integer key compares beat hashing —
//! and `push` allocates nothing once the class's deque has warmed up.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::{GroupKey, Request};

pub struct Batch {
    pub key: GroupKey,
    /// The class's seq bucket: every member fits it, and it is the
    /// smallest manifest bucket that fits the longest member.
    pub seq_bucket: usize,
    pub requests: Vec<Request>,
}

/// What one batcher operation released: batches ready to dispatch plus
/// requests whose deadline passed while they queued (cancelled here, at
/// de-queue time — the caller answers them with expired responses).
#[derive(Default)]
pub struct Drained {
    pub batches: Vec<Batch>,
    pub expired: Vec<Request>,
}

impl Drained {
    pub fn is_empty(&self) -> bool {
        self.batches.is_empty() && self.expired.is_empty()
    }
}

/// Batch-formation class: one (task, policy) group restricted to one
/// sequence-length bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ClassKey {
    group: GroupKey,
    seq_bucket: usize,
}

pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
    classes: Vec<(ClassKey, VecDeque<Request>)>,
}

/// Move every expired request out of `q` into `expired`, preserving the
/// survivors' relative (FIFO) order.
fn cull(q: &mut VecDeque<Request>, now: Instant, expired: &mut Vec<Request>) {
    if q.iter().any(|r| r.expired(now)) {
        let survivors: VecDeque<Request> = q
            .drain(..)
            .filter_map(|r| {
                if r.expired(now) {
                    expired.push(r);
                    None
                } else {
                    Some(r)
                }
            })
            .collect();
        *q = survivors;
    }
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher { max_batch, max_wait, classes: Vec::new() }
    }

    /// Add a request; returns any batch made ready by this arrival (plus
    /// requests found expired while forming it).
    pub fn push(&mut self, req: Request, now: Instant) -> Drained {
        let key = ClassKey { group: req.key, seq_bucket: req.seq_bucket };
        let idx = match self.classes.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                self.classes.push((key, VecDeque::new()));
                self.classes.len() - 1
            }
        };
        let q = &mut self.classes[idx].1;
        q.push_back(req);
        let mut out = Drained::default();
        if q.len() >= self.max_batch {
            // formation time: cancel what already expired, then flush
            // only if a full batch of survivors remains (a short class
            // keeps waiting for its max_wait tick)
            cull(q, now, &mut out.expired);
            if q.len() >= self.max_batch {
                let requests = q.drain(..self.max_batch).collect();
                out.batches.push(Batch {
                    key: key.group,
                    seq_bucket: key.seq_bucket,
                    requests,
                });
            }
        }
        out
    }

    /// Cull expired requests everywhere, then flush classes whose oldest
    /// survivor has exceeded `max_wait`.
    pub fn tick(&mut self, now: Instant) -> Drained {
        let mut out = Drained::default();
        for (key, q) in self.classes.iter_mut() {
            cull(q, now, &mut out.expired);
            while let Some(front) = q.front() {
                if now.duration_since(front.enqueued) >= self.max_wait {
                    let take = q.len().min(self.max_batch);
                    let requests: Vec<Request> = q.drain(..take).collect();
                    out.batches.push(Batch {
                        key: key.group,
                        seq_bucket: key.seq_bucket,
                        requests,
                    });
                } else {
                    break;
                }
            }
        }
        out
    }

    /// Force-flush everything (shutdown / drain); already-expired
    /// requests still come back as expired, not as batch members.
    pub fn drain_all(&mut self, now: Instant) -> Drained {
        let mut out = Drained::default();
        for (key, q) in self.classes.iter_mut() {
            cull(q, now, &mut out.expired);
            while !q.is_empty() {
                let take = q.len().min(self.max_batch);
                out.batches.push(Batch {
                    key: key.group,
                    seq_bucket: key.seq_bucket,
                    requests: q.drain(..take).collect(),
                });
            }
        }
        out
    }

    pub fn pending(&self) -> usize {
        self.classes.iter().map(|(_, q)| q.len()).sum()
    }

    /// Earliest `max_wait` flush point across classes (each class's front
    /// is its oldest request), or None when empty.  Deliberately
    /// O(classes), not O(backlog): request deadlines are *not* scanned
    /// here — the batcher loop clamps its wait to a short idle tick
    /// anyway, so expiry culls run within that bound without walking
    /// every queued request on the hot path to compute a wake-up time.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.classes
            .iter()
            .filter_map(|(_, q)| q.front().map(|r| r.enqueued + self.max_wait))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{PolicyId, TaskId};
    use crate::prop::{forall, Rng};
    use crate::sync::mpsc::channel;

    /// The test grid's seq buckets (mirrors a manifest's seq_buckets).
    const SEQ_BUCKETS: [usize; 3] = [16, 64, 128];

    fn class_for(len: usize) -> usize {
        *SEQ_BUCKETS.iter().find(|b| **b >= len).unwrap_or(&128)
    }

    fn key(task: u16, policy: u16) -> GroupKey {
        GroupKey { task: TaskId(task), policy: PolicyId(policy), version: 0 }
    }

    fn req(id: u64, task: u16, policy: u16, at: Instant) -> Request {
        req_full(id, task, policy, at, None, 128)
    }

    fn req_deadline(
        id: u64,
        task: u16,
        policy: u16,
        at: Instant,
        deadline: Option<Instant>,
    ) -> Request {
        req_full(id, task, policy, at, deadline, 128)
    }

    fn req_full(
        id: u64,
        task: u16,
        policy: u16,
        at: Instant,
        deadline: Option<Instant>,
        len: usize,
    ) -> Request {
        let (tx, _rx) = channel();
        // leak the receiver side: batcher tests never reply
        std::mem::forget(_rx);
        Request {
            id,
            key: key(task, policy),
            requested: PolicyId(policy),
            seq_bucket: class_for(len),
            ids: vec![1; len],
            type_ids: vec![0; len],
            enqueued: at,
            deadline,
            reply: tx,
        }
    }

    #[test]
    fn flushes_on_max_batch() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        let t = Instant::now();
        assert!(b.push(req(0, 0, 0, t), t).is_empty());
        assert!(b.push(req(1, 0, 0, t), t).is_empty());
        let out = b.push(req(2, 0, 0, t), t);
        assert_eq!(out.batches.len(), 1, "full batch");
        assert_eq!(out.batches[0].requests.len(), 3);
        assert_eq!(out.batches[0].seq_bucket, 128);
        assert!(out.expired.is_empty());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn groups_are_isolated() {
        let mut b = Batcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        assert!(b.push(req(0, 0, 0, t), t).is_empty());
        assert!(b.push(req(1, 0, 1, t), t).is_empty());
        assert!(b.push(req(2, 1, 0, t), t).is_empty());
        assert_eq!(b.pending(), 3);
        let out = b.push(req(3, 0, 0, t), t);
        let batch = &out.batches[0];
        assert_eq!(batch.key, key(0, 0));
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3]);
    }

    #[test]
    fn seq_classes_batch_apart_within_a_group() {
        // same (task, policy), different lengths: the short request must
        // not ride (or wait for) the long class's batch
        let mut b = Batcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        assert!(b.push(req_full(0, 0, 0, t, None, 10), t).is_empty());
        assert!(b.push(req_full(1, 0, 0, t, None, 100), t).is_empty());
        assert_eq!(b.pending(), 2, "two classes, each below max_batch");
        // a second short arrival fills the 16-token class only
        let out = b.push(req_full(2, 0, 0, t, None, 12), t);
        assert_eq!(out.batches.len(), 1);
        let batch = &out.batches[0];
        assert_eq!(batch.seq_bucket, 16);
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert!(batch.requests.iter().all(|r| r.ids.len() <= batch.seq_bucket));
        // the long request is still queued in its own class
        assert_eq!(b.pending(), 1);
        let out = b.drain_all(t);
        assert_eq!(out.batches.len(), 1);
        assert_eq!(out.batches[0].seq_bucket, 128);
        assert_eq!(out.batches[0].requests[0].id, 1);
    }

    #[test]
    fn tick_flushes_aged() {
        let mut b = Batcher::new(16, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(req(0, 0, 0, t0), t0);
        b.push(req(1, 0, 0, t0), t0);
        assert!(b.tick(t0 + Duration::from_millis(1)).is_empty());
        let out = b.tick(t0 + Duration::from_millis(6));
        assert_eq!(out.batches.len(), 1);
        assert_eq!(out.batches[0].requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest_not_request_deadlines() {
        let mut b = Batcher::new(16, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(b.next_deadline().is_none());
        b.push(req(0, 0, 0, t0), t0);
        b.push(req(1, 1, 0, t0 + Duration::from_millis(3)), t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
        // request deadlines do not move the wake-up point (the serving
        // loop's idle clamp bounds expiry-cull latency instead — the
        // wake-up stays O(classes) under a deep backlog)
        let d = t0 + Duration::from_millis(4);
        b.push(req_deadline(2, 1, 0, t0 + Duration::from_millis(3), Some(d)), t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
        // ...but tick still culls the expired request on the next wake
        let out = b.tick(t0 + Duration::from_millis(5));
        assert_eq!(out.expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn expired_requests_cancelled_at_formation_fifo_kept() {
        let mut b = Batcher::new(3, Duration::from_millis(50));
        let t0 = Instant::now();
        b.push(req(0, 0, 0, t0), t0);
        b.push(req_deadline(1, 0, 0, t0, Some(t0 + Duration::from_millis(5))), t0);
        // third arrival lands after id 1's deadline: formation culls it,
        // and the 2 survivors are below max_batch, so they keep waiting
        // for the max_wait tick (no partial eager flush)
        let out = b.push(req(2, 0, 0, t0), t0 + Duration::from_millis(10));
        assert_eq!(out.expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert!(out.batches.is_empty());
        assert_eq!(b.pending(), 2);
        let out = b.tick(t0 + Duration::from_millis(60));
        assert_eq!(out.batches.len(), 1);
        assert_eq!(
            out.batches[0].requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 2],
            "FIFO among survivors"
        );
    }

    #[test]
    fn tick_culls_expired_without_flushing_young_survivors() {
        let mut b = Batcher::new(16, Duration::from_millis(100));
        let t0 = Instant::now();
        b.push(req_deadline(0, 0, 0, t0, Some(t0 + Duration::from_millis(2))), t0);
        b.push(req(1, 0, 0, t0 + Duration::from_millis(1)), t0);
        let out = b.tick(t0 + Duration::from_millis(5));
        assert_eq!(out.expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert!(out.batches.is_empty(), "survivor is younger than max_wait");
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn drain_all_reports_expired_separately() {
        let mut b = Batcher::new(16, Duration::from_secs(10));
        let t0 = Instant::now();
        b.push(req(0, 0, 0, t0), t0);
        b.push(req_deadline(1, 0, 0, t0, Some(t0 + Duration::from_millis(1))), t0);
        let out = b.drain_all(t0 + Duration::from_millis(5));
        assert_eq!(out.expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(out.batches.len(), 1);
        assert_eq!(out.batches[0].requests[0].id, 0);
        assert_eq!(b.pending(), 0);
    }

    // ------------------------------------------------------- properties

    #[test]
    fn prop_exactly_once_fifo_and_bounded_with_deadlines_and_lengths() {
        forall("batcher-invariants", 50, |r: &mut Rng| {
            let max_batch = 1 + r.below(8);
            let mut b = Batcher::new(max_batch, Duration::from_millis(r.below(20) as u64));
            let tasks = [0u16, 1, 2];
            let modes = [0u16, 1];
            let t0 = Instant::now();
            let n = 1 + r.below(200);
            // (class key, id) per emission — FIFO is per (group, class)
            let mut emitted: Vec<(GroupKey, usize, u64)> = Vec::new();
            let mut expired_ids: Vec<u64> = Vec::new();
            let mut collect = |out: Drained,
                               emitted: &mut Vec<(GroupKey, usize, u64)>,
                               expired_ids: &mut Vec<u64>| {
                for batch in out.batches {
                    assert!(batch.requests.len() <= max_batch, "batch overflow");
                    assert!(!batch.requests.is_empty());
                    assert!(
                        SEQ_BUCKETS.contains(&batch.seq_bucket),
                        "batch seq bucket {} not in the grid",
                        batch.seq_bucket
                    );
                    for q in &batch.requests {
                        assert_eq!(q.key, batch.key);
                        // no member longer than the batch's seq bucket,
                        // and none so short it belongs to a smaller class
                        assert!(
                            q.ids.len() <= batch.seq_bucket,
                            "request of {} tokens in a {}-token batch",
                            q.ids.len(),
                            batch.seq_bucket
                        );
                        assert_eq!(
                            class_for(q.ids.len()),
                            batch.seq_bucket,
                            "request not in its smallest-fit class"
                        );
                        emitted.push((q.key, batch.seq_bucket, q.id));
                    }
                }
                for q in out.expired {
                    emitted.push((q.key, q.seq_bucket, q.id));
                    expired_ids.push(q.id);
                }
            };
            for id in 0..n as u64 {
                let task = *r.choice(&tasks);
                let mode = *r.choice(&modes);
                let at = t0 + Duration::from_millis(id);
                // ~1/3 of requests carry a deadline somewhere in the run
                let deadline = if r.below(3) == 0 {
                    Some(t0 + Duration::from_millis(r.below(240) as u64))
                } else {
                    None
                };
                // random real lengths across the whole admissible range
                let len = 1 + r.below(128);
                let out = b.push(req_full(id, task, mode, at, deadline, len), at);
                collect(out, &mut emitted, &mut expired_ids);
                if r.below(10) == 0 {
                    let out = b.tick(t0 + Duration::from_millis(id + r.below(30) as u64));
                    collect(out, &mut emitted, &mut expired_ids);
                }
            }
            collect(
                b.drain_all(t0 + Duration::from_millis(n as u64)),
                &mut emitted,
                &mut expired_ids,
            );
            assert_eq!(b.pending(), 0);
            // exactly once across batches + expired
            assert_eq!(emitted.len(), n);
            let mut ids: Vec<u64> = emitted.iter().map(|(_, _, id)| *id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), n, "duplicate or lost request");
            // FIFO within each (group, seq class) among batch survivors
            // (ids are submit-ordered; expired requests are removed, not
            // reordered; cross-class order within a group is deliberately
            // unconstrained — that freedom is the padding win)
            let expired_set: std::collections::BTreeSet<u64> =
                expired_ids.iter().copied().collect();
            for task in &tasks {
                for mode in &modes {
                    for sb in &SEQ_BUCKETS {
                        let k = key(*task, *mode);
                        let seq: Vec<u64> = emitted
                            .iter()
                            .filter(|(g, cls, id)| {
                                *g == k && *cls == *sb && !expired_set.contains(id)
                            })
                            .map(|(_, _, id)| *id)
                            .collect();
                        let mut sorted = seq.clone();
                        sorted.sort_unstable();
                        assert_eq!(seq, sorted, "(group {k:?}, class {sb}) out of order");
                    }
                }
            }
        });
    }
}
