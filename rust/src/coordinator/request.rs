//! Request/response types for the serving coordinator.
//!
//! `RequestSpec` is the typed admission surface (DESIGN.md §6.2): a
//! builder over (task, precision policy, payload) that replaces the old
//! `(task, mode, ids)` string tuple.  Policy references are resolved to
//! dense `TaskId`/`PolicyId` once at admission (`Coordinator::submit`);
//! every hot-path type here is `String`-free so the steady-state path
//! never touches the allocator for routing.

use std::time::{Duration, Instant};

use crate::model::manifest::{PolicyDraft, PolicyId, TaskId};
use crate::sync::mpsc::Sender;

/// How a request names its precision policy before interning.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PolicyRef {
    /// A manifest policy or a uniform per-mode policy ("fp", "m3", ...).
    Named(String),
    /// An inline spec (wire v2); interned at admission into the fixed
    /// `PolicyId` space (`Manifest::intern_inline_policy`).
    Inline(PolicyDraft),
}

/// Typed request spec — built fluently, consumed by `Coordinator::submit`:
///
/// ```ignore
/// coord.submit(
///     RequestSpec::task("sst2")
///         .policy("attn-out-fp")     // or .mode("m3") for whole-model
///         .ids(tokens)               // unpadded; length picks the seq bucket
///         .type_ids(segments),       // optional, defaults to zeros
/// )?;
/// ```
///
/// With no policy set, the manifest's first mode (the reference policy)
/// is used — the same default the CLI derives.
#[derive(Debug, Clone, Default)]
pub struct RequestSpec {
    pub task: String,
    pub policy: Option<PolicyRef>,
    /// Token ids, unpadded.  Admission records the real length and
    /// assigns the smallest manifest seq bucket that fits it — the
    /// request pays for `seq_bucket` tokens of memory traffic, not the
    /// model max (DESIGN.md §5.9).  Length must be 1..=seq.
    pub ids: Vec<i32>,
    pub type_ids: Option<Vec<i32>>,
    /// Per-request completion budget, measured from admission.  A request
    /// still queued when its deadline passes is cancelled at de-queue /
    /// batch-formation time — never after its batch reached the engine —
    /// and answered with an `expired` response (DESIGN.md §5.8).  `None`
    /// falls back to `ServerConfig::default_deadline` (which may also be
    /// `None`: no deadline).
    pub deadline: Option<Duration>,
}

impl RequestSpec {
    pub fn task(name: &str) -> RequestSpec {
        RequestSpec { task: name.to_string(), ..Default::default() }
    }

    /// Uniform whole-model precision: sugar for the mode's implicit policy.
    pub fn mode(self, mode: &str) -> RequestSpec {
        self.policy(mode)
    }

    /// Route through a named policy (manifest `policies` section or a
    /// uniform mode name).
    pub fn policy(mut self, name: &str) -> RequestSpec {
        self.policy = Some(PolicyRef::Named(name.to_string()));
        self
    }

    /// Route through an inline policy spec (base + overrides + fallback).
    pub fn policy_inline(mut self, draft: PolicyDraft) -> RequestSpec {
        self.policy = Some(PolicyRef::Inline(draft));
        self
    }

    /// Route through an already-built reference (benches sweeping refs).
    pub fn policy_ref(mut self, policy: PolicyRef) -> RequestSpec {
        self.policy = Some(policy);
        self
    }

    pub fn ids(mut self, ids: Vec<i32>) -> RequestSpec {
        self.ids = ids;
        self
    }

    pub fn type_ids(mut self, type_ids: Vec<i32>) -> RequestSpec {
        self.type_ids = Some(type_ids);
        self
    }

    /// Complete within `d` of admission or expire (see `deadline` field).
    pub fn deadline(mut self, d: Duration) -> RequestSpec {
        self.deadline = Some(d);
        self
    }

    /// Wire-friendly spelling of [`RequestSpec::deadline`].
    pub fn deadline_ms(self, ms: u64) -> RequestSpec {
        self.deadline(Duration::from_millis(ms))
    }
}

/// Interned batch-group key (paper §2.3 + §3 — the accuracy/latency
/// trade-off is exposed per request as a precision *policy*, not per
/// deployment).  `Copy`: batcher group lookup is two integer compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub task: TaskId,
    pub policy: PolicyId,
    /// Manifest version this request was admitted under (hot reload,
    /// DESIGN.md §5.13).  Part of the key so a batch never mixes
    /// versions: requests admitted before a reload drain on the old
    /// version's cells while new admissions ride the new one.
    pub version: u32,
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// Batch-group key; `key.policy` is the *effective* route — under an
    /// active governor downgrade it may be a cheaper policy than the one
    /// the client named.
    pub key: GroupKey,
    /// The policy the client asked for (stats attribute shed / expired /
    /// governed counts here, so a policy's ledger reconciles even while
    /// its traffic rides a downgraded route).
    pub requested: PolicyId,
    /// Smallest manifest seq bucket that fits `ids.len()` — the
    /// request's sequence-length class.  The batcher forms batches per
    /// (group, class), so a batch's seq bucket is the smallest that fits
    /// its longest member by construction (DESIGN.md §5.9).
    pub seq_bucket: usize,
    /// Unpadded token ids (`1..=seq` of them — the real length; padding
    /// to the batch's seq bucket happens at staging, not admission).
    pub ids: Vec<i32>,
    /// Type ids, padded/truncated to `ids.len()` at admission.
    pub type_ids: Vec<i32>,
    pub enqueued: Instant,
    /// Absolute expiry (admission time + the spec or server default
    /// budget); `None` = never expires.
    pub deadline: Option<Instant>,
    pub reply: Sender<Response>,
}

impl Request {
    /// True once the deadline (if any) has passed.
    pub fn expired(&self, now: Instant) -> bool {
        matches!(self.deadline, Some(d) if now >= d)
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// The interned policy this request rode (admission resolved it once;
    /// the net layer maps it back to names for v2 responses without
    /// re-resolving).
    pub policy: PolicyId,
    /// `[num_labels]` logits for this request's row.
    pub logits: Vec<f32>,
    pub timing: Timing,
    pub error: Option<String>,
    /// Deadline expiry (a distinct failure class: the server was healthy
    /// but could not serve this request within its budget).  Expired
    /// responses never carry engine timings — cancellation happens at
    /// batch formation or via the engine's cancel-before-submit hook,
    /// never after device work started.
    pub expired: bool,
    /// Replica failure (a third failure class: the engine replica holding
    /// this request's batch died before the batch completed — DESIGN.md
    /// §5.10).  The request itself was well-formed; a retry on the
    /// recovered pool is expected to succeed.  Mutually exclusive with
    /// `expired`; always accompanied by `error`.
    pub failed: bool,
    /// Admission shed on a *remote* tier (DESIGN.md §5.14): an engine
    /// node answered `Busy` after the front end had already handed the
    /// client a receiver, so the backpressure arrives as a terminal
    /// response instead of a `SubmitError`.  Same outcome class as a
    /// local `SubmitError::Busy` — retry later, nothing is wrong with
    /// the request.  Always `false` for responses a single-process
    /// coordinator produces.
    pub busy: bool,
}

#[derive(Debug, Clone, Default)]
pub struct Timing {
    /// time from submit to batch dispatch
    pub queue_us: u64,
    /// device execution time for the whole batch (launch -> readback
    /// complete; the upload window is *not* counted here)
    pub exec_us: u64,
    /// host -> device input copy time for the whole batch
    pub upload_us: u64,
    /// engine-measured whole-job time for the batch (job receipt ->
    /// readback complete).  Invariant the pipeline tests pin:
    /// `upload_us + exec_us <= engine_us <= total_us`.
    pub engine_us: u64,
    /// end-to-end (submit -> response send)
    pub total_us: u64,
    /// batch this request rode in
    pub batch_real: usize,
    pub bucket: usize,
    /// seq bucket the batch executed at (the smallest manifest seq
    /// bucket fitting its longest member)
    pub seq_bucket: usize,
    /// caller-provided tokens across the whole batch (pre-padding)
    pub real_tokens: usize,
    /// token slots the device processed (`bucket * seq_bucket`) — with
    /// `real_tokens`, the per-batch padding-waste witness
    pub padded_tokens: usize,
    /// coordinator-wide dispatch sequence number of the batch this request
    /// rode in; within a (task, policy, seq class) it is non-decreasing
    /// with request id — the FIFO witness the pipeline tests assert on.
    /// Across seq classes of one group the order is deliberately
    /// unconstrained (DESIGN.md §5.9): short requests may overtake long
    /// ones — that freedom is the padding win.
    pub batch_seq: u64,
    /// engine replica that executed this request's batch (0 when serving
    /// with a single engine).
    pub replica: usize,
    /// per-replica execution serial of the batch; with `replica`, the
    /// cross-replica FIFO witness — same-replica batches of a group
    /// execute in submit order.
    pub engine_seq: u64,
    /// time the batch waited on executable residency before its upload
    /// (0 when the cell was already resident).  A miss-caused slow
    /// request is attributable here instead of inflating `engine_us`/
    /// `upload_us` (DESIGN.md §5.13).
    pub load_wait_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_chaining() {
        let spec = RequestSpec::task("sst2");
        assert_eq!(spec.task, "sst2");
        assert!(spec.policy.is_none() && spec.type_ids.is_none() && spec.ids.is_empty());

        let spec = RequestSpec::task("sst2").mode("m3").ids(vec![1, 2]).type_ids(vec![0, 0]);
        assert_eq!(spec.policy, Some(PolicyRef::Named("m3".into())));
        assert_eq!(spec.ids, vec![1, 2]);
        assert_eq!(spec.type_ids, Some(vec![0, 0]));

        let draft = PolicyDraft::base("m3").with_override("attn_output", "fp");
        let spec = RequestSpec::task("sst2").policy_inline(draft.clone());
        assert_eq!(spec.policy, Some(PolicyRef::Inline(draft)));

        let spec = RequestSpec::task("sst2").deadline_ms(250);
        assert_eq!(spec.deadline, Some(Duration::from_millis(250)));
        assert!(RequestSpec::task("sst2").deadline.is_none(), "no default budget in the spec");
    }
}
