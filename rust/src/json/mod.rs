//! Minimal JSON codec (serde_json is unavailable offline — DESIGN.md §2).
//!
//! Full JSON: objects, arrays, strings with escapes (incl. `\uXXXX`),
//! numbers, bools, null.  Object key order is preserved (the manifest's
//! parameter lists are order-sensitive).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    /// Pairs in source order; `get` is linear (objects here are small).
    Object(Vec<(String, Value)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing helper.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            pos: 0,
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

// ---------------------------------------------------------------- parsing

pub fn parse(src: &str) -> Result<Value, JsonError> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(pairs)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

// ---------------------------------------------------------------- writing

pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            if !items.is_empty() {
                newline(out, indent, depth);
            }
            out.push(']');
        }
        Value::Object(pairs) => {
            out.push('{');
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            if !pairs.is_empty() {
                newline(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by writers.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Number(n)
}

pub fn s(v: &str) -> Value {
    Value::String(v.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Array(xs.iter().map(|x| Value::Number(*x)).collect())
}

pub fn arr_f32(xs: &[f32]) -> Value {
    Value::Array(xs.iter().map(|x| Value::Number(*x as f64)).collect())
}

/// Map helper for deterministic test comparisons.
pub fn to_map(v: &Value) -> BTreeMap<String, Value> {
    match v {
        Value::Object(pairs) => pairs.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert!(a[2].get("b").unwrap().is_null());
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""é\t\\ 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\\ 😀");
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_object().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":true,"d":"e\"f"},"g":null}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\q\"").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"a":[1,2],"b":"x"}"#).unwrap();
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }
}
