"""Parameter registry: names, shapes, dtypes and the canonical flat ordering
used for AOT parameter lists.

The HLO artifacts take weights as *parameters* (not constants), so one HLO
per (mode, batch-bucket) serves every task; the ordering contract here is
mirrored in ``artifacts/manifest.json`` and enforced by the rust loader.
"""

from collections import OrderedDict

import numpy as np

from ..config import ModelConfig, QuantSwitches

F32, I8 = "f32", "i8"


# --------------------------------------------------------------------------
# FP parameter set
# --------------------------------------------------------------------------


def fp_param_specs(cfg: ModelConfig):
    """Ordered [(name, shape, dtype)] for the FP model."""
    d, f, nl = cfg.hidden, cfg.ffn, cfg.num_labels
    specs = [
        ("emb.tok", (cfg.vocab_size, d), F32),
        ("emb.pos", (cfg.max_seq, d), F32),
        ("emb.type", (cfg.type_vocab, d), F32),
        ("emb.ln.g", (d,), F32),
        ("emb.ln.b", (d,), F32),
    ]
    for i in range(cfg.layers):
        p = f"L{i}."
        specs += [
            (p + "attn.q.w", (d, d), F32), (p + "attn.q.b", (d,), F32),
            (p + "attn.k.w", (d, d), F32), (p + "attn.k.b", (d,), F32),
            (p + "attn.v.w", (d, d), F32), (p + "attn.v.b", (d,), F32),
            (p + "attn.o.w", (d, d), F32), (p + "attn.o.b", (d,), F32),
            (p + "ln1.g", (d,), F32), (p + "ln1.b", (d,), F32),
            (p + "fc1.w", (d, f), F32), (p + "fc1.b", (f,), F32),
            (p + "fc2.w", (f, d), F32), (p + "fc2.b", (d,), F32),
            (p + "ln2.g", (d,), F32), (p + "ln2.b", (d,), F32),
        ]
    specs += [
        ("pool.w", (d, d), F32), ("pool.b", (d,), F32),
        ("cls.w", (d, nl), F32), ("cls.b", (nl,), F32),
    ]
    return specs


# --------------------------------------------------------------------------
# HERO (quantized) parameter set — depends on the mode switches
# --------------------------------------------------------------------------


def hero_param_specs(cfg: ModelConfig, sw: QuantSwitches):
    """Ordered [(name, shape, dtype)] for the quantized model.

    Produced by the rust ``quantize`` step from the fp32 checkpoint +
    calibration scales; consumed by hero_forward in exactly this order.
    """
    d, f, h = cfg.hidden, cfg.ffn, cfg.heads
    dh = cfg.head_dim
    specs = [
        ("emb.tok", (cfg.vocab_size, d), F32),
        ("emb.pos", (cfg.max_seq, d), F32),
        ("emb.type", (cfg.type_vocab, d), F32),
        ("emb.ln.g", (d,), F32),
        ("emb.ln.b", (d,), F32),
    ]
    for i in range(cfg.layers):
        p = f"L{i}."
        # ---- QKV projections
        if sw.qkv:
            for t in ("q", "k", "v"):
                specs += [
                    (p + f"attn.{t}.wq", (d, d), I8),
                    (p + f"attn.{t}.ws", (d,), F32),
                    (p + f"attn.{t}.b", (d,), F32),  # folded (b/S) iff attn INT8
                ]
        else:
            for t in ("q", "k", "v"):
                specs += [
                    (p + f"attn.{t}.w", (d, d), F32),
                    (p + f"attn.{t}.b", (d,), F32),
                ]
        # ---- attention core scales
        if sw.attn:
            specs += [
                (p + "attn.qk_scale", (1,), F32),   # S_q S_k / sqrt(dh), eq. 15
                (p + "attn.sp", (1,), F32),          # softmax out scale, eq. 16
                (p + "attn.pv_scale", (h, dh), F32),  # s_p S_v / S_attn, eq. 17
            ]
            if not sw.qkv:
                # fp QKV feeding INT8 attention: on-the-fly SQ quantizers
                specs += [
                    (p + "attn.inv_sq_q", (1,), F32),
                    (p + "attn.inv_sq_k", (1,), F32),
                    (p + "attn.inv_sq_v", (1,), F32),
                ]
        # ---- attention output projection
        if sw.attn_output:
            specs += [
                (p + "attn.o.wq", (d, d), I8),   # W~_o = S_attn W_o / S_o (eq. 23)
                (p + "attn.o.ws", (d,), F32),
                (p + "attn.o.bq", (d,), F32),    # b_o / S_o
                (p + "ln1.so", (d,), F32),       # S_o: FWQ scale of X_o into LN^quant
            ]
            if not sw.attn:
                # fp attention feeding the folded INT8 GeMM: FWQ quantizer
                specs += [(p + "attn.inv_s_attn", (d,), F32)]
        else:
            specs += [
                (p + "attn.o.w", (d, d), F32),
                (p + "attn.o.b", (d,), F32),
            ]
            if sw.attn:
                # INT8 X_attn feeding fp GeMM: dequant scale
                specs += [(p + "attn.s_attn", (d,), F32)]
        specs += [(p + "ln1.g", (d,), F32), (p + "ln1.b", (d,), F32)]
        # ---- MLP
        if sw.fc1:
            specs += [
                (p + "fc1.wq", (d, f), I8),
                (p + "fc1.ws", (f,), F32),
                (p + "fc1.b", (f,), F32),
            ]
        else:
            specs += [(p + "fc1.w", (d, f), F32), (p + "fc1.b", (f,), F32)]
        if sw.fc2:
            specs += [
                (p + "gelu.sa", (f,), F32),      # FWQ S_a (eq. 29)
                (p + "fc2.wq", (f, d), I8),      # W~_2 = S_a W_2 / S_x2 (eq. 32)
                (p + "fc2.ws", (d,), F32),
                (p + "fc2.bq", (d,), F32),       # b_2 / S_x2
                (p + "ln2.sx2", (d,), F32),      # S_x2 into LN^quant
            ]
        else:
            specs += [(p + "fc2.w", (f, d), F32), (p + "fc2.b", (d,), F32)]
        specs += [(p + "ln2.g", (d,), F32), (p + "ln2.b", (d,), F32)]
    specs += [
        ("pool.w", (d, d), F32), ("pool.b", (d,), F32),
        ("cls.w", (d, cfg.num_labels), F32), ("cls.b", (cfg.num_labels,), F32),
    ]
    return specs


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def init_fp_params(cfg: ModelConfig, seed=0):
    """BERT-style init: N(0, 0.02) matrices, zero biases, unit LN gains."""
    r = np.random.default_rng(seed)
    params = OrderedDict()
    for name, shape, dtype in fp_param_specs(cfg):
        assert dtype == F32
        if name.endswith(".g"):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith(".b"):
            params[name] = np.zeros(shape, np.float32)
        elif len(shape) >= 2:
            params[name] = r.normal(0.0, 0.02, shape).astype(np.float32)
        else:
            params[name] = np.zeros(shape, np.float32)
    return params


def specs_to_struct(specs):
    """[(name, shape, dtype)] -> list of jax.ShapeDtypeStruct."""
    import jax
    import jax.numpy as jnp

    dt = {F32: jnp.float32, I8: jnp.int8}
    return [jax.ShapeDtypeStruct(shape, dt[dtype]) for _, shape, dtype in specs]


def list_to_dict(specs, flat):
    assert len(specs) == len(flat), (len(specs), len(flat))
    return OrderedDict((name, arr) for (name, _, _), arr in zip(specs, flat))


def dict_to_list(specs, params):
    return [params[name] for name, _, _ in specs]
