//! Substrate micro-benchmarks: the from-scratch components on the serving
//! path (batcher, JSON codec, quantization engine, metrics, container IO)
//! — none of these may rival PJRT execution time (ms-scale).

use std::time::{Duration, Instant};

use zqhero::bench::{bench, fmt_us, Table};
use zqhero::coordinator::Batcher;
use zqhero::json;
use zqhero::metrics;
use zqhero::model::{Container, Tensor};
use zqhero::prop::Rng;
use zqhero::quant::quantize_weight_colwise;
use zqhero::quant::fold::fold_fwq_in_fwq_out;

fn main() {
    let mut t = Table::new(&["substrate", "op", "p50", "p95", "note"]);
    let mut rng = Rng::new(7);

    // batcher: push+flush throughput (interned route keys)
    {
        use zqhero::model::manifest::{PolicyId, TaskId};
        let stats = bench(3, 200, || {
            let mut b = Batcher::new(16, Duration::from_millis(4));
            let t0 = Instant::now();
            let mut flushed = 0;
            for i in 0..1024u64 {
                let (tx, rx) = std::sync::mpsc::channel();
                std::mem::forget(rx);
                let req = zqhero::coordinator::Request {
                    id: i,
                    key: zqhero::coordinator::GroupKey {
                        task: TaskId((i % 3) as u16),
                        policy: PolicyId((i % 2) as u16),
                        version: 0,
                    },
                    requested: PolicyId((i % 2) as u16),
                    seq_bucket: 128,
                    ids: Vec::new(),
                    type_ids: Vec::new(),
                    enqueued: t0,
                    deadline: None,
                    reply: tx,
                };
                flushed += b.push(req, t0).batches.len();
            }
            assert!(flushed > 0);
        });
        t.row(vec!["batcher".into(), "1024 push (6 groups)".into(),
                   fmt_us(stats.p50_us), fmt_us(stats.p95_us),
                   format!("{:.0} ns/req", stats.p50_us * 1e3 / 1024.0)]);
    }

    // json: parse + serialize a response-sized document
    {
        let logits: Vec<f32> = rng.vec_f32(3, -5.0, 5.0);
        let doc = json::obj(vec![
            ("ok", json::Value::Bool(true)),
            ("logits", json::arr_f32(&logits)),
            ("queue_us", json::num(123.0)),
            ("exec_us", json::num(45678.0)),
        ]);
        let text = json::to_string(&doc);
        let stats = bench(10, 2000, || {
            let v = json::parse(&text).unwrap();
            assert!(v.get("ok").is_some());
        });
        t.row(vec!["json".into(), "parse response".into(),
                   fmt_us(stats.p50_us), fmt_us(stats.p95_us), format!("{} B", text.len())]);
        let stats = bench(10, 2000, || {
            let s = json::to_string(&doc);
            assert!(!s.is_empty());
        });
        t.row(vec!["json".into(), "serialize response".into(),
                   fmt_us(stats.p50_us), fmt_us(stats.p95_us), String::new()]);
    }

    // quant engine: fold + colwise quantize an ffn-sized weight
    {
        let (k, m) = (512, 128);
        let w = rng.vec_f32(k * m, -0.5, 0.5);
        let b = rng.vec_f32(m, -0.1, 0.1);
        let s_in: Vec<f32> = (0..k).map(|_| rng.log_uniform(1e-3, 1e-1) as f32).collect();
        let s_out: Vec<f32> = (0..m).map(|_| rng.log_uniform(1e-3, 1e-1) as f32).collect();
        let stats = bench(3, 100, || {
            let (wt, _bt) = fold_fwq_in_fwq_out(&w, &b, &s_in, &s_out, k, m);
            let (q, _s) = quantize_weight_colwise(&wt, k, m);
            assert_eq!(q.len(), k * m);
        });
        t.row(vec!["quant".into(), "fold+quantize fc2 [512x128]".into(),
                   fmt_us(stats.p50_us), fmt_us(stats.p95_us), String::new()]);
    }

    // metrics: full dev-split scoring
    {
        let preds = rng.vec_i32(1000, 0, 1);
        let labels = rng.vec_i32(1000, 0, 1);
        let xs: Vec<f64> = (0..1000).map(|_| rng.uniform(0.0, 5.0)).collect();
        let ys: Vec<f64> = (0..1000).map(|_| rng.uniform(0.0, 5.0)).collect();
        let stats = bench(3, 500, || {
            let _ = metrics::matthews_corrcoef(&preds, &labels);
            let _ = metrics::f1_binary(&preds, &labels);
            let _ = metrics::spearman(&xs, &ys);
        });
        t.row(vec!["metrics".into(), "mcc+f1+spearman @1k".into(),
                   fmt_us(stats.p50_us), fmt_us(stats.p95_us), String::new()]);
    }

    // container: round-trip a full quantized checkpoint in memory
    {
        let mut c = Container::new();
        for i in 0..60 {
            c.push(&format!("w{i}"), Tensor::i8(vec![128, 128], rng.vec_i8(128 * 128)));
            c.push(&format!("s{i}"), Tensor::f32(vec![128], rng.vec_f32(128, 0.0, 1.0)));
        }
        let stats = bench(3, 50, || {
            let bytes = c.write_bytes();
            let r = Container::read_bytes(&bytes).unwrap();
            assert_eq!(r.len(), c.len());
        });
        t.row(vec!["container".into(), "roundtrip ~1MB ckpt".into(),
                   fmt_us(stats.p50_us), fmt_us(stats.p95_us), String::new()]);
    }

    println!("\nsubstrate micro-benchmarks (all must be << PJRT ms-scale):\n");
    t.print();
}
