//! Calibration orchestrator (paper §3: "100 batches, batch size 16" of
//! forward passes): drives the instrumented FP artifact over the task's
//! train split, records the per-batch stat history (so percentile clipping
//! — Discussion (b) — can be applied after the fact), and persists it as
//! JSON next to the checkpoint.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::{batches, Split};
use crate::json::{self, Value};
use crate::model::manifest::TaskSpec;
use crate::model::Container;
use crate::runtime::Runtime;

/// Per-batch history: stat name -> [batch][flattened values].
pub type StatHistory = Vec<(String, Vec<Vec<f64>>)>;

/// Run calibration: `num_batches` batches of the manifest's calibration
/// batch size, drawn sequentially from the train split (wrapping).
pub fn run_calibration(
    rt: &mut Runtime,
    task: &TaskSpec,
    fp: &Container,
    num_batches: usize,
) -> Result<StatHistory> {
    let split = Split::load(&rt.manifest, task, "train")?;
    let cb = rt.manifest.calib.batch;
    let stat_names: Vec<String> =
        rt.manifest.calib.stats.iter().map(|(n, _)| n.clone()).collect();

    // fp params in manifest order, uploaded once
    let mut tensors = Vec::new();
    for spec in &rt.manifest.calib.params {
        let t = fp
            .get(&spec.name)
            .with_context(|| format!("fp checkpoint missing {}", spec.name))?;
        if t.shape != spec.shape {
            bail!("{}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
        }
        tensors.push(t.clone());
    }
    let fp_bufs = rt.upload_all(&tensors)?;

    let all = batches(&split, cb);
    if all.is_empty() {
        bail!("empty train split for {}", task.name);
    }
    // only full batches are usable (fixed artifact shape); wrap if needed
    let full: Vec<_> = all.iter().filter(|b| b.real == cb).collect();
    if full.is_empty() {
        bail!("train split smaller than one calibration batch");
    }

    let mut history: StatHistory =
        stat_names.iter().map(|n| (n.clone(), Vec::new())).collect();
    for bi in 0..num_batches {
        let b = full[bi % full.len()];
        let out = rt.calibrate_batch(&fp_bufs, &b.ids, &b.type_ids, &b.mask)?;
        // outputs: [logits, stat0, stat1, ...] in manifest order
        if out.tensors.len() != 1 + stat_names.len() {
            bail!(
                "calibration artifact returned {} outputs, expected {}",
                out.tensors.len(),
                1 + stat_names.len()
            );
        }
        for (i, t) in out.tensors[1..].iter().enumerate() {
            let vals: Vec<f64> = t.as_f32()?.iter().map(|x| *x as f64).collect();
            history[i].1.push(vals);
        }
    }
    Ok(history)
}

// ------------------------------------------------------------ persistence

pub fn save_history(path: &Path, hist: &StatHistory, num_batches: usize) -> Result<()> {
    let stats = Value::Object(
        hist.iter()
            .map(|(name, per_batch)| {
                let arr = Value::Array(per_batch.iter().map(|b| json::arr_f64(b)).collect());
                (name.clone(), arr)
            })
            .collect(),
    );
    let doc = json::obj(vec![
        ("batches", json::num(num_batches as f64)),
        ("stats", stats),
    ]);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, json::to_string_pretty(&doc))?;
    Ok(())
}

pub fn load_history(path: &Path) -> Result<StatHistory> {
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let doc = json::parse(&src).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    let stats = doc
        .get("stats")
        .and_then(|s| s.as_object())
        .context("calib json missing stats")?;
    let mut out = Vec::new();
    for (name, batches_v) in stats {
        let mut per_batch = Vec::new();
        for b in batches_v.as_array().context("stat not array")? {
            let vals = b
                .as_array()
                .context("batch not array")?
                .iter()
                .map(|x| x.as_f64().context("stat value"))
                .collect::<Result<Vec<f64>>>()?;
            per_batch.push(vals);
        }
        out.push((name.clone(), per_batch));
    }
    Ok(out)
}

/// Truncate a history to its first `n` batches (the calibration-batches
/// ablation reuses one 100-batch run).
pub fn truncate_history(hist: &StatHistory, n: usize) -> StatHistory {
    hist.iter()
        .map(|(name, per_batch)| (name.clone(), per_batch.iter().take(n).cloned().collect()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_json_roundtrip() {
        let hist: StatHistory = vec![
            ("q_absmax".into(), vec![vec![1.0, 2.0], vec![1.5, 2.5]]),
            ("attn_absmax".into(), vec![vec![0.1; 8], vec![0.2; 8]]),
        ];
        let dir = std::env::temp_dir().join("zqh_calib_test");
        let path = dir.join("calib.json");
        save_history(&path, &hist, 2).unwrap();
        let r = load_history(&path).unwrap();
        assert_eq!(r, hist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncation() {
        let hist: StatHistory = vec![("x".into(), vec![vec![1.0], vec![2.0], vec![3.0]])];
        let t = truncate_history(&hist, 2);
        assert_eq!(t[0].1.len(), 2);
    }
}
