//! Quantization primitives (paper §2.1) — rust mirror of
//! `python/compile/kernels/quant_ops.py`.  Bit-exact parity with the python
//! reference is enforced by golden-file tests; the numeric conventions
//! (f64 division + round-half-to-even, f32 storage) are therefore part of
//! the contract, not incidental.

pub const QMAX: f64 = 127.0;
pub const ASYM_LEVELS: f64 = 255.0;
pub const SCALE_FLOOR: f64 = 1e-10;

/// Round half to even — matches numpy's `np.round` and XLA's
/// `round_nearest_even`.
#[inline]
pub fn round_ties_even(x: f64) -> f64 {
    x.round_ties_even()
}

/// Symmetric int8: `round(x / scale)` clamped to ±127 (f64 internals,
/// matching the python reference).
#[inline]
pub fn sym_quantize_one(x: f32, scale: f64) -> i8 {
    round_ties_even(x as f64 / scale).clamp(-QMAX, QMAX) as i8
}

pub fn sym_dequantize_one(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Symmetric scale from an abs-max statistic (guards all-zero slices).
#[inline]
pub fn scale_from_absmax(absmax: f64) -> f64 {
    absmax.max(SCALE_FLOOR) / QMAX
}

/// Asymmetric non-negative scale over the full 255-level range
/// (Softmax^quant output, zero point -128).
#[inline]
pub fn scale_from_max_nonneg(maxval: f64) -> f64 {
    maxval.max(SCALE_FLOOR) / ASYM_LEVELS
}

/// Column-wise symmetric int8 weight quantization (paper eq. 2).
///
/// `w` is row-major `[k, m]`; returns `(w_int8, s_w[m])` with the int8
/// computed against the f64 scale and the stored scale truncated to f32 —
/// exactly the python `quantize_weight_colwise`.
pub fn quantize_weight_colwise(w: &[f32], k: usize, m: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), k * m);
    let mut absmax = vec![0f32; m];
    for row in 0..k {
        for col in 0..m {
            let a = w[row * m + col].abs();
            if a > absmax[col] {
                absmax[col] = a;
            }
        }
    }
    let scales_f64: Vec<f64> = absmax.iter().map(|a| scale_from_absmax(*a as f64)).collect();
    let mut q = vec![0i8; k * m];
    for row in 0..k {
        for col in 0..m {
            q[row * m + col] = sym_quantize_one(w[row * m + col], scales_f64[col]);
        }
    }
    (q, scales_f64.iter().map(|s| *s as f32).collect())
}

/// numpy-default ("linear") percentile over a sample axis, in f64.
/// `pct >= 100` degenerates to the plain maximum (running-max calibration).
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    assert!(!samples.is_empty());
    if pct >= 100.0 {
        return samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = pct / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let frac = rank - lo as f64;
    if lo + 1 < v.len() {
        v[lo] * (1.0 - frac) + v[lo + 1] * frac
    } else {
        v[lo]
    }
}

/// Percentile clip across a per-batch history: `hist[b][i]` -> out[i].
pub fn clip_absmax_history(hist: &[Vec<f64>], pct: f64) -> Vec<f64> {
    assert!(!hist.is_empty());
    let n = hist[0].len();
    let mut out = Vec::with_capacity(n);
    let mut col = Vec::with_capacity(hist.len());
    for i in 0..n {
        col.clear();
        col.extend(hist.iter().map(|h| h[i]));
        out.push(percentile(&col, pct));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ties_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
    }

    #[test]
    fn sym_quantize_clamps() {
        assert_eq!(sym_quantize_one(1000.0, 1.0), 127);
        assert_eq!(sym_quantize_one(-1000.0, 1.0), -127);
        assert_eq!(sym_quantize_one(0.0, 1.0), 0);
    }

    #[test]
    fn colwise_scales_per_column() {
        // col0 max 4, col1 max 0.5
        let w = [4.0f32, 0.5, -2.0, -0.25];
        let (q, s) = quantize_weight_colwise(&w, 2, 2);
        assert!((s[0] - (4.0 / 127.0) as f32).abs() < 1e-9);
        assert!((s[1] - (0.5 / 127.0) as f32).abs() < 1e-9);
        assert_eq!(q[0], 127); // 4 / (4/127)
        assert_eq!(q[3], -64); // -0.25/(0.5/127) = -63.5 -> ties-even -> -64
    }

    #[test]
    fn colwise_roundtrip_error_bound() {
        // |w - q*s| <= s/2 for every element
        let w: Vec<f32> = (0..64).map(|i| ((i * 37 % 100) as f32 - 50.0) / 13.0).collect();
        let (q, s) = quantize_weight_colwise(&w, 8, 8);
        for row in 0..8 {
            for col in 0..8 {
                let recon = q[row * 8 + col] as f32 * s[col];
                assert!((recon - w[row * 8 + col]).abs() <= s[col] / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn zero_column_guard() {
        let w = [0.0f32, 1.0, 0.0, -1.0];
        let (q, s) = quantize_weight_colwise(&w, 2, 2);
        assert!(s[0] > 0.0);
        assert_eq!(q[0], 0);
        assert_eq!(q[2], 0);
    }

    #[test]
    fn percentile_linear_matches_numpy() {
        // np.percentile([1,2,3,4], 50) == 2.5 ; 25 -> 1.75 ; 100 -> 4
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.5);
        assert!((percentile(&[1.0, 2.0, 3.0, 4.0], 25.0) - 1.75).abs() < 1e-12);
        assert_eq!(percentile(&[3.0, 1.0, 4.0, 2.0], 100.0), 4.0);
    }

    #[test]
    fn scale_floor_guards_zeros() {
        assert!(scale_from_absmax(0.0) > 0.0);
        assert!(scale_from_max_nonneg(0.0) > 0.0);
    }
}
