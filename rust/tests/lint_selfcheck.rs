//! The tree passes its own linter (DESIGN.md §5.11).
//!
//! Runs on a bare checkout — herolint needs no artifacts.  This is the
//! in-process twin of the `scripts/ci.sh` stage (`cargo run --release
//! -- lint`): zero unsuppressed findings across the five analyses, and
//! the observed lock order stays a DAG (a cycle is reported as a
//! `lock-order` finding, so `clean()` covers it).

use std::path::Path;

#[test]
fn source_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = zqhero::lint::lint_tree(&root).expect("walking the source tree");
    assert!(
        report.clean(),
        "unsuppressed lint findings — fix the site or annotate with a reason:\n{}",
        report.render()
    );
    // guard against the vacuous pass: the walk really covered the
    // serving spine (hundreds of functions, locks observed in order)
    let a = &report.analysis;
    assert!(a.files >= 30, "only {} files linted — wrong root?", a.files);
    assert!(a.functions >= 300, "only {} functions extracted", a.functions);
    assert!(
        !a.edges.is_empty(),
        "no lock-order edges observed — the extractor lost the lock sites"
    );
    // the documented discipline (DESIGN.md §5.11): replica-slot critical
    // sections acquire downstream locks, never the reverse
    assert!(
        a.edges.iter().any(|e| e.from == "replica slot" && e.to == "job queue"),
        "expected the replica-slot -> job-queue edge from supervised close"
    );
}

#[test]
fn suppressions_are_in_use_but_bounded() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = zqhero::lint::lint_tree(&root).expect("walking the source tree");
    let a = &report.analysis;
    // every suppression carries a reviewed reason; this ceiling forces
    // the next hot-path unwrap to be *triaged* (typed error, poison
    // recovery, or a new justified annotation that raises the bound)
    assert!(
        a.suppressed_panic <= 60,
        "panic-ok count grew to {} — triage new sites instead of annotating by reflex",
        a.suppressed_panic
    );
    assert!(
        a.suppressed_relaxed <= 12,
        "relaxed-ok count grew to {} — most Relaxed sites should be upgraded, not excused",
        a.suppressed_relaxed
    );
    // hold-across-blocking triage: the worker-pool recv() handoff is the
    // one reviewed exception; a second one deserves a design review
    assert!(
        (1..=3).contains(&a.suppressed_block),
        "block-ok count is {} — expected the ThreadPool recv() handoff (and little else)",
        a.suppressed_block
    );
}
