//! Serving metrics: lock-light latency/throughput recording with
//! log-bucketed histograms, keyed by precision mode.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Log2-bucketed latency histogram (microseconds).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) us; 64 buckets.
    counts: Vec<u64>,
    total: u64,
    sum_us: u128,
    max_us: u64,
    min_us: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; 64], total: 0, sum_us: 0, max_us: 0, min_us: u64::MAX }
    }

    pub fn record(&mut self, us: u64) {
        let bucket = 63 - us.max(1).leading_zeros() as usize;
        self.counts[bucket.min(63)] += 1;
        self.total += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
        self.min_us = self.min_us.min(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    /// Percentile estimate from bucket boundaries (upper bound of bucket).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let want = (self.total as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= want {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    pub fn max_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.max_us }
    }

    pub fn min_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min_us }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug, Default, Clone)]
pub struct ModeStats {
    pub latency: Histogram,
    pub exec: Histogram,
    pub queue: Histogram,
    pub requests: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub errors: u64,
}

impl ModeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rows as f64 / self.batches as f64
        }
    }
}

/// Shared recorder (single mutex — recording is tiny next to inference).
pub struct Recorder {
    start: Instant,
    inner: Mutex<BTreeMap<String, ModeStats>>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder { start: Instant::now(), inner: Mutex::new(BTreeMap::new()) }
    }

    pub fn record_request(&self, mode: &str, total_us: u64, queue_us: u64, err: bool) {
        let mut g = self.inner.lock().unwrap();
        let s = g.entry(mode.to_string()).or_default();
        s.requests += 1;
        if err {
            s.errors += 1;
        } else {
            s.latency.record(total_us);
            s.queue.record(queue_us);
        }
    }

    pub fn record_batch(&self, mode: &str, rows: usize, exec_us: u64) {
        let mut g = self.inner.lock().unwrap();
        let s = g.entry(mode.to_string()).or_default();
        s.batches += 1;
        s.batched_rows += rows as u64;
        s.exec.record(exec_us);
    }

    pub fn snapshot(&self) -> BTreeMap<String, ModeStats> {
        self.inner.lock().unwrap().clone()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Human-readable summary table.
    pub fn render(&self) -> String {
        use crate::bench::Table;
        let snap = self.snapshot();
        let elapsed = self.elapsed_s();
        let mut t = Table::new(&[
            "mode", "reqs", "errs", "thr(req/s)", "mean batch", "p50 lat", "p95 lat",
            "p99 lat", "mean exec/batch",
        ]);
        for (mode, s) in &snap {
            t.row(vec![
                mode.clone(),
                s.requests.to_string(),
                s.errors.to_string(),
                format!("{:.1}", s.requests as f64 / elapsed.max(1e-9)),
                format!("{:.2}", s.mean_batch_size()),
                format!("{:.1}ms", s.latency.percentile_us(0.50) as f64 / 1e3),
                format!("{:.1}ms", s.latency.percentile_us(0.95) as f64 / 1e3),
                format!("{:.1}ms", s.latency.percentile_us(0.99) as f64 / 1e3),
                format!("{:.1}ms", s.exec.mean_us() / 1e3),
            ]);
        }
        t.render()
    }
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_percentiles() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 5120] {
            h.record(us);
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(0.5) >= 80);
        assert!(h.percentile_us(1.0) >= 5120);
        assert_eq!(h.min_us(), 10);
        assert_eq!(h.max_us(), 5120);
    }

    #[test]
    fn histogram_zero_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn recorder_accumulates_per_mode() {
        let r = Recorder::new();
        r.record_request("m3", 1000, 100, false);
        r.record_request("m3", 2000, 200, false);
        r.record_request("fp", 99, 9, true);
        r.record_batch("m3", 8, 500);
        let snap = r.snapshot();
        assert_eq!(snap["m3"].requests, 2);
        assert_eq!(snap["fp"].errors, 1);
        assert_eq!(snap["m3"].mean_batch_size(), 8.0);
        assert!(r.render().contains("m3"));
    }
}
