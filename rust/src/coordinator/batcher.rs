//! Dynamic batcher: groups requests by interned (task, policy), flushes a
//! group when it reaches `max_batch` or its oldest request has waited
//! `max_wait`.
//!
//! The core is a pure state machine (`push`/`tick` return ready batches),
//! which makes the invariants property-testable without threads:
//!   * no batch exceeds `max_batch`;
//!   * a request is emitted exactly once, in FIFO order within its group;
//!   * no request waits longer than `max_wait` once `tick` is called.
//!
//! Groups live in a flat `Vec` scanned linearly: the group count is the
//! handful of admitted (task, policy) routes, for which two-integer key
//! compares beat hashing — and `push` allocates nothing once the group's
//! deque has warmed up.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::{GroupKey, Request};

pub struct Batch {
    pub key: GroupKey,
    pub requests: Vec<Request>,
}

pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
    groups: Vec<(GroupKey, VecDeque<Request>)>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher { max_batch, max_wait, groups: Vec::new() }
    }

    /// Add a request; returns any batch made ready by this arrival.
    pub fn push(&mut self, req: Request) -> Option<Batch> {
        let key = req.key;
        let idx = match self.groups.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                self.groups.push((key, VecDeque::new()));
                self.groups.len() - 1
            }
        };
        let q = &mut self.groups[idx].1;
        q.push_back(req);
        if q.len() >= self.max_batch {
            let requests = q.drain(..self.max_batch).collect();
            Some(Batch { key, requests })
        } else {
            None
        }
    }

    /// Flush groups whose oldest request has exceeded `max_wait`.
    pub fn tick(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        for (key, q) in self.groups.iter_mut() {
            while let Some(front) = q.front() {
                if now.duration_since(front.enqueued) >= self.max_wait {
                    let take = q.len().min(self.max_batch);
                    let requests: Vec<Request> = q.drain(..take).collect();
                    out.push(Batch { key: *key, requests });
                } else {
                    break;
                }
            }
        }
        out
    }

    /// Force-flush everything (shutdown / drain).
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for (key, q) in self.groups.iter_mut() {
            while !q.is_empty() {
                let take = q.len().min(self.max_batch);
                out.push(Batch { key: *key, requests: q.drain(..take).collect() });
            }
        }
        out
    }

    pub fn pending(&self) -> usize {
        self.groups.iter().map(|(_, q)| q.len()).sum()
    }

    /// Earliest deadline across groups (for the batcher thread's
    /// `recv_timeout`); None when empty.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.groups
            .iter()
            .filter_map(|(_, q)| q.front().map(|r| r.enqueued + self.max_wait))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{PolicyId, TaskId};
    use crate::prop::{forall, Rng};
    use std::sync::mpsc::channel;

    fn key(task: u16, policy: u16) -> GroupKey {
        GroupKey { task: TaskId(task), policy: PolicyId(policy) }
    }

    fn req(id: u64, task: u16, policy: u16, at: Instant) -> Request {
        let (tx, _rx) = channel();
        // leak the receiver side: batcher tests never reply
        std::mem::forget(_rx);
        Request {
            id,
            key: key(task, policy),
            ids: vec![],
            type_ids: vec![],
            enqueued: at,
            reply: tx,
        }
    }

    #[test]
    fn flushes_on_max_batch() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        let t = Instant::now();
        assert!(b.push(req(0, 0, 0, t)).is_none());
        assert!(b.push(req(1, 0, 0, t)).is_none());
        let batch = b.push(req(2, 0, 0, t)).expect("full batch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn groups_are_isolated() {
        let mut b = Batcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        assert!(b.push(req(0, 0, 0, t)).is_none());
        assert!(b.push(req(1, 0, 1, t)).is_none());
        assert!(b.push(req(2, 1, 0, t)).is_none());
        assert_eq!(b.pending(), 3);
        let batch = b.push(req(3, 0, 0, t)).expect("task-0 mode-0 full");
        assert_eq!(batch.key, key(0, 0));
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3]);
    }

    #[test]
    fn tick_flushes_aged() {
        let mut b = Batcher::new(16, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(req(0, 0, 0, t0));
        b.push(req(1, 0, 0, t0));
        assert!(b.tick(t0 + Duration::from_millis(1)).is_empty());
        let out = b.tick(t0 + Duration::from_millis(6));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = Batcher::new(16, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(b.next_deadline().is_none());
        b.push(req(0, 0, 0, t0));
        b.push(req(1, 1, 0, t0 + Duration::from_millis(3)));
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
    }

    // ------------------------------------------------------- properties

    #[test]
    fn prop_exactly_once_fifo_and_bounded() {
        forall("batcher-invariants", 50, |r: &mut Rng| {
            let max_batch = 1 + r.below(8);
            let mut b = Batcher::new(max_batch, Duration::from_millis(r.below(20) as u64));
            let tasks = [0u16, 1, 2];
            let modes = [0u16, 1];
            let t0 = Instant::now();
            let n = 1 + r.below(200);
            let mut emitted: Vec<(GroupKey, u64)> = Vec::new();
            let mut collect = |batches: Vec<Batch>, emitted: &mut Vec<(GroupKey, u64)>| {
                for batch in batches {
                    assert!(batch.requests.len() <= max_batch, "batch overflow");
                    assert!(!batch.requests.is_empty());
                    for q in &batch.requests {
                        assert_eq!(q.key, batch.key);
                        emitted.push((q.key, q.id));
                    }
                }
            };
            for id in 0..n as u64 {
                let task = *r.choice(&tasks);
                let mode = *r.choice(&modes);
                let at = t0 + Duration::from_millis(id);
                if let Some(batch) = b.push(req(id, task, mode, at)) {
                    collect(vec![batch], &mut emitted);
                }
                if r.below(10) == 0 {
                    let out = b.tick(t0 + Duration::from_millis(id + r.below(30) as u64));
                    collect(out, &mut emitted);
                }
            }
            collect(b.drain_all(), &mut emitted);
            assert_eq!(b.pending(), 0);
            // exactly once
            assert_eq!(emitted.len(), n);
            let mut ids: Vec<u64> = emitted.iter().map(|(_, id)| *id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), n, "duplicate or lost request");
            // FIFO within each group (ids are submit-ordered)
            for task in &tasks {
                for mode in &modes {
                    let k = key(*task, *mode);
                    let seq: Vec<u64> =
                        emitted.iter().filter(|(g, _)| *g == k).map(|(_, id)| *id).collect();
                    let mut sorted = seq.clone();
                    sorted.sort_unstable();
                    assert_eq!(seq, sorted, "group {k:?} out of order");
                }
            }
        });
    }
}
