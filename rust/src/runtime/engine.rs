//! Engine threads: each replica owns a (non-`Send`) PJRT runtime and
//! serves execution requests over queues — the executor-thread pattern a
//! production GPU server uses.  The coordinator and its worker pool stay
//! fully `Send`.
//!
//! PR 3 replicated the engine: `EnginePool` spawns N replica threads
//! (each with its own `Runtime`, preloaded checkpoints, and precompiled
//! executables) behind a load-aware dispatcher (`DispatchState`,
//! DESIGN.md §5.7).  A batch routes to the replica with the fewest
//! in-flight batches; a (task, policy) group is pinned to one replica
//! while it has batches in flight — same-replica FIFO execution keeps the
//! group's batches in submit order — and may migrate once it drains.
//!
//! PR 6 adds replica *supervision* (DESIGN.md §5.10).  Each replica
//! incarnation carries a heartbeat (`ReplicaHealth`, beaten at job
//! de-queue, post-upload, and retire), a `JobQueue` that can be closed
//! and drained from outside, and a `SweepTable` parking every
//! device-committed completion.  A supervisor thread watches all three:
//! a finished thread (panic/exit) or a heartbeat stalled past the
//! watchdog budget while work is in flight marks the replica dead, at
//! which point queued jobs are drained and resubmitted to live replicas,
//! in-flight completions are swept with a typed [`ReplicaFailed`] error
//! (exactly once — `Completion` carries a drop-guard so no path can leak
//! a client), and the replica is respawned under exponential backoff
//! with a restart-budget circuit breaker.  `DispatchState` tags every
//! assignment with the replica's generation so completions from a dead
//! incarnation are dropped as stale.  Faults for the chaos suite are
//! scripted through a structured [`FaultPlan`] instead of ad-hoc knobs,
//! and a fake device (`EngineOptions::fake`) runs the whole machine
//! without artifacts or PJRT.
//!
//! PR 9 replaces the eager full-grid executable preload with per-replica
//! *residency* (DESIGN.md §5.13): each slot owns a [`Residency`] table;
//! startup synchronously loads only the manifest-derived pin set, other
//! `(mode, seq, batch)` cells compile on first demand (single-flight,
//! LRU-evicted under `EngineOptions::max_resident_cells`/`_bytes`), and
//! `Msg::Warm` prefetches cells between jobs so a governed downgrade
//! never stalls on a cold rung.  `Msg::Reload` installs a new manifest
//! version ([`VersionPayload`]) without stopping the loop: new-version
//! requests route in while the old version drains and its cells unpin
//! and age out.  Preload failures are typed per cell ([`PreloadError`]);
//! the supervisor treats one as a deterministic artifact fault and
//! excludes the slot immediately instead of burning the restart budget.
//!
//! Each replica's request loop is a software pipeline (DESIGN.md §5.4):
//! while batch N executes on the device, batch N+1's host arrays are
//! uploaded, and batch N's readback is deferred until N+1 has been
//! launched, so the device never idles waiting on a host copy.  Readback
//! results (de-batching, reply dispatch) are handed to the shared
//! `exec::ThreadPool` instead of blocking the engine thread.  Jobs carry
//! only interned `TaskId`/`PolicyId` — no strings on the hot path; the
//! engine selects the executable through its mirrored `policy -> exec
//! mode` table (manifest-derived, so it agrees with the coordinator's
//! without a handshake — DESIGN.md §6.3).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use crate::sync::thread::JoinHandle;
use crate::sync::{Arc, Condvar, Mutex, RwLock};

use anyhow::{anyhow, Context, Result};

use crate::exec::ThreadPool;
use crate::model::manifest::{Manifest, ModeId, PolicyId, TaskId};
use crate::model::tensor::Tensor;
use crate::model::Container;

use super::residency::{Begin, CellKey, Residency};
use super::staging::{StagingBuf, StagingPool};
use super::{InputBufs, PendingOutputs, Runtime};

/// Completion callback: runs on the shared worker pool with the batch
/// result (readback stage output).  Owning the per-request reply senders,
/// it is where de-batching and reply dispatch happen.
///
/// A `Completion` is a *liability*, not a plain closure: every admitted
/// batch holds backlog slots (`depth`) and client reply channels that are
/// only released when the callback runs.  The drop-guard makes that
/// structural — if a `Completion` is dropped without [`Completion::run`]
/// (a job stranded in a dead replica's queue, a panic unwinding the
/// engine loop), the callback still fires with a [`ReplicaFailed`] error,
/// so no failure path can hang a client or leak admission accounting.
pub struct Completion {
    f: Option<Box<dyn FnOnce(Result<InferDone>) + Send + 'static>>,
}

impl Completion {
    pub fn new(f: impl FnOnce(Result<InferDone>) + Send + 'static) -> Self {
        Completion { f: Some(Box::new(f)) }
    }

    /// Invoke the callback with `res`.  The closure is taken out first,
    /// so a panic *inside* the callback does not re-fire the drop-guard.
    pub fn run(mut self, res: Result<InferDone>) {
        if let Some(f) = self.f.take() {
            f(res);
        }
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        if let Some(f) = self.f.take() {
            // the guard runs on whatever thread dropped the job (engine
            // unwind, queue drain, supervisor) — isolate callback panics
            // so the guard itself can never take down its host
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                f(Err(anyhow::Error::new(ReplicaFailed)))
            }));
        }
    }
}

impl std::fmt::Debug for Completion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.f.is_some() { "Completion(pending)" } else { "Completion(spent)" })
    }
}

/// Cancel-before-submit hook (DESIGN.md §5.8): the engine thread calls
/// this once per job, after de-queueing it and *before* any device work
/// (upload/launch).  `true` abandons the batch — its completion runs
/// with a [`CancelledBeforeSubmit`] error and the staging buffer is
/// recycled untouched.  This is the only cancellation point past batch
/// formation; once upload starts a batch always executes to completion.
pub type CancelCheck = Box<dyn Fn() -> bool + Send + 'static>;

/// Sentinel error a cancelled job's completion receives; completions
/// `downcast_ref` it to tell deadline expiry from real engine failures.
#[derive(Debug, Clone, Copy)]
pub struct CancelledBeforeSubmit;

impl std::fmt::Display for CancelledBeforeSubmit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("batch cancelled before engine submit (every request past its deadline)")
    }
}

impl std::error::Error for CancelledBeforeSubmit {}

/// Typed terminal error for a batch lost to replica death (DESIGN.md
/// §5.10): the replica panicked, stalled past the watchdog budget, or
/// went away with the batch queued/in flight.  Completions downcast it
/// to route the request to the `failed` ledger column rather than the
/// generic error path.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaFailed;

impl std::fmt::Display for ReplicaFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("engine replica failed before the batch completed")
    }
}

impl std::error::Error for ReplicaFailed {}

/// Typed startup/preload failure naming the exact artifact cell that
/// broke (DESIGN.md §5.13).  Deterministic: retrying the incarnation
/// would fail on the same cell, so the supervisor downcasts this from a
/// restart's ready channel and *excludes* the slot immediately instead
/// of crash-looping the restart circuit breaker against it.
#[derive(Debug, Clone)]
pub enum PreloadError {
    /// A (task, mode) checkpoint failed to load/upload.
    Checkpoint { task: String, mode: String, cause: String },
    /// A (mode, seq bucket, batch bucket) executable cell failed to
    /// compile or upload.
    Executable { mode: String, seq: usize, bucket: usize, cause: String },
}

impl std::fmt::Display for PreloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PreloadError::Checkpoint { task, mode, cause } => {
                write!(f, "preload failed at checkpoint ({task}, {mode}): {cause}")
            }
            PreloadError::Executable { mode, seq, bucket, cause } => {
                write!(
                    f,
                    "preload failed at executable cell ({mode}, seq {seq}, bucket {bucket}): \
                     {cause}"
                )
            }
        }
    }
}

impl std::error::Error for PreloadError {}

pub struct InferJob {
    pub task: TaskId,
    /// Interned precision policy; the engine maps it to its executable
    /// mode via the mirrored `policy_exec` table.
    pub policy: PolicyId,
    /// Manifest version (hot reload, DESIGN.md §5.13): selects the
    /// checkpoint set and executable cells; 0 until the first reload.
    pub version: u32,
    /// Pooled host buffers: `bucket * seq` ids/type_ids/mask.  Recycled to
    /// the staging pool by the engine right after the device upload.
    pub staging: StagingBuf,
    /// Checked once before upload; `None` = never cancel (the common
    /// case: only all-deadline batches carry a check).
    pub cancel: Option<CancelCheck>,
    pub done: Completion,
}

pub struct InferDone {
    pub logits: Tensor,
    /// launch -> readback-complete time (engine-thread measured), us.
    /// The clock starts *after* `upload_inputs` returns, so `upload_us`
    /// is never double-counted here.  Under overlap this still includes
    /// the next batch's upload window.
    pub exec_us: u64,
    /// host -> device input copy time, microseconds.
    pub upload_us: u64,
    /// whole-job engine time (job receipt -> readback complete), us —
    /// the same quantity `Timing::engine_us` carries to clients (the
    /// end-to-end time is `Timing::total_us`, a different clock).
    /// Invariant: `upload_us + exec_us <= engine_us`.
    pub engine_us: u64,
    /// Replica that executed the batch (0 for a single engine).
    pub replica: usize,
    /// Per-replica batch serial, stamped in execution order — combined
    /// with `replica`, the cross-replica FIFO witness (same-replica
    /// batches of a group execute in submit order).
    pub exec_seq: u64,
    /// Time the batch spent resolving its executable cell against the
    /// residency table, us — ~0 on a hit, the compile+upload latency on
    /// a miss.  Measured *before* the `engine_us` clock starts, so a
    /// miss-caused slow request is attributable (DESIGN.md §5.13).
    pub load_wait_us: u64,
}

enum Msg {
    Infer(Box<InferJob>),
    /// Install a new manifest version (hot reload).  Idempotent: a
    /// version the replica already knows (startup snapshot vs queued
    /// reload race) is skipped.
    Reload(Arc<VersionPayload>),
    /// Prefetch one executable cell between jobs (governed-rung warm).
    Warm(CellKey),
    Stop,
}

// ------------------------------------------------------------------ faults

/// One scripted fault kind (DESIGN.md §5.10).  Batch indices count the
/// jobs a replica incarnation de-queues (0-based), except
/// `CompletionPanicAt`, which counts coordinator dispatch sequence
/// numbers (it fires in the completion callback, not the engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the engine thread when it de-queues batch `batch` — the
    /// held job's completion drop-guard delivers `ReplicaFailed` during
    /// unwind; the supervisor reaps the thread.
    PanicAt { batch: u64 },
    /// Sleep `dur` after de-queueing batch `batch` (post-heartbeat): a
    /// hung device call for the watchdog to detect.
    StallFor { batch: u64, dur: Duration },
    /// Sleep per de-queued job, before the cancel check and any device
    /// work — the deterministic service-rate throttle the overload
    /// suite builds queue pressure with (previously
    /// `ServerConfig::throttle_batch`).
    Throttle { per_batch: Duration },
    /// Close the replica's own submit queue after de-queueing batch
    /// `after_batch`: later pushes fail and the pool reroutes, while
    /// already-queued work drains normally.
    FailSubmit { after_batch: u64 },
    /// Sleep per batch before the input upload (a slow host->device
    /// link; with a tight watchdog this reads as a stall).
    SlowUpload { per_batch: Duration },
    /// Coordinator-side: panic the completion callback of dispatch batch
    /// `batch_seq` (previously `ServerConfig::fault_inject_batch`) —
    /// exercises worker-pool panic isolation and depth-release ordering.
    CompletionPanicAt { batch_seq: u64 },
    /// Fail the incarnation's startup with a typed [`PreloadError`]
    /// (simulated corrupt artifact cell) — drives the supervisor's
    /// immediate-exclusion path.
    FailPreload,
}

/// A fault kind scoped to a replica and lifetime.  By default a fault
/// applies only to generation 0 (the original incarnation), so a
/// restarted replica comes back healthy; `persistent` faults survive
/// restarts (how the chaos suite drives the circuit breaker), and
/// `from_gen` delays a fault until a later incarnation (e.g. a preload
/// failure that appears only on restart).
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// `None` = every replica.
    pub replica: Option<usize>,
    pub kind: FaultKind,
    pub persistent: bool,
    /// First generation the fault applies to (default 0).
    pub min_generation: u64,
}

impl FaultSpec {
    /// Fault every replica's first incarnation.
    pub fn all(kind: FaultKind) -> Self {
        FaultSpec { replica: None, kind, persistent: false, min_generation: 0 }
    }

    /// Fault one replica's first incarnation.
    pub fn on(replica: usize, kind: FaultKind) -> Self {
        FaultSpec { replica: Some(replica), kind, persistent: false, min_generation: 0 }
    }

    /// Apply to every incarnation (survives supervised restart).
    pub fn persistent(mut self) -> Self {
        self.persistent = true;
        self
    }

    /// Apply only from generation `g` on (pair with `persistent` —
    /// non-persistent faults are already limited to generation 0).
    pub fn from_gen(mut self, g: u64) -> Self {
        self.min_generation = g;
        self
    }
}

/// Structured fault-injection plan threaded through `EngineOptions`
/// (DESIGN.md §5.10): the test-only plane the chaos suite scripts
/// replica death, stalls, and slow paths with.  Empty in production.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn with(mut self, spec: FaultSpec) -> Self {
        self.faults.push(spec);
        self
    }

    /// Service-rate throttle on every replica, every incarnation — the
    /// migration shim for the old `throttle_batch` knob.
    pub fn throttle(per_batch: Duration) -> Self {
        FaultPlan::default().with(FaultSpec::all(FaultKind::Throttle { per_batch }).persistent())
    }

    /// Coordinator-side completion panic — the migration shim for the
    /// old `fault_inject_batch` knob.
    pub fn completion_panic_at(batch_seq: u64) -> Self {
        FaultPlan::default().with(FaultSpec::all(FaultKind::CompletionPanicAt { batch_seq }))
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The dispatch sequence number whose completion should panic, if
    /// scripted (consumed by the coordinator, not the engine).
    pub fn completion_panic(&self) -> Option<u64> {
        self.faults.iter().find_map(|s| match s.kind {
            FaultKind::CompletionPanicAt { batch_seq } => Some(batch_seq),
            _ => None,
        })
    }

    /// Resolve the engine-side faults for one replica incarnation.
    fn for_replica(&self, replica: usize, generation: u64) -> EngineFaults {
        let mut f = EngineFaults::default();
        for spec in &self.faults {
            if spec.replica.is_some_and(|r| r != replica) {
                continue;
            }
            if generation > 0 && !spec.persistent {
                continue;
            }
            if generation < spec.min_generation {
                continue;
            }
            match spec.kind {
                FaultKind::PanicAt { batch } => f.panic_at = Some(batch),
                FaultKind::StallFor { batch, dur } => f.stall = Some((batch, dur)),
                FaultKind::Throttle { per_batch } => f.throttle = Some(per_batch),
                FaultKind::FailSubmit { after_batch } => f.fail_submit_after = Some(after_batch),
                FaultKind::SlowUpload { per_batch } => f.slow_upload = Some(per_batch),
                FaultKind::CompletionPanicAt { .. } => {}
                FaultKind::FailPreload => f.fail_preload = true,
            }
        }
        f
    }
}

/// Per-incarnation resolved fault script (engine-side kinds only).
#[derive(Debug, Clone, Copy, Default)]
struct EngineFaults {
    panic_at: Option<u64>,
    stall: Option<(u64, Duration)>,
    throttle: Option<Duration>,
    fail_submit_after: Option<u64>,
    slow_upload: Option<Duration>,
    fail_preload: bool,
}

// ------------------------------------------------------------- supervision

/// Supervised-restart tuning (DESIGN.md §5.10): a dead replica respawns
/// after `backoff * 2^consecutive_failures` (capped at `max_backoff`);
/// `budget` failures within `window` trip the circuit breaker and the
/// replica is excluded for the life of the pool.
#[derive(Debug, Clone)]
pub struct RestartPolicy {
    pub backoff: Duration,
    pub max_backoff: Duration,
    pub budget: usize,
    pub window: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
            budget: 5,
            window: Duration::from_secs(60),
        }
    }
}

/// Per-incarnation liveness signal: `progress` is a monotonic counter
/// beaten at job de-queue, post-upload, and retire; `beat_us` is the
/// beat's timestamp (micros since the pool epoch).  The watchdog reads
/// `progress`; the health table renders `beat_us` age.
#[derive(Debug, Default)]
struct ReplicaHealth {
    progress: AtomicU64,
    beat_us: AtomicU64,
}

impl ReplicaHealth {
    fn beat(&self, epoch: &Instant) {
        self.progress.fetch_add(1, Ordering::SeqCst);
        self.beat_us.store(epoch.elapsed().as_micros() as u64, Ordering::SeqCst);
    }

    fn progress(&self) -> u64 {
        self.progress.load(Ordering::SeqCst)
    }

    fn beat_age_us(&self, epoch: &Instant) -> u64 {
        let now = epoch.elapsed().as_micros() as u64;
        now.saturating_sub(self.beat_us.load(Ordering::SeqCst))
    }
}

/// Closable, drainable job queue (replaces the mpsc channel so the
/// supervisor can reclaim queued jobs from outside).  `close` (graceful
/// shutdown) rejects new pushes but lets queued work drain; `poison`
/// (replica death, via `close_and_drain`) additionally tells a
/// still-running incarnation to abandon work on sight.
struct JobQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    poisoned: AtomicBool,
}

struct QueueInner {
    q: VecDeque<Msg>,
    closed: bool,
}

enum TryPop {
    Msg(Msg),
    Empty,
    Closed,
}

impl JobQueue {
    fn new() -> Arc<Self> {
        Arc::new(JobQueue {
            inner: Mutex::new(QueueInner { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            poisoned: AtomicBool::new(false),
        })
    }

    /// Enqueue; `Err` hands the message back when the queue is closed.
    fn push(&self, msg: Msg) -> std::result::Result<(), Msg> {
        // panic-ok: queue critical sections are push/pop/flag flips that
        // cannot panic while holding the lock
        let mut inner = self.inner.lock().expect("job queue");
        if inner.closed {
            return Err(msg);
        }
        inner.q.push_back(msg);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking de-queue; `None` once the queue is closed *and* empty
    /// (graceful close drains queued work first).
    fn pop(&self) -> Option<Msg> {
        // panic-ok: queue critical sections are panic-free (see push)
        let mut inner = self.inner.lock().expect("job queue");
        loop {
            if let Some(m) = inner.q.pop_front() {
                return Some(m);
            }
            if inner.closed {
                return None;
            }
            // panic-ok: wait() re-acquires the same panic-free lock
            inner = self.cv.wait(inner).expect("job queue");
        }
    }

    /// Non-blocking de-queue (the overlap loop's try-recv analogue).
    fn try_pop(&self) -> TryPop {
        // panic-ok: queue critical sections are panic-free (see push)
        let mut inner = self.inner.lock().expect("job queue");
        match inner.q.pop_front() {
            Some(m) => TryPop::Msg(m),
            None if inner.closed => TryPop::Closed,
            None => TryPop::Empty,
        }
    }

    /// Graceful close: new pushes fail, queued work still drains.
    fn close(&self) {
        // panic-ok: queue critical sections are panic-free (see push)
        self.inner.lock().expect("job queue").closed = true;
        self.cv.notify_all();
    }

    /// Death close: reject pushes, reclaim everything queued, and poison
    /// the queue so a hung-but-alive incarnation abandons work on wake.
    fn close_and_drain(&self) -> Vec<Msg> {
        self.poisoned.store(true, Ordering::SeqCst);
        // panic-ok: queue critical sections are panic-free (see push)
        let mut inner = self.inner.lock().expect("job queue");
        inner.closed = true;
        let drained = inner.q.drain(..).collect();
        self.cv.notify_all();
        drained
    }

    fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }
}

/// Parking lot for device-committed completions.  The engine registers a
/// completion right before upload and takes it back at retire; when the
/// supervisor declares the incarnation dead it sweeps the table instead.
/// The mutex makes take-vs-sweep a race with exactly one winner, so every
/// completion runs exactly once no matter which side gets there first.
#[derive(Default)]
struct SweepTable {
    inner: Mutex<SweepInner>,
}

#[derive(Default)]
struct SweepInner {
    next: u64,
    slots: HashMap<u64, Completion>,
}

impl SweepTable {
    fn register(&self, done: Completion) -> u64 {
        // panic-ok: sweep-table critical sections are map ops that cannot
        // panic while holding the lock
        let mut inner = self.inner.lock().expect("sweep table");
        let id = inner.next;
        inner.next += 1;
        inner.slots.insert(id, done);
        id
    }

    fn take(&self, id: u64) -> Option<Completion> {
        // panic-ok: sweep-table critical sections are panic-free (see register)
        self.inner.lock().expect("sweep table").slots.remove(&id)
    }

    fn sweep(&self) -> Vec<Completion> {
        // panic-ok: sweep-table critical sections are panic-free (see register)
        let mut inner = self.inner.lock().expect("sweep table");
        inner.slots.drain().map(|(_, c)| c).collect()
    }
}

// ------------------------------------------------------------ engine handle

/// Route/policy tables mirrored out of the engine-side manifest at
/// startup: both sides derive ids from the same `manifest.json`, so the
/// coordinator's and engine's tables are identical by construction (the
/// parity the policy integration tests pin).
#[derive(Clone)]
struct RouteTables {
    tasks: Vec<String>,
    modes: Vec<String>,
    policies: Vec<String>,
    /// `[policy] -> executable mode` — the engine-side half of policy
    /// executable selection.
    policy_exec: Vec<ModeId>,
}

impl RouteTables {
    fn from_manifest(man: &Manifest) -> Self {
        RouteTables {
            tasks: man.task_order.clone(),
            modes: man.mode_order.clone(),
            policies: man.policy_order.clone(),
            policy_exec: man.policy_order.iter().map(|p| man.policies[p].exec_mode).collect(),
        }
    }

    fn task_id(&self, name: &str) -> Result<TaskId> {
        crate::model::manifest::intern_position(&self.tasks, name)
            .map(TaskId)
            .with_context(|| format!("unknown task {name:?}"))
    }

    fn mode_id(&self, name: &str) -> Result<ModeId> {
        crate::model::manifest::intern_position(&self.modes, name)
            .map(ModeId)
            .with_context(|| format!("unknown mode {name:?}"))
    }

    fn policy_id(&self, name: &str) -> Result<PolicyId> {
        crate::model::manifest::intern_position(&self.policies, name)
            .map(PolicyId)
            .with_context(|| format!("unknown policy {name:?} (have {:?})", self.policies))
    }

    fn policy_exec_mode(&self, policy: PolicyId) -> Result<ModeId> {
        self.policy_exec
            .get(policy.index())
            .copied()
            .with_context(|| format!("PolicyId {} out of range", policy.0))
    }
}

/// `Send` handle to one engine replica thread (blocking/CLI path; the
/// serving path talks to replicas through `EnginePool`'s slots).
pub struct Engine {
    queue: Arc<JobQueue>,
    join: Option<JoinHandle<()>>,
    tables: RouteTables,
}

/// A spawned-but-not-ready replica incarnation: the thread is live
/// (uploading checkpoints, precompiling executables) but has not
/// reported its route tables yet.  Startup fans all replicas out in this
/// state so preload/precompile runs concurrently; supervised restart
/// holds one while the respawned thread warms up, re-admitting the
/// replica to dispatch only once `ready_rx` reports success.
struct PendingReplica {
    queue: Arc<JobQueue>,
    join: JoinHandle<()>,
    health: Arc<ReplicaHealth>,
    sweep: Arc<SweepTable>,
    ready_rx: Receiver<Result<RouteTables>>,
}

impl PendingReplica {
    fn wait(self) -> Result<(LiveReplica, RouteTables)> {
        let tables = self
            .ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok((
            LiveReplica { queue: self.queue, join: self.join, health: self.health, sweep: self.sweep },
            tables,
        ))
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Overlap upload/execute/readback (one batch in flight behind the
    /// head).  `false` restores the strictly serial per-batch loop — kept
    /// for A/B benchmarking the pipeline win.
    pub overlap: bool,
    /// Engine replicas behind the pool dispatcher (min 1).  Each replica
    /// owns its own PJRT runtime, checkpoints, and executables.
    pub replicas: usize,
    /// Heartbeat stall budget: a replica with work in flight whose
    /// progress counter has not advanced for this long is declared dead
    /// (swept, drained, restarted).  `None` disables stall detection —
    /// thread death (panic/exit) is always detected.
    pub watchdog: Option<Duration>,
    /// Supervised-restart backoff and circuit-breaker budget.
    pub restart: RestartPolicy,
    /// Scripted fault plan (chaos suite; empty in production).
    pub fault_plan: FaultPlan,
    /// `Some(latency)` replaces the PJRT device with a fake that sleeps
    /// `latency` per batch and returns zero logits — the artifact-free
    /// path the chaos suite runs the full serving machine on.
    pub fake: Option<Duration>,
    /// Per-replica resident executable-cell budget (DESIGN.md §5.13):
    /// cold cells LRU-evict past this count.  `None` = unbounded.
    /// Pinned cells override the budget.
    pub max_resident_cells: Option<usize>,
    /// Per-replica resident executable byte budget (artifact file
    /// sizes).  `None` = unbounded.
    pub max_resident_bytes: Option<usize>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            overlap: true,
            replicas: 1,
            watchdog: None,
            restart: RestartPolicy::default(),
            fault_plan: FaultPlan::default(),
            fake: None,
            max_resident_cells: None,
            max_resident_bytes: None,
        }
    }
}

impl Engine {
    /// Spawn one engine replica and wait for it to become ready: it
    /// uploads every (task, mode) checkpoint in `preload` and pins the
    /// requested (mode, seq bucket, batch bucket) grid cells so those
    /// never compile on the hot path (other cells load on demand under
    /// residency).  `pool` runs completion callbacks; `staging` receives
    /// recycled host buffers.
    pub fn spawn(
        artifacts: PathBuf,
        preload: Vec<(String, String, Container)>,
        precompile: Vec<(String, usize, usize)>,
        pool: Arc<ThreadPool>,
        staging: Arc<StagingPool>,
        options: EngineOptions,
    ) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts)?;
        let pins = precompile
            .iter()
            .map(|(mode, seq, bucket)| Ok((manifest.mode_id(mode)?.0, *seq, *bucket)))
            .collect::<Result<Vec<_>>>()?;
        let payload = Arc::new(VersionPayload {
            version: 0,
            manifest: Arc::new(manifest),
            preload: Arc::new(preload),
            pins: Arc::new(pins),
        });
        let spawner = Spawner::new(payload, 1, pool, staging, options);
        let (live, tables) = spawner.spawn(0, 0, Instant::now())?.wait()?;
        Ok(Engine { queue: live.queue, join: Some(live.join), tables })
    }

    /// Enqueue a job; on failure (engine gone) the job is handed back so
    /// the caller can recycle its staging buffer and fail its requests.
    pub fn submit(&self, job: InferJob) -> std::result::Result<(), Box<InferJob>> {
        self.queue.push(Msg::Infer(Box::new(job))).map_err(|m| match m {
            Msg::Infer(job) => job,
            _ => unreachable!("submit only sends Infer"),
        })
    }

    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        self.tables.task_id(name)
    }

    pub fn mode_id(&self, name: &str) -> Result<ModeId> {
        self.tables.mode_id(name)
    }

    /// Resolve a policy name against the engine's mirrored table (uniform
    /// mode names included).
    pub fn policy_id(&self, name: &str) -> Result<PolicyId> {
        self.tables.policy_id(name)
    }

    /// The mirrored policy-name table (parity checks against the
    /// coordinator's `Manifest::policy_order`).
    pub fn policy_names(&self) -> &[String] {
        &self.tables.policies
    }

    /// The executable mode this policy selects on the engine.
    pub fn policy_exec_mode(&self, policy: PolicyId) -> Result<ModeId> {
        self.tables.policy_exec_mode(policy)
    }

    /// Synchronous convenience call (CLI paths, tests).  `route` is a
    /// policy name (uniform mode names work).  `ids`/`type_ids` are
    /// `[bucket * seq_bucket]` — the seq bucket derives from the payload
    /// length and must exist in the manifest grid; the mask is derived
    /// from PAD positions.
    pub fn infer_blocking(
        &self,
        task: &str,
        route: &str,
        bucket: usize,
        ids: Vec<i32>,
        type_ids: Vec<i32>,
    ) -> Result<InferDone> {
        if bucket == 0 || ids.len() % bucket != 0 {
            // deriving seq from a ragged payload would silently truncate
            // trailing tokens at from_parts' resize
            anyhow::bail!("ids len {} not a multiple of bucket {bucket}", ids.len());
        }
        let seq = ids.len() / bucket;
        let staging = StagingBuf::from_parts(bucket, seq, ids, type_ids);
        let (reply, rx) = channel();
        self.submit(InferJob {
            task: self.task_id(task)?,
            policy: self.policy_id(route)?,
            version: 0,
            staging,
            cancel: None,
            done: Completion::new(move |res| {
                let _ = reply.send(res);
            }),
        })
        .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // graceful close: queued work drains, then the loop exits
        self.queue.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// -------------------------------------------------------------- the device

/// The execution backend behind one replica: the real PJRT runtime, or a
/// fake that mimics its timing surface (sleep per batch, zero logits)
/// without artifacts — what lets the chaos suite drive the whole
/// supervision machine on a bare checkout.
enum EngineDevice {
    Real(Box<Runtime>),
    Fake { manifest: Arc<Manifest>, latency: Duration },
}

enum EngineInputs {
    Real(InputBufs),
    Fake { rows: usize },
}

enum EnginePending {
    Real(PendingOutputs),
    Fake { rows: usize },
}

impl EngineDevice {
    fn open(manifest: &Arc<Manifest>, fake: Option<Duration>) -> Result<EngineDevice> {
        match fake {
            Some(latency) => Ok(EngineDevice::Fake { manifest: Arc::clone(manifest), latency }),
            None => Runtime::new((**manifest).clone()).map(|rt| EngineDevice::Real(Box::new(rt))),
        }
    }

    fn manifest(&self) -> &Manifest {
        match self {
            EngineDevice::Real(rt) => &rt.manifest,
            EngineDevice::Fake { manifest, .. } => manifest,
        }
    }

    /// Upload one version's (task, mode) checkpoints (fake: no-op —
    /// there is nothing to stage).  A failure is typed per cell so the
    /// supervisor can tell a corrupt checkpoint from a dead replica.
    fn upload_version_checkpoints(&mut self, payload: &VersionPayload) -> Result<()> {
        if let EngineDevice::Real(rt) = self {
            for (task, mode, ckpt) in payload.preload.iter() {
                let ids = {
                    // name -> id resolution is stable across versions
                    // (reload requires identical task/mode orders)
                    let man = &rt.manifest;
                    man.task_id(task).and_then(|t| man.mode_id(mode).map(|m| (t, m)))
                };
                let res =
                    ids.and_then(|(t, m)| rt.upload_checkpoint_v(payload.version, t, m, ckpt));
                if let Err(e) = res {
                    return Err(anyhow::Error::new(PreloadError::Checkpoint {
                        task: task.clone(),
                        mode: mode.clone(),
                        cause: format!("{e:#}"),
                    }));
                }
            }
        }
        Ok(())
    }

    /// Compile + insert one executable grid cell; returns the artifact's
    /// on-disk size for the residency byte ledger.  The fake device has
    /// nothing to compile — loads are instant (0 bytes), which lets the
    /// chaos suite exercise the full residency protocol without PJRT.
    fn load_cell(&mut self, man: &Manifest, key: CellKey) -> Result<u64> {
        match self {
            EngineDevice::Real(rt) => {
                let mode = ModeId(key.mode);
                let (exe, bytes) = rt.load_exe(man, mode, key.seq, key.bucket)?;
                rt.insert_exe(key.version, mode, key.seq, key.bucket, exe);
                Ok(bytes)
            }
            EngineDevice::Fake { .. } => Ok(0),
        }
    }

    /// Drop evicted cells' device-side executables.
    fn evict_cells(&mut self, keys: &[CellKey]) {
        if let EngineDevice::Real(rt) = self {
            for k in keys {
                rt.remove_exe(k.version, ModeId(k.mode), k.seq, k.bucket);
            }
        }
    }

    /// Drop checkpoints of versions older than `keep_min` (reload keeps
    /// the current + draining versions' weights resident).
    fn drop_version_ckpts(&mut self, keep_min: u32) {
        if let EngineDevice::Real(rt) = self {
            rt.drop_version_ckpts(keep_min);
        }
    }

    fn upload(&self, host: &StagingBuf) -> Result<EngineInputs> {
        match self {
            EngineDevice::Real(rt) => rt
                .upload_inputs(host.seq, host.bucket, &host.ids, &host.type_ids, &host.mask)
                .map(EngineInputs::Real),
            EngineDevice::Fake { .. } => {
                let n = host.bucket * host.seq;
                if host.ids.len() != n || host.type_ids.len() != n || host.mask.len() != n {
                    anyhow::bail!(
                        "ids/type_ids/mask length mismatch for bucket {} * seq {}",
                        host.bucket,
                        host.seq
                    );
                }
                Ok(EngineInputs::Fake { rows: host.bucket })
            }
        }
    }

    /// Launch against a resident cell — `&self`, never compiles: the
    /// residency resolve above this call guaranteed the cell (a typed
    /// error surfaces if bookkeeping and device state disagree).
    fn execute(
        &self,
        version: u32,
        task: TaskId,
        mode: ModeId,
        inputs: &EngineInputs,
    ) -> Result<EnginePending> {
        match (self, inputs) {
            (EngineDevice::Real(rt), EngineInputs::Real(i)) => {
                rt.execute_model_at(version, task, mode, i).map(EnginePending::Real)
            }
            (EngineDevice::Fake { latency, .. }, EngineInputs::Fake { rows }) => {
                // the fake "device" is busy for the scripted latency —
                // blocking here gives tests a deterministic service rate
                crate::sync::thread::sleep(*latency);
                Ok(EnginePending::Fake { rows: *rows })
            }
            _ => unreachable!("device and inputs come from the same replica"),
        }
    }

    fn readback(&self, pending: EnginePending) -> Result<Tensor> {
        match (self, pending) {
            (EngineDevice::Real(rt), EnginePending::Real(p)) => rt.readback_logits(p),
            (EngineDevice::Fake { manifest, .. }, EnginePending::Fake { rows }) => {
                let nl = manifest.model.num_labels;
                Ok(Tensor::f32(vec![rows, nl], vec![0.0; rows * nl]))
            }
            _ => unreachable!("device and pending come from the same replica"),
        }
    }
}

// ---------------------------------------------------------------- dispatch

/// Load-aware replica dispatch state, shared by `EnginePool::submit`
/// (batcher thread), batch completions (worker pool), and the supervisor:
/// per-replica in-flight batch counts, liveness, incarnation generations,
/// and per-group pins.  A (task, policy) group is pinned to one replica
/// while it has batches in flight — same-replica FIFO execution keeps its
/// batches in submit order — and may migrate to the least-loaded replica
/// once it drains (DESIGN.md §5.7).  Every assignment is tagged with the
/// replica's generation; `mark_dead` bumps it, so completions issued to a
/// dead incarnation can never touch a revived replica's accounting
/// (DESIGN.md §5.10).  Pure state machine: unit- and property-tested
/// without engine threads.
pub struct DispatchState {
    /// Batches submitted to each replica and not yet completed.
    inflight: Vec<AtomicUsize>,
    /// Replicas currently out of service (dead, restarting, or excluded):
    /// excluded from least-loaded choice so a dead replica — which would
    /// otherwise sit at zero in-flight and win every tie — cannot
    /// attract all traffic and turn one failure into a full outage.
    dead: Vec<AtomicBool>,
    /// Incarnation counter per replica: bumped by `mark_dead`, left
    /// unchanged by `revive`.  A completion whose generation predates
    /// the current one is stale and dropped.
    generation: Vec<AtomicU64>,
    /// group -> (pinned replica, group batches in flight).  Entries exist
    /// only while a group has in-flight batches, so the map stays at the
    /// handful of currently-active routes.
    pins: Mutex<HashMap<(TaskId, PolicyId), (usize, usize)>>,
}

impl DispatchState {
    pub fn new(replicas: usize) -> Self {
        assert!(replicas > 0, "dispatch needs at least one replica");
        DispatchState {
            inflight: (0..replicas).map(|_| AtomicUsize::new(0)).collect(),
            dead: (0..replicas).map(|_| AtomicBool::new(false)).collect(),
            generation: (0..replicas).map(|_| AtomicU64::new(0)).collect(),
            pins: Mutex::new(HashMap::new()),
        }
    }

    pub fn replicas(&self) -> usize {
        self.inflight.len()
    }

    /// Batches submitted to `replica` and not yet completed.
    pub fn inflight(&self, replica: usize) -> usize {
        self.inflight[replica].load(Ordering::SeqCst)
    }

    pub fn alive(&self, replica: usize) -> bool {
        !self.dead[replica].load(Ordering::SeqCst)
    }

    /// The replica's incarnation generation (== its death count).
    pub fn generation(&self, replica: usize) -> u64 {
        self.generation[replica].load(Ordering::SeqCst)
    }

    /// Groups currently pinned to a replica (tests / introspection).
    pub fn pinned_groups(&self) -> usize {
        // panic-ok: pins critical sections are map/counter ops that cannot
        // panic while holding the lock
        self.pins.lock().expect("dispatch pins").len()
    }

    /// Pick the replica for one batch of `key` and account it in flight:
    /// the pinned replica while the group already has batches in flight,
    /// else the live replica with the fewest in-flight batches (ties
    /// break to the lowest index; if every replica is dead the choice
    /// falls back to all of them — the submit will fail either way).
    /// Returns the replica and its generation at assignment time; the
    /// completion must echo both to `complete`.
    pub fn assign(&self, key: (TaskId, PolicyId)) -> (usize, u64) {
        // panic-ok: pins critical sections are panic-free (see pinned_groups)
        let mut pins = self.pins.lock().expect("dispatch pins");
        let replica = match pins.get_mut(&key) {
            Some((replica, n)) => {
                *n += 1;
                *replica
            }
            None => {
                let replica = (0..self.inflight.len())
                    .filter(|r| self.alive(*r))
                    .min_by_key(|r| self.inflight[*r].load(Ordering::SeqCst))
                    .unwrap_or_else(|| {
                        (0..self.inflight.len())
                            .min_by_key(|r| self.inflight[*r].load(Ordering::SeqCst))
                            // panic-ok: pool construction rejects zero replicas
                            .expect("at least one replica")
                    });
                pins.insert(key, (replica, 1));
                replica
            }
        };
        // incremented under the pins lock so a concurrent completion
        // cannot interleave between replica choice and accounting
        self.inflight[replica].fetch_add(1, Ordering::SeqCst);
        (replica, self.generation[replica].load(Ordering::SeqCst))
    }

    /// Mark one batch of `key` complete on `replica`; the group unpins
    /// (and may migrate on its next batch) when its last in-flight batch
    /// completes.  A completion tagged with a stale generation — or whose
    /// group is no longer pinned to `replica` — belongs to a dead
    /// incarnation whose accounting `mark_dead` already purged, and is
    /// dropped without touching the live state.
    pub fn complete(&self, key: (TaskId, PolicyId), replica: usize, generation: u64) {
        if self.generation[replica].load(Ordering::SeqCst) != generation {
            return;
        }
        // panic-ok: pins critical sections are panic-free (see pinned_groups)
        let mut pins = self.pins.lock().expect("dispatch pins");
        match pins.get_mut(&key) {
            Some((r, n)) if *r == replica => {
                *n -= 1;
                if *n == 0 {
                    pins.remove(&key);
                }
                self.inflight[replica].fetch_sub(1, Ordering::SeqCst);
            }
            _ => {}
        }
    }

    /// Take `replica` out of service: exclude it from least-loaded
    /// choices, bump its generation (staling every outstanding
    /// completion), and purge its pins so affected groups migrate on
    /// their next batch.  The supervisor pairs this with a queue drain +
    /// sweep so none of those completions is lost — they all run with
    /// `ReplicaFailed` or are resubmitted elsewhere.
    pub fn mark_dead(&self, replica: usize) {
        self.dead[replica].store(true, Ordering::SeqCst);
        self.generation[replica].fetch_add(1, Ordering::SeqCst);
        // panic-ok: pins critical sections are panic-free (see pinned_groups)
        let mut pins = self.pins.lock().expect("dispatch pins");
        pins.retain(|_, (r, _)| *r != replica);
        // outstanding completions are now stale no-ops, so zero the
        // counter — introspection and the all-dead fallback must not see
        // phantom in-flight work
        self.inflight[replica].store(0, Ordering::SeqCst);
    }

    /// Re-admit a restarted replica to dispatch.  The generation keeps
    /// its post-death value, so completions from the previous incarnation
    /// stay stale; in-flight is already zero (`mark_dead` cleared it and
    /// nothing routed here while dead).
    pub fn revive(&self, replica: usize) {
        self.dead[replica].store(false, Ordering::SeqCst);
    }
}

// -------------------------------------------------------------------- pool

/// Supervision lifecycle events, delivered to the pool's event hook from
/// the supervisor thread (the coordinator forwards them to the recorder's
/// replica-health ledger).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolEvent {
    /// The replica was declared dead (thread death or heartbeat stall);
    /// `failed_batches` counts the device-committed batches swept with
    /// `ReplicaFailed` (drained-but-recoverable jobs are resubmitted and
    /// not counted here).
    ReplicaFailed { replica: usize, generation: u64, failed_batches: u64 },
    /// A respawned incarnation reported ready and rejoined dispatch.
    ReplicaRestarted { replica: usize, generation: u64 },
    /// The circuit breaker tripped: no further restarts for this replica.
    ReplicaExcluded { replica: usize },
    /// Periodic liveness sample for a live replica.
    Heartbeat { replica: usize, generation: u64, age_us: u64 },
    /// An executable cell became resident (pin, warm, or demand miss);
    /// `resident` is the replica's post-load resident cell count.
    CellLoaded { replica: usize, load_us: u64, pinned: bool, resident: usize },
    /// A cell was LRU-evicted (or dropped with its drained version).
    CellEvicted { replica: usize, resident: usize },
    /// A batch resolved its executable cell: `hit` = already resident;
    /// `wait_us` is what the batch waited on the residency table (~0 on
    /// a hit, the compile+upload latency on a miss).
    ResidencyLookup { replica: usize, hit: bool, wait_us: u64 },
}

/// Pool event subscriber (see `EnginePool::set_event_hook`).
pub type PoolEventHook = Arc<dyn Fn(PoolEvent) + Send + Sync>;

/// One manifest version's startup/reload inputs: the parsed manifest
/// (artifact paths), every route's (task, mode) checkpoints, and the
/// pin set as `(mode index, seq bucket, batch bucket)` cells.  Reload
/// (`EnginePool::push_version`) appends one of these to the shared
/// version list and broadcasts it to every replica queue.
pub struct VersionPayload {
    pub version: u32,
    pub manifest: Arc<Manifest>,
    pub preload: Arc<Vec<(String, String, Container)>>,
    pub pins: Arc<Vec<(u16, usize, usize)>>,
}

/// Everything needed to (re)spawn a replica incarnation — kept by the
/// pool so the supervisor can respawn with the exact startup inputs.
/// The version list is shared (append-only under its lock): a respawn
/// snapshots it so a restarted replica comes back knowing every version
/// pushed while it was down.
struct Spawner {
    versions: Arc<Mutex<Vec<Arc<VersionPayload>>>>,
    /// Per-slot residency tables — owned here (not by the incarnation)
    /// so they survive restarts and the supervisor can `clear` them on
    /// terminal exclusion.
    residencies: Vec<Arc<Residency>>,
    /// Shared with engine threads so they can emit residency events
    /// (`CellLoaded`/`CellEvicted`/`ResidencyLookup`).
    hook: Arc<RwLock<Option<PoolEventHook>>>,
    pool: Arc<ThreadPool>,
    staging: Arc<StagingPool>,
    options: EngineOptions,
}

impl Spawner {
    fn new(
        payload: Arc<VersionPayload>,
        replicas: usize,
        pool: Arc<ThreadPool>,
        staging: Arc<StagingPool>,
        options: EngineOptions,
    ) -> Spawner {
        let residencies = (0..replicas)
            .map(|_| {
                Arc::new(Residency::new(options.max_resident_cells, options.max_resident_bytes))
            })
            .collect();
        Spawner {
            versions: Arc::new(Mutex::new(vec![payload])),
            residencies,
            hook: Arc::new(RwLock::new(None)),
            pool,
            staging,
            options,
        }
    }

    fn spawn(&self, replica: usize, generation: u64, epoch: Instant) -> Result<PendingReplica> {
        let queue = JobQueue::new();
        let health = Arc::new(ReplicaHealth::default());
        let sweep = Arc::new(SweepTable::default());
        let (ready_tx, ready_rx) = channel::<Result<RouteTables>>();
        let ctx = EngineCtx {
            versions: Arc::clone(&self.versions),
            residency: Arc::clone(&self.residencies[replica]),
            hook: Arc::clone(&self.hook),
            queue: Arc::clone(&queue),
            ready_tx,
            pool: Arc::clone(&self.pool),
            staging: Arc::clone(&self.staging),
            options: self.options.clone(),
            replica,
            generation,
            health: Arc::clone(&health),
            sweep: Arc::clone(&sweep),
            epoch,
        };
        let join = crate::sync::thread::Builder::new()
            .name(format!("zqhero-engine-{replica}"))
            .spawn(move || engine_main(ctx))
            .context("spawning engine thread")?;
        Ok(PendingReplica { queue, join, health, sweep, ready_rx })
    }
}

/// One live replica incarnation's handles.
struct LiveReplica {
    queue: Arc<JobQueue>,
    join: JoinHandle<()>,
    health: Arc<ReplicaHealth>,
    sweep: Arc<SweepTable>,
}

/// Supervision state machine per replica slot (DESIGN.md §5.10):
/// `Live -> (death) -> Backoff -> Restarting -> Live`, or `-> Excluded`
/// once the restart budget is spent.
enum SlotState {
    Live(LiveReplica),
    Backoff { until: Instant },
    Restarting { live: LiveReplica, ready_rx: Receiver<Result<RouteTables>> },
    Excluded,
}

struct SlotInner {
    state: SlotState,
    /// Successful supervised restarts.
    restarts: u64,
    /// Consecutive failures since the last successful restart (backoff
    /// exponent).
    consecutive: u32,
    /// Failure timestamps inside the circuit-breaker window.
    failures: VecDeque<Instant>,
    /// Device-committed batches lost to this replica's deaths.
    failed_batches: u64,
}

struct ReplicaSlot {
    inner: Mutex<SlotInner>,
}

/// Shared pool state: the dispatcher, the per-replica slots, and the
/// spawner the supervisor respawns incarnations with.
struct PoolShared {
    state: DispatchState,
    slots: Vec<ReplicaSlot>,
    tables: RouteTables,
    spawner: Spawner,
    stop: AtomicBool,
    /// Pool birth — the zero point for heartbeat timestamps.
    epoch: Instant,
}

/// Fire the pool event hook (shared between the supervisor, which holds
/// `PoolShared`, and engine threads, which only hold the `Arc`'d hook).
fn emit_hook(hook: &RwLock<Option<PoolEventHook>>, ev: PoolEvent) {
    // panic-ok: hook panics run outside the read guard (worker pool
    // isolation); writers only swap the Option
    if let Some(h) = hook.read().expect("pool event hook").as_ref() {
        h(ev);
    }
}

impl PoolShared {
    fn emit(&self, ev: PoolEvent) {
        emit_hook(&self.spawner.hook, ev);
    }

    /// Release a terminally excluded slot's device-side footprint: clear
    /// its residency table (the next `Residency::counters` read shows
    /// zero resident cells) and shrink the staging pool's per-cell cap
    /// to match the surviving replica count.  The engine thread is
    /// already gone at this point, so its `Runtime` (executable tables,
    /// checkpoints, PJRT client) was dropped with the thread stack —
    /// this tears down what the *pool* still holds for the slot.
    fn teardown_slot(&self, replica: usize) {
        self.spawner.residencies[replica].clear();
        let live = self
            .slots
            .iter()
            // panic-ok: slot critical sections are panic-free (see submit_inner)
            .filter(|s| {
                !matches!(s.inner.lock().expect("replica slot").state, SlotState::Excluded)
            })
            .count();
        self.spawner.staging.trim(live, self.slots.len());
    }

    /// Route one batch through the load-aware dispatcher.  The completion
    /// is wrapped so the in-flight accounting decrements exactly when the
    /// batch's completion runs (generation-tagged, so it no-ops if the
    /// replica dies first).  A push failure marks that replica dead and
    /// the batch retries on the next live replica — one dead replica
    /// costs a re-route, not a batch of client errors.  `Err` means every
    /// replica is gone; the handed-back job's `done` must still be
    /// invoked exactly once (its drop-guard enforces that).
    fn submit_inner(self: &Arc<Self>, job: InferJob) -> std::result::Result<(), Box<InferJob>> {
        let key = (job.task, job.policy);
        let mut job = job;
        for _ in 0..self.state.replicas() {
            let (replica, generation) = self.state.assign(key);
            let shared = Arc::clone(self);
            let InferJob { task, policy, version, staging, cancel, done } = job;
            let wrapped = InferJob {
                task,
                policy,
                version,
                staging,
                cancel,
                done: Completion::new(move |res| {
                    // decrement before the inner completion so a panicking
                    // callback (isolated by the worker pool) cannot leak a
                    // pin or an in-flight count.  After a failed attempt
                    // or a replica death this is stale and dropped.
                    shared.state.complete(key, replica, generation);
                    done.run(res);
                }),
            };
            let push = {
                // panic-ok: slot critical sections only match on state and
                // move messages; replica death is handled by the
                // supervisor, not by lock poisoning
                let slot = self.slots[replica].inner.lock().expect("replica slot");
                match &slot.state {
                    SlotState::Live(l) => l.queue.push(Msg::Infer(Box::new(wrapped))),
                    // not serving: fail this attempt without touching the
                    // (possibly warming) incarnation's queue
                    _ => Err(Msg::Infer(Box::new(wrapped))),
                }
            };
            match push {
                Ok(()) => return Ok(()),
                Err(Msg::Infer(boxed)) => {
                    // the replica cannot take work: take it out of
                    // dispatch (the supervisor owns recovery) and retry
                    // the batch elsewhere.  The wrapped completion's
                    // accounting is already stale via the generation bump.
                    self.state.mark_dead(replica);
                    job = *boxed;
                }
                Err(_) => unreachable!("submit only sends Infer"),
            }
        }
        Err(Box::new(job))
    }

    /// Fail an orphaned job that could not be resubmitted anywhere:
    /// recycle its staging buffer and deliver `ReplicaFailed` on the
    /// worker pool.
    fn fail_job(&self, job: InferJob) {
        self.spawner.staging.put(job.staging);
        let done = job.done;
        self.spawner.pool.spawn(move || done.run(Err(anyhow::Error::new(ReplicaFailed))));
    }
}

/// N supervised engine replicas behind a load-aware dispatcher
/// (DESIGN.md §5.7, §5.10).  Startup fans the shared-read `preload` out
/// to all replica threads concurrently (each uploads to its own device
/// context and compiles its own executables — PJRT handles are not
/// `Send`); a supervisor thread then watches heartbeats, reconciles
/// failed replicas, and respawns them under backoff.  Shutdown stops the
/// supervisor, closes every queue (queued work drains), then joins the
/// replica threads in slot order.
pub struct EnginePool {
    shared: Arc<PoolShared>,
    supervisor: Option<JoinHandle<()>>,
}

impl EnginePool {
    /// Spawn `options.replicas` engine threads plus the supervisor.  All
    /// replicas start concurrently (checkpoint upload + pin-set compile
    /// overlap across threads) and share one read-only version payload;
    /// the call returns once every replica reports ready, or the first
    /// error.  Startup loads *only* `payload.pins` — everything else in
    /// the grid compiles on first demand (DESIGN.md §5.13).
    pub fn spawn(
        payload: Arc<VersionPayload>,
        pool: Arc<ThreadPool>,
        staging: Arc<StagingPool>,
        options: EngineOptions,
        hook: Option<PoolEventHook>,
    ) -> Result<EnginePool> {
        let n = options.replicas.max(1);
        let epoch = Instant::now();
        let spawner = Spawner::new(payload, n, pool, staging, options);
        if let Some(h) = hook {
            // installed before the first incarnation spawns so the
            // startup pin loads are ledgered too (the residency smoke
            // asserts startup loads == the pin set)
            // panic-ok: the write guard only swaps the Option (see emit_hook)
            *spawner.hook.write().expect("pool event hook") = Some(h);
        }
        let pending: Vec<PendingReplica> =
            (0..n).map(|i| spawner.spawn(i, 0, epoch)).collect::<Result<_>>()?;
        // wait in replica order; if one fails, close every other queue so
        // the already-started threads drain out and exit on their own
        let mut tables: Option<RouteTables> = None;
        let mut lives: Vec<LiveReplica> = Vec::with_capacity(n);
        let mut failure: Option<anyhow::Error> = None;
        let mut iter = pending.into_iter();
        for p in iter.by_ref() {
            match p.wait() {
                Ok((live, t)) => {
                    tables.get_or_insert(t);
                    lives.push(live);
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = failure {
            for l in &lives {
                l.queue.close();
            }
            for p in iter {
                p.queue.close();
            }
            return Err(e);
        }
        let mut slots = Vec::with_capacity(n);
        for live in lives {
            slots.push(ReplicaSlot {
                inner: Mutex::new(SlotInner {
                    state: SlotState::Live(live),
                    restarts: 0,
                    consecutive: 0,
                    failures: VecDeque::new(),
                    failed_batches: 0,
                }),
            });
        }
        let shared = Arc::new(PoolShared {
            state: DispatchState::new(n),
            slots,
            // panic-ok: the spawn loop above ran at least once (n is
            // clamped to >= 1 at entry) and filled `tables`
            tables: tables.expect("at least one replica"),
            spawner,
            stop: AtomicBool::new(false),
            epoch,
        });
        let sup = {
            let shared = Arc::clone(&shared);
            crate::sync::thread::Builder::new()
                .name("zqhero-supervisor".into())
                .spawn(move || supervisor_main(shared))
                .context("spawning supervisor thread")?
        };
        Ok(EnginePool { shared, supervisor: Some(sup) })
    }

    pub fn replicas(&self) -> usize {
        self.shared.slots.len()
    }

    /// Replicas currently live and accepting work.
    pub fn live_replicas(&self) -> usize {
        self.shared
            .slots
            .iter()
            // panic-ok: slot critical sections are panic-free (see submit_inner)
            .filter(|s| matches!(s.inner.lock().expect("replica slot").state, SlotState::Live(_)))
            .count()
    }

    /// Whether the circuit breaker has permanently excluded `replica`.
    pub fn replica_excluded(&self, replica: usize) -> bool {
        matches!(
            // panic-ok: slot critical sections are panic-free (see submit_inner)
            self.shared.slots[replica].inner.lock().expect("replica slot").state,
            SlotState::Excluded
        )
    }

    /// Successful supervised restarts of `replica`.
    pub fn replica_restarts(&self, replica: usize) -> u64 {
        // panic-ok: slot critical sections are panic-free (see submit_inner)
        self.shared.slots[replica].inner.lock().expect("replica slot").restarts
    }

    /// The pool's dispatch accounting (tests / introspection).
    pub fn dispatch_state(&self) -> &DispatchState {
        &self.shared.state
    }

    /// Subscribe to supervision events (replica failure/restart/
    /// exclusion, heartbeats).  One subscriber; installing replaces the
    /// previous hook.  Called from the supervisor thread — keep it quick
    /// and never call back into the pool.
    pub fn set_event_hook(&self, hook: PoolEventHook) {
        // panic-ok: the write guard only swaps the Option (see emit_hook)
        *self.shared.spawner.hook.write().expect("pool event hook") = Some(hook);
    }

    /// Install a new manifest version on every replica (hot reload).
    /// The payload is appended to the shared version list (so replicas
    /// restarting later pick it up at startup) and a `Reload` message is
    /// broadcast to every live *and* restarting incarnation's queue.
    /// Idempotent per version number; the caller swaps the admission
    /// version only after this returns, so new requests never race ahead
    /// of the install broadcast (a queued `Reload` is processed before
    /// any job enqueued after it).
    pub fn push_version(&self, payload: Arc<VersionPayload>) {
        {
            // panic-ok: the version list critical section only pushes
            let mut versions = self.shared.spawner.versions.lock().expect("version list");
            if versions.iter().any(|p| p.version == payload.version) {
                return;
            }
            versions.push(Arc::clone(&payload));
        }
        for slot in &self.shared.slots {
            // panic-ok: slot critical sections are panic-free (see submit_inner)
            let slot = slot.inner.lock().expect("replica slot");
            let queue = match &slot.state {
                SlotState::Live(l) => &l.queue,
                SlotState::Restarting { live, .. } => &live.queue,
                _ => continue,
            };
            // a closed queue means the incarnation is dying; the shared
            // version list covers its successor
            let _ = queue.push(Msg::Reload(Arc::clone(&payload)));
        }
    }

    /// Whether *any* replica has an executable resident for
    /// `(version, mode, seq_bucket)` at any batch bucket.  Used by the
    /// admission path to decide if a governed downshift would stall on a
    /// cold compile (DESIGN.md §5.13).
    pub fn any_resident(&self, version: u32, mode: ModeId, seq_bucket: usize) -> bool {
        self.shared
            .spawner
            .residencies
            .iter()
            .any(|r| r.any_resident(version, mode.0, seq_bucket))
    }

    /// Ask every live replica to load `(version, mode, seq, bucket)` in
    /// the background (between batches).  Fire-and-forget: replicas that
    /// are down simply skip the warm; a later demand miss still works.
    pub fn warm(&self, version: u32, mode: ModeId, seq: usize, bucket: usize) {
        let key = CellKey { version, mode: mode.0, seq, bucket };
        for slot in &self.shared.slots {
            // panic-ok: slot critical sections are panic-free (see submit_inner)
            let slot = slot.inner.lock().expect("replica slot");
            if let SlotState::Live(l) = &slot.state {
                let _ = l.queue.push(Msg::Warm(key));
            }
        }
    }

    /// Route one batch through the load-aware dispatcher (see
    /// `PoolShared::submit_inner`).
    pub fn submit(&self, job: InferJob) -> std::result::Result<(), Box<InferJob>> {
        self.shared.submit_inner(job)
    }

    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        self.shared.tables.task_id(name)
    }

    pub fn mode_id(&self, name: &str) -> Result<ModeId> {
        self.shared.tables.mode_id(name)
    }

    pub fn policy_id(&self, name: &str) -> Result<PolicyId> {
        self.shared.tables.policy_id(name)
    }

    /// The mirrored policy-name table (identical across replicas: every
    /// replica derives it from the same `manifest.json`).
    pub fn policy_names(&self) -> &[String] {
        &self.shared.tables.policies
    }

    pub fn policy_exec_mode(&self, policy: PolicyId) -> Result<ModeId> {
        self.shared.tables.policy_exec_mode(policy)
    }

    // NB: no pool-level `infer_blocking` — blocking convenience calls go
    // through a single `Engine` (see `Engine::infer_blocking`); serving
    // traffic reaches the pool only via `Coordinator::dispatch`.
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.supervisor.take() {
            let _ = j.join();
        }
        // close every queue first so replicas drain concurrently, then
        // join the threads in slot order (deterministic shutdown).
        // Threads abandoned by the supervisor (hung incarnations) are not
        // here — they exit on their own when they observe poisoning.
        let mut joins = Vec::new();
        for slot in &self.shared.slots {
            // panic-ok: slot critical sections are panic-free (see submit_inner)
            let mut inner = slot.inner.lock().expect("replica slot");
            match std::mem::replace(&mut inner.state, SlotState::Excluded) {
                SlotState::Live(l) => {
                    l.queue.close();
                    joins.push(l.join);
                }
                SlotState::Restarting { live, .. } => {
                    live.queue.close();
                    joins.push(live.join);
                }
                _ => {}
            }
        }
        for j in joins {
            let _ = j.join();
        }
    }
}

// -------------------------------------------------------------- supervisor

fn supervisor_main(shared: Arc<PoolShared>) {
    let options = &shared.spawner.options;
    // poll fast enough to resolve the watchdog budget, slow enough to
    // stay invisible in profiles
    let tick = match options.watchdog {
        Some(w) => (w / 4).clamp(Duration::from_millis(1), Duration::from_millis(50)),
        None => Duration::from_millis(10),
    };
    let n = shared.slots.len();
    // (progress value, when it last changed) per replica
    let mut last: Vec<(u64, Instant)> = (0..n).map(|_| (0, Instant::now())).collect();
    while !shared.stop.load(Ordering::SeqCst) {
        for r in 0..n {
            poll_replica(&shared, r, &mut last[r]);
        }
        crate::sync::thread::sleep(tick);
    }
}

/// One supervision step for one replica slot.  Slot-state mutation runs
/// under the slot lock; orphan resubmission and event delivery are
/// deferred until the lock is released (`submit_inner` takes slot locks,
/// and the hook may take foreign ones).
fn poll_replica(shared: &Arc<PoolShared>, r: usize, last: &mut (u64, Instant)) {
    let now = Instant::now();
    let watchdog = shared.spawner.options.watchdog;
    let policy = &shared.spawner.options.restart;
    let mut events: Vec<PoolEvent> = Vec::new();
    let mut orphans: Vec<Box<InferJob>> = Vec::new();
    {
        // panic-ok: slot critical sections are panic-free (see submit_inner)
        let mut inner = shared.slots[r].inner.lock().expect("replica slot");
        let state = std::mem::replace(&mut inner.state, SlotState::Excluded);
        inner.state = match state {
            SlotState::Live(live) => {
                let progress = live.health.progress();
                if progress != last.0 {
                    *last = (progress, now);
                }
                let stalled = watchdog.is_some_and(|w| {
                    shared.state.inflight(r) > 0 && now.duration_since(last.1) > w
                });
                if live.join.is_finished() || stalled {
                    fail_replica(shared, r, live, &mut inner, now, &mut events, &mut orphans)
                } else {
                    events.push(PoolEvent::Heartbeat {
                        replica: r,
                        generation: shared.state.generation(r),
                        age_us: live.health.beat_age_us(&shared.epoch),
                    });
                    SlotState::Live(live)
                }
            }
            SlotState::Backoff { until } if now >= until => {
                match shared.spawner.spawn(r, shared.state.generation(r), shared.epoch) {
                    Ok(p) => SlotState::Restarting {
                        live: LiveReplica {
                            queue: p.queue,
                            join: p.join,
                            health: p.health,
                            sweep: p.sweep,
                        },
                        ready_rx: p.ready_rx,
                    },
                    Err(_) => breaker_step(r, &mut inner, policy, now, &mut events),
                }
            }
            SlotState::Restarting { live, ready_rx } => match ready_rx.try_recv() {
                Ok(Ok(_tables)) => {
                    inner.restarts += 1;
                    inner.consecutive = 0;
                    shared.state.revive(r);
                    *last = (live.health.progress(), now);
                    events.push(PoolEvent::ReplicaRestarted {
                        replica: r,
                        generation: shared.state.generation(r),
                    });
                    SlotState::Live(live)
                }
                // still warming (checkpoint upload / pin compile) — keep
                // watching the other replicas rather than blocking on this one
                Err(TryRecvError::Empty) => SlotState::Restarting { live, ready_rx },
                // A typed preload error names one corrupt artifact cell:
                // restarting cannot fix the artifact, so exclude immediately
                // instead of burning the restart budget on it.
                Ok(Err(e)) if e.downcast_ref::<PreloadError>().is_some() => {
                    events.push(PoolEvent::ReplicaExcluded { replica: r });
                    SlotState::Excluded
                }
                Ok(Err(_)) | Err(TryRecvError::Disconnected) => {
                    breaker_step(r, &mut inner, policy, now, &mut events)
                }
            },
            other => other,
        };
    }
    // a terminal exclusion releases the slot's residual footprint
    // (residency table, staging shelf share) outside the slot lock
    if events.iter().any(|e| matches!(e, PoolEvent::ReplicaExcluded { .. })) {
        shared.teardown_slot(r);
    }
    // recoverable (never-uploaded) orphans ride a live replica; if none
    // is left their drop-guarded completions still deliver ReplicaFailed
    for job in orphans {
        if let Err(job) = shared.submit_inner(*job) {
            shared.fail_job(*job);
        }
    }
    for ev in events {
        shared.emit(ev);
    }
}

/// Declare a live incarnation dead: poison + drain its queue, stale its
/// dispatch accounting, sweep its device-committed completions (each
/// runs exactly once with `ReplicaFailed`), and move the slot into
/// backoff — or trip the circuit breaker.  Runs under the slot lock;
/// drained jobs are handed back to the caller for resubmission after the
/// lock drops.
fn fail_replica(
    shared: &Arc<PoolShared>,
    r: usize,
    live: LiveReplica,
    inner: &mut SlotInner,
    now: Instant,
    events: &mut Vec<PoolEvent>,
    orphans: &mut Vec<Box<InferJob>>,
) -> SlotState {
    // order matters: close the queue first (new pushes fail -> reroute),
    // then bump the generation (outstanding completions go stale), then
    // sweep (anything device-committed fails exactly once)
    let drained = live.queue.close_and_drain();
    shared.state.mark_dead(r);
    let generation = shared.state.generation(r);
    let swept = live.sweep.sweep();
    inner.failed_batches += swept.len() as u64;
    events.push(PoolEvent::ReplicaFailed {
        replica: r,
        generation,
        failed_batches: swept.len() as u64,
    });
    for done in swept {
        shared.spawner.pool.spawn(move || done.run(Err(anyhow::Error::new(ReplicaFailed))));
    }
    for msg in drained {
        if let Msg::Infer(job) = msg {
            orphans.push(job);
        }
    }
    if live.join.is_finished() {
        let _ = live.join.join();
    }
    // else: the thread is hung inside a device call — abandon the handle;
    // the poisoned queue makes it abandon work and exit when it wakes,
    // and generation tags + the swept table neutralize its late effects
    breaker_step(r, inner, &shared.spawner.options.restart, now, events)
}

/// Record one failure against the restart budget: exclude the replica
/// when `budget` failures land inside `window`, otherwise schedule a
/// respawn after the exponential backoff.
fn breaker_step(
    r: usize,
    inner: &mut SlotInner,
    policy: &RestartPolicy,
    now: Instant,
    events: &mut Vec<PoolEvent>,
) -> SlotState {
    inner.failures.push_back(now);
    while inner.failures.front().is_some_and(|t| now.duration_since(*t) > policy.window) {
        inner.failures.pop_front();
    }
    if inner.failures.len() >= policy.budget.max(1) {
        events.push(PoolEvent::ReplicaExcluded { replica: r });
        SlotState::Excluded
    } else {
        let exp = inner.consecutive.min(16);
        inner.consecutive += 1;
        let delay = policy.backoff.saturating_mul(1u32 << exp).min(policy.max_backoff);
        SlotState::Backoff { until: now + delay }
    }
}

// ------------------------------------------------------------- engine loop

/// One launched-but-not-read-back batch (the pipeline register).  The
/// completion itself is parked in the sweep table; `done_id` redeems it
/// at retire (or the supervisor sweeps it on death — whoever takes the
/// slot first wins).
struct InFlight {
    pending: EnginePending,
    done_id: u64,
    /// job receipt (before upload) — the `engine_us` clock.
    t_job: Instant,
    /// post-upload launch point — the `exec_us` clock.
    t0: Instant,
    upload_us: u64,
    exec_seq: u64,
    /// Residency resolution wait (0 on a hit) — clocked *before* `t_job`
    /// so `engine_us`/`upload_us` stay comparable across hits and misses.
    load_wait_us: u64,
}

/// Stage 3: synchronize, copy logits to host, and hand de-batching +
/// reply dispatch to the worker pool.  A swept batch (the supervisor
/// already failed it) is skipped entirely.
fn retire(dev: &EngineDevice, f: InFlight, pool: &ThreadPool, replica: usize, sweep: &SweepTable) {
    let Some(done) = sweep.take(f.done_id) else { return };
    let res = dev.readback(f.pending).map(|logits| InferDone {
        logits,
        exec_us: f.t0.elapsed().as_micros() as u64,
        upload_us: f.upload_us,
        engine_us: f.t_job.elapsed().as_micros() as u64,
        replica,
        exec_seq: f.exec_seq,
        load_wait_us: f.load_wait_us,
    });
    pool.spawn(move || done.run(res));
}

/// Startup + loop inputs for one replica incarnation.
struct EngineCtx {
    versions: Arc<Mutex<Vec<Arc<VersionPayload>>>>,
    residency: Arc<Residency>,
    hook: Arc<RwLock<Option<PoolEventHook>>>,
    queue: Arc<JobQueue>,
    ready_tx: Sender<Result<RouteTables>>,
    pool: Arc<ThreadPool>,
    staging: Arc<StagingPool>,
    options: EngineOptions,
    replica: usize,
    generation: u64,
    health: Arc<ReplicaHealth>,
    sweep: Arc<SweepTable>,
    epoch: Instant,
}

/// Background-load one cell between batches (`Msg::Warm`, or a reload's
/// new pin set warming in).  Never blocks a job: a resident cell is a
/// no-op, a concurrent load elsewhere is left to finish on its own, and
/// a failed load just clears the marker (the next demand miss retries).
fn warm_cell(
    dev: &mut EngineDevice,
    residency: &Residency,
    known: &BTreeMap<u32, Arc<VersionPayload>>,
    pin_set: &HashSet<CellKey>,
    key: CellKey,
    hook: &RwLock<Option<PoolEventHook>>,
    replica: usize,
) {
    if residency.is_resident(key) {
        return;
    }
    let Some(payload) = known.get(&key.version) else { return };
    match residency.begin(key) {
        Begin::Hit => {}
        Begin::Load => {
            let t0 = Instant::now();
            match dev.load_cell(&payload.manifest, key) {
                Ok(bytes) => {
                    let pinned = pin_set.contains(&key);
                    let evicted = residency.complete(key, bytes, pinned);
                    dev.evict_cells(&evicted);
                    let resident = residency.counters().resident;
                    emit_hook(
                        hook,
                        PoolEvent::CellLoaded {
                            replica,
                            load_us: t0.elapsed().as_micros() as u64,
                            pinned,
                            resident,
                        },
                    );
                    for _ in &evicted {
                        emit_hook(hook, PoolEvent::CellEvicted { replica, resident });
                    }
                }
                Err(_) => residency.fail(key),
            }
        }
    }
}

/// Install a reload payload on this incarnation: upload its checkpoints,
/// swap the pin set (old pins unpin and age out via LRU; new pins warm
/// in between batches), and drain every version older than
/// `payload.version - 1` — one predecessor stays resident so in-flight
/// and still-queued jobs stamped with it finish cleanly.
#[allow(clippy::too_many_arguments)]
fn apply_reload(
    dev: &mut EngineDevice,
    residency: &Residency,
    known: &mut BTreeMap<u32, Arc<VersionPayload>>,
    pin_set: &mut HashSet<CellKey>,
    pending_warm: &mut VecDeque<CellKey>,
    payload: Arc<VersionPayload>,
    hook: &RwLock<Option<PoolEventHook>>,
    replica: usize,
) {
    // idempotent: push_version broadcasts to live + restarting queues and
    // a restart also snapshots the shared list, so duplicates are normal
    if known.contains_key(&payload.version) {
        return;
    }
    if dev.upload_version_checkpoints(&payload).is_err() {
        // version stays uninstalled on this replica; jobs stamped with it
        // fail with a typed "not resident" error rather than killing the
        // incarnation (the coordinator only swaps admission after
        // push_version, so this window is a degraded replica, not a
        // client-visible outage)
        return;
    }
    let new_pins: Vec<CellKey> = payload
        .pins
        .iter()
        .map(|&(mode, seq, bucket)| CellKey { version: payload.version, mode, seq, bucket })
        .collect();
    let evicted = residency.repin(&new_pins);
    dev.evict_cells(&evicted);
    let resident = residency.counters().resident;
    for _ in &evicted {
        emit_hook(hook, PoolEvent::CellEvicted { replica, resident });
    }
    *pin_set = new_pins.iter().copied().collect();
    for key in new_pins {
        if !residency.is_resident(key) && !pending_warm.contains(&key) {
            pending_warm.push_back(key);
        }
    }
    known.insert(payload.version, payload);
    // drain everything older than the immediate predecessor
    let newest = *known.keys().next_back().unwrap_or(&0);
    let keep_min = newest.saturating_sub(1);
    let dropped = residency.drop_versions_below(keep_min);
    if !dropped.is_empty() {
        dev.evict_cells(&dropped);
        let resident = residency.counters().resident;
        for _ in &dropped {
            emit_hook(hook, PoolEvent::CellEvicted { replica, resident });
        }
    }
    dev.drop_version_ckpts(keep_min);
    known.retain(|v, _| *v >= keep_min);
    pending_warm.retain(|k| k.version >= keep_min);
}

fn engine_main(ctx: EngineCtx) {
    let EngineCtx {
        versions,
        residency,
        hook,
        queue,
        ready_tx,
        pool,
        staging,
        options,
        replica,
        generation,
        health,
        sweep,
        epoch,
    } = ctx;
    let faults = options.fault_plan.for_replica(replica, generation);
    // snapshot the shared version list: every version pushed so far must
    // be installed before this incarnation reports ready (a restarted
    // replica joins at the pool's current version, not its birth version)
    let snapshot: Vec<Arc<VersionPayload>> = {
        // panic-ok: the version list critical section only clones Arcs
        versions.lock().expect("version list").clone()
    };
    let Some(latest) = snapshot.last().cloned() else {
        let _ = ready_tx.send(Err(anyhow!("replica {replica}: empty version list")));
        return;
    };
    let mut dev = match EngineDevice::open(&latest.manifest, options.fake) {
        Ok(d) => d,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    // a fresh incarnation starts from an empty residency table (the
    // previous incarnation's device state died with its thread)
    residency.reset();
    // installed versions on this incarnation (checkpoints uploaded)
    let mut known: BTreeMap<u32, Arc<VersionPayload>> = BTreeMap::new();
    for payload in &snapshot {
        if let Err(e) = dev.upload_version_checkpoints(payload) {
            let _ = ready_tx.send(Err(e));
            return;
        }
        known.insert(payload.version, Arc::clone(payload));
    }
    if faults.fail_preload {
        let _ = ready_tx.send(Err(anyhow::Error::new(PreloadError::Executable {
            mode: "fault-injected".into(),
            seq: 0,
            bucket: 0,
            cause: "fault injection: FailPreload".into(),
        })));
        return;
    }
    // startup loads exactly the newest version's pin set — nothing else
    // (the ISSUE's ledger assertion: startup loads == pinned cells)
    let mut pin_set: HashSet<CellKey> = HashSet::new();
    for &(mode, seq, bucket) in latest.pins.iter() {
        let key = CellKey { version: latest.version, mode, seq, bucket };
        pin_set.insert(key);
        match residency.begin(key) {
            Begin::Hit => {}
            Begin::Load => {
                let t0 = Instant::now();
                match dev.load_cell(&latest.manifest, key) {
                    Ok(bytes) => {
                        let evicted = residency.complete(key, bytes, true);
                        dev.evict_cells(&evicted);
                        emit_hook(
                            &hook,
                            PoolEvent::CellLoaded {
                                replica,
                                load_us: t0.elapsed().as_micros() as u64,
                                pinned: true,
                                resident: residency.counters().resident,
                            },
                        );
                    }
                    Err(e) => {
                        residency.fail(key);
                        let _ = ready_tx.send(Err(anyhow::Error::new(PreloadError::Executable {
                            mode: latest.manifest.mode_name(ModeId(key.mode)).to_string(),
                            seq: key.seq,
                            bucket: key.bucket,
                            cause: format!("{e:#}"),
                        })));
                        return;
                    }
                }
            }
        }
    }
    let tables = RouteTables::from_manifest(dev.manifest());
    // keep the engine thread's own copy of executable selection
    let policy_exec = tables.policy_exec.clone();
    if ready_tx.send(Ok(tables)).is_err() {
        return;
    }
    // warm requests deferred to idle gaps between batches
    let mut pending_warm: VecDeque<CellKey> = VecDeque::new();

    let mut inflight: Option<InFlight> = None;
    // per-replica batch serial, stamped in execution order (the
    // cross-replica FIFO witness carried on InferDone::exec_seq)
    let mut next_exec_seq: u64 = 0;
    // de-queued Infer jobs — the index the fault script fires on
    let mut batches: u64 = 0;
    loop {
        // With a batch executing, prefer new work (to keep the device fed)
        // but retire the head batch as soon as the queue runs dry.  Warm
        // loads run strictly in idle gaps: only once the queue is empty
        // and nothing is in flight, one warm per iteration so a fresh
        // job never waits behind a warm backlog.
        let msg = if inflight.is_some() {
            match queue.try_pop() {
                TryPop::Msg(m) => Some(m),
                TryPop::Empty => {
                    if let Some(f) = inflight.take() {
                        retire(&dev, f, &pool, replica, &sweep);
                        health.beat(&epoch);
                    }
                    if pending_warm.is_empty() {
                        queue.pop()
                    } else {
                        continue;
                    }
                }
                TryPop::Closed => None,
            }
        } else if !pending_warm.is_empty() {
            match queue.try_pop() {
                TryPop::Msg(m) => Some(m),
                TryPop::Empty => {
                    if let Some(key) = pending_warm.pop_front() {
                        warm_cell(&mut dev, &residency, &known, &pin_set, key, &hook, replica);
                        health.beat(&epoch);
                    }
                    continue;
                }
                TryPop::Closed => None,
            }
        } else {
            queue.pop()
        };
        let job = match msg {
            Some(Msg::Infer(job)) => *job,
            Some(Msg::Reload(payload)) => {
                apply_reload(
                    &mut dev,
                    &residency,
                    &mut known,
                    &mut pin_set,
                    &mut pending_warm,
                    payload,
                    &hook,
                    replica,
                );
                health.beat(&epoch);
                continue;
            }
            Some(Msg::Warm(key)) => {
                if !pending_warm.contains(&key) {
                    pending_warm.push_back(key);
                }
                continue;
            }
            Some(Msg::Stop) | None => break,
        };
        // heartbeat 1: job de-queued
        health.beat(&epoch);
        let batch_no = batches;
        batches += 1;
        let InferJob { task, policy, version, staging: host, cancel, done } = job;
        // scripted faults fire while `done` is live on this stack frame,
        // so a panic's unwind runs its drop-guard (ReplicaFailed out)
        if let Some((at, dur)) = faults.stall {
            if batch_no == at {
                crate::sync::thread::sleep(dur);
            }
        }
        if faults.panic_at == Some(batch_no) {
            panic!("fault injection: replica {replica} panics at batch {batch_no}");
        }
        if let Some(d) = faults.throttle {
            crate::sync::thread::sleep(d);
        }
        // A poisoned queue means the supervisor declared this incarnation
        // dead (e.g. it stalled past the watchdog) and already reconciled
        // its work: abandon the job (the drop-guard delivers
        // ReplicaFailed) instead of racing the successor with late output.
        if queue.is_poisoned() {
            staging.put(host);
            drop(done);
            break;
        }
        // Cancel-before-submit hook: the one cancellation point past
        // batch formation, strictly before any device work.  Cancelled
        // jobs consume no exec_seq — the per-replica serial witnesses
        // *executed* batches only.
        if matches!(&cancel, Some(c) if c()) {
            staging.put(host);
            pool.spawn(move || done.run(Err(anyhow::Error::new(CancelledBeforeSubmit))));
            continue;
        }
        let exec_seq = next_exec_seq;
        next_exec_seq += 1;
        // Executable selection: policy -> mode through the mirrored table.
        let mode = match policy_exec.get(policy.index()) {
            Some(m) => *m,
            None => {
                staging.put(host);
                pool.spawn(move || done.run(Err(anyhow!("PolicyId {} out of range", policy.0))));
                continue;
            }
        };
        // Residency resolution runs on its own clock, *before* t_job:
        // a demand-miss compile must show up as load_wait_us, never as
        // upload_us/engine_us (hit and miss batches stay comparable).
        let cell = CellKey { version, mode: mode.0, seq: host.seq, bucket: host.bucket };
        let t_res = Instant::now();
        match residency.begin(cell) {
            Begin::Hit => {
                emit_hook(
                    &hook,
                    PoolEvent::ResidencyLookup {
                        replica,
                        hit: true,
                        wait_us: t_res.elapsed().as_micros() as u64,
                    },
                );
            }
            Begin::Load => {
                let load = match known.get(&version) {
                    Some(p) => dev.load_cell(&p.manifest, cell),
                    None => Err(anyhow!(
                        "manifest version {version} is not installed on replica {replica} \
                         (reload drained it or its checkpoint upload failed)"
                    )),
                };
                match load {
                    Ok(bytes) => {
                        let pinned = pin_set.contains(&cell);
                        let evicted = residency.complete(cell, bytes, pinned);
                        dev.evict_cells(&evicted);
                        let resident = residency.counters().resident;
                        let wait_us = t_res.elapsed().as_micros() as u64;
                        emit_hook(
                            &hook,
                            PoolEvent::CellLoaded { replica, load_us: wait_us, pinned, resident },
                        );
                        for _ in &evicted {
                            emit_hook(&hook, PoolEvent::CellEvicted { replica, resident });
                        }
                        emit_hook(
                            &hook,
                            PoolEvent::ResidencyLookup { replica, hit: false, wait_us },
                        );
                    }
                    Err(e) => {
                        residency.fail(cell);
                        emit_hook(
                            &hook,
                            PoolEvent::ResidencyLookup {
                                replica,
                                hit: false,
                                wait_us: t_res.elapsed().as_micros() as u64,
                            },
                        );
                        staging.put(host);
                        pool.spawn(move || done.run(Err(e)));
                        continue;
                    }
                }
            }
        }
        let load_wait_us = t_res.elapsed().as_micros() as u64;
        let t_job = Instant::now();
        if let Some(d) = faults.slow_upload {
            crate::sync::thread::sleep(d);
        }
        // Stage 1: upload this batch's inputs (overlaps the previous
        // batch's device execution), then recycle the host buffers.  The
        // staging buffer carries its seq bucket, so a short batch uploads
        // `bucket * seq_bucket` tokens, not `bucket * max_seq`.
        let uploaded = dev.upload(&host);
        let upload_us = t_job.elapsed().as_micros() as u64;
        staging.put(host);
        // The batch is now device-committed: park the completion in the
        // sweep table so a dead incarnation's in-flight work can be
        // reconciled from outside (take-vs-sweep runs it exactly once).
        let done_id = sweep.register(done);
        // heartbeat 2: upload finished
        health.beat(&epoch);
        let inputs = match uploaded {
            Ok(i) => i,
            Err(e) => {
                if let Some(f) = inflight.take() {
                    retire(&dev, f, &pool, replica, &sweep);
                }
                if let Some(done) = sweep.take(done_id) {
                    pool.spawn(move || done.run(Err(e)));
                }
                continue;
            }
        };
        // Stage 2: launch this batch.  The exec clock starts only after
        // the upload returned: InferDone::exec_us must not double-count
        // upload_us (it used to, inflating per-batch exec reporting).
        let t0 = Instant::now();
        let launched = dev.execute(version, task, mode, &inputs);
        // Stage 3 for the previous batch: its readback now overlaps this
        // batch's execution.
        if let Some(f) = inflight.take() {
            retire(&dev, f, &pool, replica, &sweep);
        }
        match launched {
            Ok(pending) => {
                let f = InFlight { pending, done_id, t_job, t0, upload_us, exec_seq, load_wait_us };
                if options.overlap {
                    inflight = Some(f);
                } else {
                    retire(&dev, f, &pool, replica, &sweep);
                }
            }
            Err(e) => {
                if let Some(done) = sweep.take(done_id) {
                    pool.spawn(move || done.run(Err(e)));
                }
            }
        }
        // heartbeat 3: batch launched/retired
        health.beat(&epoch);
        // fail-submit fault: close our own queue so later pushes fail and
        // the pool reroutes; already-queued work still drains above
        if faults.fail_submit_after == Some(batch_no) {
            queue.close();
        }
    }
    if let Some(f) = inflight.take() {
        retire(&dev, f, &pool, replica, &sweep);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Rng};

    fn key(task: u16, policy: u16) -> (TaskId, PolicyId) {
        (TaskId(task), PolicyId(policy))
    }

    #[test]
    fn completion_drop_guard_fires_replica_failed_exactly_once() {
        let (tx, rx) = channel::<Result<InferDone>>();
        let done = Completion::new(move |res| {
            let _ = tx.send(res);
        });
        drop(done);
        let res = rx.recv().expect("guard delivered a result");
        let err = res.expect_err("drop-guard must deliver an error");
        assert!(err.downcast_ref::<ReplicaFailed>().is_some(), "not ReplicaFailed: {err:#}");
        assert!(rx.try_recv().is_err(), "guard fired more than once");
    }

    #[test]
    fn completion_run_consumes_and_disarms_the_guard() {
        let (tx, rx) = channel::<Result<InferDone>>();
        let done = Completion::new(move |res| {
            let _ = tx.send(res);
        });
        done.run(Err(anyhow!("explicit")));
        let res = rx.recv().expect("run delivered");
        assert!(res.is_err());
        // run() consumed the closure: the subsequent drop is a no-op
        assert!(rx.try_recv().is_err(), "guard re-fired after run");
    }

    #[test]
    fn job_queue_close_semantics() {
        let q = JobQueue::new();
        q.push(Msg::Stop).map_err(|_| ()).expect("open queue accepts");
        // graceful close: pushes fail, queued work still drains
        q.close();
        assert!(q.push(Msg::Stop).is_err(), "closed queue must reject");
        assert!(!q.is_poisoned(), "graceful close is not poison");
        assert!(matches!(q.try_pop(), TryPop::Msg(Msg::Stop)));
        assert!(matches!(q.try_pop(), TryPop::Closed));
        assert!(q.pop().is_none());
    }

    #[test]
    fn job_queue_drain_reclaims_and_poisons() {
        let q = JobQueue::new();
        q.push(Msg::Stop).map_err(|_| ()).unwrap();
        q.push(Msg::Stop).map_err(|_| ()).unwrap();
        let drained = q.close_and_drain();
        assert_eq!(drained.len(), 2);
        assert!(q.is_poisoned());
        assert!(q.push(Msg::Stop).is_err());
        assert!(q.pop().is_none());
    }

    #[test]
    fn sweep_table_take_and_sweep_are_exactly_once() {
        let t = SweepTable::default();
        let (tx, rx) = channel::<Result<InferDone>>();
        let tx2 = tx.clone();
        let a = t.register(Completion::new(move |r| {
            let _ = tx.send(r);
        }));
        let b = t.register(Completion::new(move |r| {
            let _ = tx2.send(r);
        }));
        // retire wins slot a
        t.take(a).expect("registered").run(Err(anyhow!("retired")));
        assert!(rx.recv().unwrap().is_err());
        // the sweep gets only slot b, and a second take of a is None
        let swept = t.sweep();
        assert_eq!(swept.len(), 1);
        assert!(t.take(a).is_none());
        assert!(t.take(b).is_none());
        for done in swept {
            done.run(Err(anyhow::Error::new(ReplicaFailed)));
        }
        assert!(rx.recv().unwrap().is_err());
        assert!(rx.try_recv().is_err(), "a completion ran twice");
    }

    #[test]
    fn fault_plan_scopes_by_replica_and_generation() {
        let plan = FaultPlan::default()
            .with(FaultSpec::on(1, FaultKind::PanicAt { batch: 3 }))
            .with(FaultSpec::all(FaultKind::Throttle { per_batch: Duration::from_millis(5) })
                .persistent())
            .with(FaultSpec::on(2, FaultKind::StallFor {
                batch: 0,
                dur: Duration::from_millis(9),
            }));
        // replica scoping
        assert_eq!(plan.for_replica(1, 0).panic_at, Some(3));
        assert_eq!(plan.for_replica(0, 0).panic_at, None);
        assert_eq!(plan.for_replica(2, 0).stall, Some((0, Duration::from_millis(9))));
        // generation scoping: non-persistent faults die with generation 0
        assert_eq!(plan.for_replica(1, 1).panic_at, None);
        assert_eq!(plan.for_replica(2, 2).stall, None);
        // persistent faults survive restart
        assert_eq!(plan.for_replica(1, 4).throttle, Some(Duration::from_millis(5)));
        // coordinator-side kind is invisible to the engine
        let cp = FaultPlan::completion_panic_at(7);
        assert_eq!(cp.completion_panic(), Some(7));
        assert_eq!(cp.for_replica(0, 0).panic_at, None);
        assert!(FaultPlan::default().is_empty());
        // from_gen arms a fault only from that generation onward — the
        // chaos suite uses it to corrupt a replica's *restart* preload
        // while its first incarnation boots cleanly
        let fp = FaultPlan::default()
            .with(FaultSpec::on(0, FaultKind::FailPreload).from_gen(1).persistent());
        assert!(!fp.for_replica(0, 0).fail_preload);
        assert!(fp.for_replica(0, 1).fail_preload);
        assert!(fp.for_replica(0, 2).fail_preload);
        assert!(!fp.for_replica(1, 1).fail_preload);
    }

    #[test]
    fn breaker_trips_after_budget_failures_in_window() {
        let policy = RestartPolicy {
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(40),
            budget: 3,
            window: Duration::from_secs(60),
        };
        let mut inner = SlotInner {
            state: SlotState::Excluded,
            restarts: 0,
            consecutive: 0,
            failures: VecDeque::new(),
            failed_batches: 0,
        };
        let now = Instant::now();
        let mut events = Vec::new();
        // failures 1 and 2: exponential backoff, capped at max_backoff
        match breaker_step(0, &mut inner, &policy, now, &mut events) {
            SlotState::Backoff { until } => assert_eq!(until - now, Duration::from_millis(10)),
            _ => panic!("expected backoff"),
        }
        match breaker_step(0, &mut inner, &policy, now, &mut events) {
            SlotState::Backoff { until } => assert_eq!(until - now, Duration::from_millis(20)),
            _ => panic!("expected backoff"),
        }
        assert!(events.is_empty());
        // failure 3 trips the breaker
        assert!(matches!(
            breaker_step(0, &mut inner, &policy, now, &mut events),
            SlotState::Excluded
        ));
        assert_eq!(events, vec![PoolEvent::ReplicaExcluded { replica: 0 }]);
        // a successful restart resets the exponent but not the window:
        // budget counts failures, not consecutive failures
        inner.consecutive = 0;
        assert!(matches!(
            breaker_step(0, &mut inner, &policy, now, &mut events),
            SlotState::Excluded
        ));
    }

    #[test]
    fn dispatch_pins_group_while_in_flight() {
        let d = DispatchState::new(2);
        let g0 = key(0, 0);
        let g1 = key(0, 1);
        // first assignment: tie at zero load -> lowest index
        assert_eq!(d.assign(g0), (0, 0));
        // pinned while in flight, even though replica 1 is emptier
        assert_eq!(d.assign(g0), (0, 0));
        assert_eq!(d.inflight(0), 2);
        assert_eq!(d.inflight(1), 0);
        // a different group routes to the least-loaded replica
        assert_eq!(d.assign(g1), (1, 0));
        assert_eq!(d.pinned_groups(), 2);
        // draining one batch keeps the pin; draining all releases it
        d.complete(g0, 0, 0);
        assert_eq!(d.assign(g0).0, 0, "still one batch in flight: pinned");
        d.complete(g0, 0, 0);
        d.complete(g0, 0, 0);
        assert_eq!(d.pinned_groups(), 1);
        assert_eq!(d.inflight(0), 0);
        // migration: replica 1 carries g1's batch, so g0 re-pins to 0 —
        // but if 0 were loaded it could move (see prop test)
        assert_eq!(d.assign(g0).0, 0);
        d.complete(g1, 1, 0);
        d.complete(g0, 0, 0);
        assert_eq!(d.pinned_groups(), 0);
    }

    #[test]
    fn dispatch_migrates_drained_group_off_loaded_replica() {
        let d = DispatchState::new(2);
        let g0 = key(0, 0);
        let g1 = key(1, 0);
        // g0 runs a batch on replica 0 and drains
        assert_eq!(d.assign(g0).0, 0);
        d.complete(g0, 0, 0);
        assert_eq!(d.pinned_groups(), 0);
        // g1 now occupies replica 0 (tie at zero load -> lowest index)
        assert_eq!(d.assign(g1).0, 0);
        // g0 returns while replica 0 is loaded: it migrates to replica 1
        // — pinning is per in-flight window, not a permanent assignment
        assert_eq!(d.assign(g0).0, 1);
        d.complete(g1, 0, 0);
        d.complete(g0, 1, 0);
        assert_eq!(d.pinned_groups(), 0);
        assert_eq!(d.inflight(0) + d.inflight(1), 0);
    }

    #[test]
    fn dead_replica_is_excluded_and_revive_readmits_with_stale_generations() {
        let d = DispatchState::new(2);
        let g0 = key(0, 0);
        let g1 = key(0, 1);
        let (r, gen0) = d.assign(g0);
        assert_eq!((r, gen0), (0, 0));
        d.mark_dead(0);
        assert!(!d.alive(0));
        assert_eq!(d.generation(0), 1, "death bumps the generation");
        // pins on the dead replica are purged and its counter zeroed:
        // g0's next batch migrates
        assert_eq!(d.pinned_groups(), 0);
        assert_eq!(d.inflight(0), 0);
        assert_eq!(d.assign(g0).0, 1);
        // the dead replica never wins least-loaded, even though its
        // in-flight count is the minimum
        assert_eq!(d.assign(g1).0, 1);
        // the dead incarnation's completion is stale twice over: its
        // generation predates the bump and its pin is gone
        d.complete(g0, 0, gen0);
        assert_eq!(d.inflight(1), 2);
        assert_eq!(d.pinned_groups(), 2);
        // revive re-admits at the bumped generation
        d.revive(0);
        assert!(d.alive(0));
        assert_eq!(d.generation(0), 1);
        let g2 = key(1, 0);
        let (r2, gen2) = d.assign(g2);
        assert_eq!((r2, gen2), (0, 1), "revived replica is least-loaded again");
        // a late pre-death completion for the same slot still can't touch
        // the new incarnation's accounting
        d.complete(g2, 0, gen0);
        assert_eq!(d.inflight(0), 1);
        d.complete(g2, 0, gen2);
        d.complete(g0, 1, 0);
        d.complete(g1, 1, 0);
        assert_eq!(d.pinned_groups(), 0);
        assert_eq!(d.inflight(0) + d.inflight(1), 0);
    }

    #[test]
    fn prop_per_group_fifo_pinning_and_count_consistency() {
        forall("dispatch-pinning", 60, |r: &mut Rng| {
            let nrep = 1 + r.below(4);
            let d = DispatchState::new(nrep);
            // in-flight batches as (group, replica, generation)
            let mut open: Vec<((TaskId, PolicyId), usize, u64)> = Vec::new();
            let mut pinned: HashMap<(TaskId, PolicyId), usize> = HashMap::new();
            for _ in 0..200 {
                if open.is_empty() || r.bool() {
                    let k = key(r.below(2) as u16, r.below(3) as u16);
                    let loads: Vec<usize> = (0..nrep).map(|i| d.inflight(i)).collect();
                    let (rep, gen) = d.assign(k);
                    assert!(rep < nrep);
                    assert_eq!(gen, 0, "no deaths in this test");
                    match pinned.get(&k) {
                        // the FIFO guarantee: while a group has batches in
                        // flight, every new batch lands on the same replica
                        Some(p) => assert_eq!(*p, rep, "group reassigned while in flight"),
                        // a fresh (or migrated) group takes a least-loaded
                        // replica, measured before this assignment
                        None => {
                            let min = loads.iter().copied().min().unwrap();
                            assert_eq!(loads[rep], min, "not least-loaded: {loads:?} -> {rep}");
                            pinned.insert(k, rep);
                        }
                    }
                    open.push((k, rep, gen));
                } else {
                    let i = r.below(open.len());
                    let (k, rep, gen) = open.swap_remove(i);
                    d.complete(k, rep, gen);
                    if !open.iter().any(|(ok, _, _)| *ok == k) {
                        pinned.remove(&k);
                    }
                }
                // accounting consistency: per-replica in-flight counters
                // always equal the number of open batches per replica
                for rep in 0..nrep {
                    assert_eq!(
                        d.inflight(rep),
                        open.iter().filter(|(_, p, _)| *p == rep).count(),
                        "replica {rep} count drifted"
                    );
                }
                assert_eq!(d.pinned_groups(), pinned.len());
            }
            for (k, rep, gen) in open.drain(..) {
                d.complete(k, rep, gen);
            }
            assert_eq!(d.pinned_groups(), 0);
            for rep in 0..nrep {
                assert_eq!(d.inflight(rep), 0);
            }
        });
    }

    #[test]
    fn prop_supervised_dispatch_generations_neutralize_stale_completions() {
        forall("dispatch-supervision", 60, |r: &mut Rng| {
            let nrep = 1 + r.below(4);
            let d = DispatchState::new(nrep);
            // live batches vs completions orphaned by a death (stale)
            let mut open: Vec<((TaskId, PolicyId), usize, u64)> = Vec::new();
            let mut stale: Vec<((TaskId, PolicyId), usize, u64)> = Vec::new();
            let mut pinned: HashMap<(TaskId, PolicyId), usize> = HashMap::new();
            let mut alive = vec![true; nrep];
            for _ in 0..300 {
                match r.below(10) {
                    // kill a replica: its open batches become stale
                    0 => {
                        let rep = r.below(nrep);
                        if alive[rep] {
                            d.mark_dead(rep);
                            alive[rep] = false;
                            let mut kept = Vec::new();
                            for e in open.drain(..) {
                                if e.1 == rep {
                                    stale.push(e);
                                } else {
                                    kept.push(e);
                                }
                            }
                            open = kept;
                            pinned.retain(|_, p| *p != rep);
                        }
                    }
                    // supervised restart re-admits the slot
                    1 => {
                        let rep = r.below(nrep);
                        if !alive[rep] {
                            d.revive(rep);
                            alive[rep] = true;
                        }
                    }
                    // replay a stale completion at a random point: the
                    // generation tag must make it a strict no-op
                    2 | 3 if !stale.is_empty() => {
                        let i = r.below(stale.len());
                        let (k, rep, gen) = stale.swap_remove(i);
                        d.complete(k, rep, gen);
                    }
                    _ if open.is_empty() || r.bool() => {
                        let k = key(r.below(2) as u16, r.below(3) as u16);
                        let (rep, gen) = d.assign(k);
                        assert!(rep < nrep);
                        assert_eq!(gen, d.generation(rep));
                        match pinned.get(&k) {
                            Some(p) => assert_eq!(*p, rep, "group reassigned while in flight"),
                            None => {
                                if alive.iter().any(|a| *a) {
                                    assert!(
                                        alive[rep],
                                        "assigned to a dead replica while a live one exists"
                                    );
                                }
                                pinned.insert(k, rep);
                            }
                        }
                        open.push((k, rep, gen));
                    }
                    _ => {
                        let i = r.below(open.len());
                        let (k, rep, gen) = open.swap_remove(i);
                        d.complete(k, rep, gen);
                        if !open.iter().any(|(ok, _, _)| *ok == k) {
                            pinned.remove(&k);
                        }
                    }
                }
                // the live accounting never drifts, no matter how death,
                // revival, and stale replays interleave
                for rep in 0..nrep {
                    assert_eq!(
                        d.inflight(rep),
                        open.iter().filter(|(_, p, _)| *p == rep).count(),
                        "replica {rep} count drifted"
                    );
                }
                assert_eq!(d.pinned_groups(), pinned.len());
            }
            for (k, rep, gen) in open.drain(..) {
                d.complete(k, rep, gen);
            }
            // any leftover stale completions drain as no-ops
            for (k, rep, gen) in stale.drain(..) {
                d.complete(k, rep, gen);
            }
            assert_eq!(d.pinned_groups(), 0);
            for rep in 0..nrep {
                assert_eq!(d.inflight(rep), 0, "stale completion corrupted replica {rep}");
            }
        });
    }
}
