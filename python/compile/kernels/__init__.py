"""L1: Pallas kernels for ZeroQuant-HERO's quantization-aware operators.

Every kernel has a pure-jnp oracle in :mod:`ref` and runs in interpret mode
(the repo executes on CPU PJRT; the BlockSpec structure is the TPU
schedule -- see DESIGN.md section 7).
"""

from .ln_quant import ln_quant, ln_quant_embed, twq_quantize
from .gemm_quant import (
    gemm_twq_to_i8,
    gemm_twq_to_f32,
    gemm_folded_to_i8,
    gemm_folded_to_f32,
)
from .gelu_quant import gelu_quant, gelu_fp
from .softmax_quant import softmax_quant
from .attention_quant import attention_quant

__all__ = [
    "ln_quant",
    "ln_quant_embed",
    "twq_quantize",
    "gemm_twq_to_i8",
    "gemm_twq_to_f32",
    "gemm_folded_to_i8",
    "gemm_folded_to_f32",
    "gelu_quant",
    "gelu_fp",
    "softmax_quant",
    "attention_quant",
]
