"""L2: JAX encoder — FP baseline, HERO quantized modes, calibration."""

from .params import (
    fp_param_specs, hero_param_specs, init_fp_params,
    specs_to_struct, list_to_dict, dict_to_list,
)
from .bert import bert_forward
from .hero import hero_forward
from .calibration import calibration_forward, STAT_NAMES, stat_shapes
from .quantize import quantize_checkpoint, derive_scales
