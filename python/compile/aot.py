"""AOT build driver: datasets -> trained checkpoints -> HLO-text artifacts.

Runs once under ``make artifacts``; the rust binary is self-contained
afterwards.  HLO *text* (not serialized HloModuleProto) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--fast] [--force] \
        [--stage all|data|train|models|calib|micro|manifest]
"""

import argparse
import json
import os
import time

import numpy as np

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .config import ModelConfig, MODES, QMAX  # noqa: E402
from . import data as D  # noqa: E402
from .container import write_container, read_container  # noqa: E402
from .modeling import (  # noqa: E402
    fp_param_specs, hero_param_specs, init_fp_params,
    specs_to_struct, list_to_dict,
    bert_forward, hero_forward, calibration_forward, STAT_NAMES, stat_shapes,
)
from . import train as T  # noqa: E402

BUCKETS = (1, 4, 8, 16)
SEQ = 128
# Sequence-length buckets (format_version 3): model executables are
# lowered per (seq_bucket, batch_bucket) cell so short requests ride a
# short executable instead of paying full-SEQ memory traffic on every
# bandwidth-bound op.  Strictly ascending; the last entry must equal SEQ
# (the rust loader enforces both).
SEQ_BUCKETS = (16, 32, 64, 128)
CALIB_BATCH = 16

EPOCHS = {"cola": 10, "mrpc": 8, "stsb": 10, "rte": 14,
          "qnli": 8, "sst2": 6, "mnli": 8, "qqp": 6}
LR = 5e-4

MICRO_NAMES = ("ln_fp", "ln_quant", "gemm_fp", "gemm_int8", "gemm_fp_ffn",
               "gemm_int8_ffn", "gelu_fp", "gelu_quant", "attn_fp", "attn_int8")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, arg_structs, path):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*arg_structs)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  lowered {path} ({len(text) / 1e6:.2f} MB, {time.time() - t0:.1f}s)")


def input_structs(batch, seq=SEQ):
    return [
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),    # input_ids
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),    # type_ids
        jax.ShapeDtypeStruct((batch, seq), jnp.float32),  # attn_mask
    ]


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------


def build_datasets(out, cfg, fast, force):
    for task in D.TASKS:
        tdir = os.path.join(out, "tasks", task)
        meta_path = os.path.join(tdir, "meta.json")
        if os.path.exists(meta_path) and not force:
            print(f"  [data] {task}: exists, skip")
            continue
        os.makedirs(tdir, exist_ok=True)
        splits = D.make_task(task, seq_len=SEQ, fast=fast)
        split_files = {}
        for name, split in splits.items():
            path = os.path.join(tdir, f"{name}.bin")
            write_container(path, split)
            split_files[name] = f"tasks/{task}/{name}.bin"
        meta = dict(D.TASK_META[task])
        meta.update(task=task, seq_len=SEQ, splits=split_files,
                    sizes={k: int(v["input_ids"].shape[0]) for k, v in splits.items()})
        with open(meta_path, "w") as f:
            json.dump(meta, f, indent=1)
        print(f"  [data] {task}: {meta['sizes']}")


def train_all(out, cfg, fast, force):
    results = {}
    for task in D.TASKS:
        cdir = os.path.join(out, "checkpoints", task)
        ckpt = os.path.join(cdir, "fp32.bin")
        mpath = os.path.join(cdir, "train_metrics.json")
        if os.path.exists(ckpt) and not force:
            print(f"  [train] {task}: checkpoint exists, skip")
            if os.path.exists(mpath):
                results[task] = json.load(open(mpath))
            continue
        os.makedirs(cdir, exist_ok=True)
        tdir = os.path.join(out, "tasks", task)
        meta = json.load(open(os.path.join(tdir, "meta.json")))
        splits = {name: dict(read_container(os.path.join(out, rel)))
                  for name, rel in meta["splits"].items()}
        epochs = 1 if fast else EPOCHS[task]
        params, dev = T.train_task(
            task, splits, cfg, init_fp_params(cfg, seed=42), epochs=epochs, lr=LR)
        # jax flattens dict pytrees in sorted-key order; restore the
        # canonical manifest order before writing (the rust loader also
        # defensively reorders, see Container::reordered)
        params = {name: params[name] for name, _, _ in fp_param_specs(cfg)}
        write_container(ckpt, params)
        json.dump(dev, open(mpath, "w"))
        results[task] = dev
    return results


def make_model_fn(cfg, mode):
    sw = MODES[mode]
    if mode == "fp":
        specs = fp_param_specs(cfg)

        def fn(*args):
            params = list_to_dict(specs, args[:-3])
            return (bert_forward(params, cfg, *args[-3:]),)
    else:
        specs = hero_param_specs(cfg, sw)

        def fn(*args):
            params = list_to_dict(specs, args[:-3])
            return (hero_forward(params, cfg, sw, *args[-3:]),)
    return fn, specs


def lower_models(out, cfg, force):
    for mode in MODES:
        fn, specs = make_model_fn(cfg, mode)
        structs = specs_to_struct(specs)
        for s in SEQ_BUCKETS:
            for b in BUCKETS:
                path = os.path.join(out, "models", mode, f"s{s}_b{b}.hlo.txt")
                if os.path.exists(path) and not force:
                    continue
                lower_to_file(fn, structs + input_structs(b, s), path)


def lower_calibration(out, cfg, force):
    specs = fp_param_specs(cfg)

    def fn(*args):
        params = list_to_dict(specs, args[:-3])
        logits, stats = calibration_forward(params, cfg, *args[-3:])
        return (logits,) + tuple(stats[k] for k in STAT_NAMES)

    path = os.path.join(out, "calib", f"instrumented_b{CALIB_BATCH}.hlo.txt")
    if os.path.exists(path) and not force:
        return
    lower_to_file(fn, specs_to_struct(specs) + input_structs(CALIB_BATCH), path)


def lower_micro(out, cfg, force):
    """Micro-kernel artifacts for the per-op FP-vs-INT8 benches."""
    from .kernels import ln_quant, gemm_twq_to_i8, gelu_quant, attention_quant
    from .modeling.bert import layer_norm
    from .kernels.ref import gelu as gelu_ref, attention_fp

    n, d, f = 2048, cfg.hidden, cfg.ffn
    bh, s, dh = 16 * cfg.heads, SEQ, cfg.head_dim
    f32, i8 = jnp.float32, jnp.int8
    S = jax.ShapeDtypeStruct

    micro = {}

    def add(name, fn, structs):
        path = os.path.join(out, "micro", f"{name}.hlo.txt")
        micro[name] = f"micro/{name}.hlo.txt"
        if os.path.exists(path) and not force:
            return
        lower_to_file(fn, structs, path)

    add("ln_fp",
        lambda x, g, b: (layer_norm(x, g, b, cfg.ln_eps),),
        [S((n, d), f32), S((d,), f32), S((d,), f32)])
    add("ln_quant",
        lambda a, sa, bq, sb, g, b: ln_quant(a, bq, g, b, a_scale=sa, b_scale=sb),
        [S((n, d), i8), S((n, 1), f32), S((n, d), i8), S((1, d), f32),
         S((d,), f32), S((d,), f32)])
    add("gemm_fp",
        lambda x, w, b: (x @ w + b,),
        [S((n, d), f32), S((d, d), f32), S((d,), f32)])
    add("gemm_int8",
        lambda x, w, xs, ws, b: (gemm_twq_to_i8(x, w, xs, ws, b),),
        [S((n, d), i8), S((d, d), i8), S((n, 1), f32), S((1, d), f32),
         S((1, d), f32)])
    add("gemm_fp_ffn",
        lambda x, w, b: (x @ w + b,),
        [S((n, d), f32), S((d, f), f32), S((f,), f32)])
    add("gemm_int8_ffn",
        lambda x, w, xs, ws, b: (gemm_twq_to_i8(x, w, xs, ws, b),),
        [S((n, d), i8), S((d, f), i8), S((n, 1), f32), S((1, f), f32),
         S((1, f), f32)])
    add("gelu_fp",
        lambda x: (gelu_ref(x),),
        [S((n, f), f32)])
    add("gelu_quant",
        lambda x, sa: (gelu_quant(x, sa),),
        [S((n, f), f32), S((1, f), f32)])
    add("attn_fp",
        lambda q, k, v, m: (attention_fp(q, k, v, m, 1.0 / np.sqrt(dh)),),
        [S((bh, s, dh), f32)] * 3 + [S((bh, s), f32)])
    add("attn_int8",
        lambda q, k, v, m, qk, sp, pv: (attention_quant(q, k, v, m, qk, sp, pv),),
        [S((bh, s, dh), i8)] * 3 + [S((bh, s), f32), S((1, 1), f32),
                                    S((1, 1), f32), S((bh, 1, dh), f32)])
    return micro


def build_golden(out, cfg, force):
    """Cross-language parity fixtures: python-quantized checkpoints that the
    rust engine must reproduce bit-exactly (tests/golden_parity.rs)."""
    from .config import MODES
    from .modeling.quantize import quantize_checkpoint

    gdir = os.path.join(out, "golden")
    if os.path.exists(os.path.join(gdir, "hero-m3.bin")) and not force:
        return
    os.makedirs(gdir, exist_ok=True)
    fp = init_fp_params(cfg, seed=7)
    write_container(os.path.join(gdir, "fp32.bin"), fp)

    r = np.random.default_rng(11)
    L, d, f = cfg.layers, cfg.hidden, cfg.ffn
    nb = 3
    shapes = {"q_absmax": (L,), "k_absmax": (L,), "v_absmax": (L,),
              "p_max": (L,), "attn_absmax": (L, d), "o_absmax": (L, d),
              "gelu_absmax": (L, f), "x2_absmax": (L, d)}
    hist = {}
    for k, shp in shapes.items():
        base = np.exp(r.uniform(np.log(0.05), np.log(8.0), size=shp))
        if k == "p_max":
            base = r.uniform(0.5, 1.0, size=shp)
        hist[k] = np.stack([base * r.uniform(0.8, 1.2, size=shp)
                            for _ in range(nb)]).astype(np.float32)
    # calib.json in the rust calibrator's format (flattened per batch)
    doc = {"batches": nb,
           "stats": {k: [v[b].reshape(-1).astype(np.float64).tolist()
                         for b in range(nb)] for k, v in hist.items()}}
    json.dump(doc, open(os.path.join(gdir, "calib.json"), "w"))
    for mode, sw in MODES.items():
        if mode == "fp":
            continue
        hq = quantize_checkpoint(fp, hist, cfg, sw)
        write_container(os.path.join(gdir, f"hero-{mode}.bin"), hq)
    print(f"  wrote golden fixtures ({nb} batches) to {gdir}")


def write_manifest(out, cfg, micro, train_metrics):
    modes = {}
    for mode in MODES:
        sw = MODES[mode]
        specs = fp_param_specs(cfg) if mode == "fp" else hero_param_specs(cfg, sw)
        modes[mode] = {
            "switches": {k: getattr(sw, k) for k in
                         ("embedding", "qkv", "attn", "attn_output", "fc1", "fc2")},
            "params": [[n, list(s), d] for n, s, d in specs],
            "artifacts": {f"s{s}b{b}": f"models/{mode}/s{s}_b{b}.hlo.txt"
                          for s in SEQ_BUCKETS for b in BUCKETS},
        }
    tasks = {}
    for task in D.TASKS:
        meta = json.load(open(os.path.join(out, "tasks", task, "meta.json")))
        tasks[task] = meta
        tasks[task]["checkpoint"] = f"checkpoints/{task}/fp32.bin"
        tasks[task]["train_dev_metrics"] = train_metrics.get(task)
    from .config import POLICIES
    manifest = {
        # 2: adds the `policies` section (named precision policies).
        # 3: adds `seq_buckets` and keys model artifacts by
        #    (seq bucket, batch bucket) as "s{S}b{B}".  Both keys are
        #    optional to the rust loader — a v2 manifest (no seq_buckets,
        #    bare "bN" artifact keys) collapses to the single-bucket axis
        #    [seq] and serves identically.
        "format_version": 3,
        "model": {
            "vocab_size": cfg.vocab_size, "hidden": cfg.hidden,
            "layers": cfg.layers, "heads": cfg.heads, "ffn": cfg.ffn,
            "max_seq": cfg.max_seq, "type_vocab": cfg.type_vocab,
            "num_labels": cfg.num_labels, "ln_eps": cfg.ln_eps,
        },
        "seq": SEQ,
        "seq_buckets": list(SEQ_BUCKETS),
        "buckets": list(BUCKETS),
        "qmax": QMAX,
        "modes": modes,
        "policies": POLICIES,
        "calib": {
            "artifact": f"calib/instrumented_b{CALIB_BATCH}.hlo.txt",
            "batch": CALIB_BATCH,
            "params": [[n, list(s), d] for n, s, d in fp_param_specs(cfg)],
            "stats": [[k, list(stat_shapes(cfg)[k])] for k in STAT_NAMES],
        },
        "tasks": tasks,
        "micro": micro or {},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("  wrote manifest.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="small datasets + 1 epoch (CI smoke)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--stage", default="all",
                    choices=["all", "data", "train", "models", "calib",
                             "micro", "golden", "manifest"])
    args = ap.parse_args()
    fast = args.fast or os.environ.get("ZQH_FAST") == "1"
    cfg = ModelConfig()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    t0 = time.time()
    train_metrics = {}
    if args.stage in ("all", "data"):
        print("== datasets ==")
        build_datasets(out, cfg, fast, args.force)
    if args.stage in ("all", "train"):
        print("== training ==")
        train_metrics = train_all(out, cfg, fast, args.force)
    if args.stage in ("all", "models"):
        print("== model artifacts ==")
        lower_models(out, cfg, args.force)
    if args.stage in ("all", "calib"):
        print("== calibration artifact ==")
        lower_calibration(out, cfg, args.force)
    micro = None
    if args.stage in ("all", "micro"):
        print("== micro artifacts ==")
        micro = lower_micro(out, cfg, args.force)
    if args.stage in ("all", "golden"):
        print("== golden parity fixtures ==")
        build_golden(out, cfg, args.force)
    if args.stage in ("all", "manifest"):
        if not train_metrics:
            for task in D.TASKS:
                p = os.path.join(out, "checkpoints", task, "train_metrics.json")
                if os.path.exists(p):
                    train_metrics[task] = json.load(open(p))
        if micro is None:
            micro = {k: f"micro/{k}.hlo.txt" for k in MICRO_NAMES}
        write_manifest(out, cfg, micro, train_metrics)
    print(f"== done in {time.time() - t0:.0f}s ==")


if __name__ == "__main__":
    main()
