//! Quickstart: load the INT8 (M3) model for one task, run a single
//! request end-to-end through the PJRT runtime, print the logits.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use zqhero::data::Split;
use zqhero::evalharness as eh;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::Runtime;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    let man = Manifest::load(&dir)?;
    let mut rt = Runtime::new(man)?;

    let task = rt.manifest.task("sst2")?.clone();
    println!("task: {} ({:?})", task.name, task.metrics);

    // PTQ pipeline on demand: calibrate (paper: 100 batches x 16), fold
    // scales into weights (eqs. 20-23, 32), column-quantize, upload.
    println!("preparing ZeroQuant-HERO-M3 checkpoint...");
    eh::ensure_checkpoint(&mut rt, &task, "m3", eh::DEFAULT_CALIB_BATCHES, 100.0)?;

    // one dev example through the INT8 graph
    let split = Split::load(&rt.manifest, &task, "dev")?;
    let (ids, tys) = split.row(0);
    let mask = Split::mask_row(ids);
    rt.infer(&task.name, "m3", 1, ids, tys, &mask)?; // warm: compiles the HLO
    let t0 = std::time::Instant::now();
    let logits = rt.infer(&task.name, "m3", 1, ids, tys, &mask)?;
    let us = t0.elapsed().as_micros();

    let v = logits.as_f32()?;
    let tokens: Vec<i32> = ids.iter().copied().filter(|t| *t != 0).collect();
    println!("input ({} tokens): {:?}...", tokens.len(), &tokens[..8.min(tokens.len())]);
    println!("logits: {:?}  ({} us, INT8 W8A8 end-to-end)", &v[..2], us);
    println!("prediction: class {}", if v[0] >= v[1] { 0 } else { 1 });
    Ok(())
}
