//! Serving metrics: lock-light latency/throughput recording with
//! log-bucketed histograms, keyed by interned precision policy.
//! Recording is index-addressed (`PolicyId` -> dense slot) so the
//! steady-state path never allocates; names reappear only in
//! `snapshot`/`render`.  Uniform per-mode policies occupy the first
//! slots, so v1 (string-mode) traffic keeps its mode-name keys.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::model::manifest::PolicyId;

/// Log2-bucketed latency histogram (microseconds).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) us; 64 buckets.
    counts: Vec<u64>,
    total: u64,
    sum_us: u128,
    max_us: u64,
    min_us: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; 64], total: 0, sum_us: 0, max_us: 0, min_us: u64::MAX }
    }

    pub fn record(&mut self, us: u64) {
        let bucket = 63 - us.max(1).leading_zeros() as usize;
        self.counts[bucket.min(63)] += 1;
        self.total += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
        self.min_us = self.min_us.min(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    /// Percentile estimate: linear interpolation inside the target
    /// log2 bucket (assuming uniform spread), clamped to the observed
    /// [min, max].  Returning the bucket's upper bound — the previous
    /// behaviour — over-reported by up to 2x; with the clamp, a
    /// single-valued histogram is exact at every percentile.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let want = (self.total as f64 * p).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            if *c == 0 {
                continue;
            }
            if seen + c >= want {
                let lo = (1u64 << i) as f64;
                let hi = lo * 2.0; // avoids 1<<64 overflow in the top bucket
                // midpoint of the k-th sample's share of the bucket
                let frac = ((want - seen) as f64 - 0.5) / *c as f64;
                let v = lo + frac * (hi - lo);
                return (v as u64).clamp(self.min_us, self.max_us);
            }
            seen += c;
        }
        self.max_us
    }

    pub fn max_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.max_us }
    }

    pub fn min_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min_us }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug, Default, Clone)]
pub struct PolicyStats {
    pub latency: Histogram,
    pub exec: Histogram,
    pub queue: Histogram,
    pub requests: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub errors: u64,
}

impl PolicyStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rows as f64 / self.batches as f64
        }
    }

    fn active(&self) -> bool {
        self.requests > 0 || self.batches > 0 || self.errors > 0
    }
}

/// Per-replica batch accounting for the engine pool (DESIGN.md §5.7):
/// how many batches (and request rows) each replica executed, the
/// load-balance witness the replica-scaling bench and tests read.
#[derive(Debug, Default, Clone)]
pub struct ReplicaStats {
    pub batches: u64,
    pub rows: u64,
}

/// Both slot tables behind the recorder's single mutex: per-policy and
/// per-replica counters update atomically together, so "per-replica
/// batch counts sum to per-policy batch totals" holds for every
/// observer, not just quiescent ones.
struct Slots {
    policies: Vec<PolicyStats>,
    replicas: Vec<ReplicaStats>,
}

/// Shared recorder (single mutex — recording is tiny next to inference).
/// Slots are dense by `PolicyId`; policy names are kept only for
/// rendering.  Replica slots are dense by replica index, fixed at
/// startup; per-replica batch counts always sum to the per-policy batch
/// totals (every batch is recorded once, with the replica that ran it,
/// under one lock).
pub struct Recorder {
    start: Instant,
    policies: Vec<String>,
    inner: Mutex<Slots>,
}

impl Recorder {
    /// `policies` is the manifest's `policy_order` — the `PolicyId` space
    /// (uniform mode policies first, then the `policies` section).
    /// `replicas` is the engine-pool size (min 1).
    pub fn new(policies: Vec<String>, replicas: usize) -> Self {
        let slots = Slots {
            policies: policies.iter().map(|_| PolicyStats::default()).collect(),
            replicas: vec![ReplicaStats::default(); replicas.max(1)],
        };
        Recorder { start: Instant::now(), policies, inner: Mutex::new(slots) }
    }

    pub fn record_request(&self, policy: PolicyId, total_us: u64, queue_us: u64, err: bool) {
        let mut g = self.inner.lock().unwrap();
        // slots are policy_order-sized; a foreign PolicyId is a bug, not a slot
        let s = &mut g.policies[policy.index()];
        s.requests += 1;
        if err {
            s.errors += 1;
        } else {
            s.latency.record(total_us);
            s.queue.record(queue_us);
        }
    }

    pub fn record_batch(&self, policy: PolicyId, rows: usize, exec_us: u64, replica: usize) {
        let mut g = self.inner.lock().unwrap();
        let s = &mut g.policies[policy.index()];
        s.batches += 1;
        s.batched_rows += rows as u64;
        s.exec.record(exec_us);
        // replica slots are fixed at startup; an out-of-range index is an
        // engine-pool bug, not a slot to grow
        let rs = &mut g.replicas[replica];
        rs.batches += 1;
        rs.rows += rows as u64;
    }

    /// Per-replica batch counts, dense by replica index (all replicas,
    /// including idle ones — the imbalance is the signal).
    pub fn replica_snapshot(&self) -> Vec<ReplicaStats> {
        self.inner.lock().unwrap().replicas.clone()
    }

    fn policy_snapshot_of(&self, slots: &Slots) -> BTreeMap<String, PolicyStats> {
        slots
            .policies
            .iter()
            .enumerate()
            .filter(|(_, s)| s.active())
            .map(|(i, s)| (self.policies[i].clone(), s.clone()))
            .collect()
    }

    /// Per-policy stats keyed by policy name, active policies only (so
    /// callers see the same shape as traffic they actually sent).
    pub fn snapshot(&self) -> BTreeMap<String, PolicyStats> {
        let g = self.inner.lock().unwrap();
        self.policy_snapshot_of(&g)
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Human-readable summary table.  Both tables come from one lock
    /// acquisition, so the replica counts always sum to the policy batch
    /// totals even while traffic is flowing.
    pub fn render(&self) -> String {
        use crate::bench::Table;
        let (snap, reps) = {
            let g = self.inner.lock().unwrap();
            (self.policy_snapshot_of(&g), g.replicas.clone())
        };
        let elapsed = self.elapsed_s();
        let mut t = Table::new(&[
            "policy", "reqs", "errs", "thr(req/s)", "mean batch", "p50 lat", "p95 lat",
            "p99 lat", "mean exec/batch",
        ]);
        for (policy, s) in &snap {
            t.row(vec![
                policy.clone(),
                s.requests.to_string(),
                s.errors.to_string(),
                format!("{:.1}", s.requests as f64 / elapsed.max(1e-9)),
                format!("{:.2}", s.mean_batch_size()),
                format!("{:.1}ms", s.latency.percentile_us(0.50) as f64 / 1e3),
                format!("{:.1}ms", s.latency.percentile_us(0.95) as f64 / 1e3),
                format!("{:.1}ms", s.latency.percentile_us(0.99) as f64 / 1e3),
                format!("{:.1}ms", s.exec.mean_us() / 1e3),
            ]);
        }
        let mut out = t.render();
        if reps.len() > 1 {
            let total: u64 = reps.iter().map(|r| r.batches).sum();
            let mut rt = Table::new(&["replica", "batches", "rows", "share"]);
            for (i, r) in reps.iter().enumerate() {
                rt.row(vec![
                    i.to_string(),
                    r.batches.to_string(),
                    r.rows.to_string(),
                    format!("{:.0}%", 100.0 * r.batches as f64 / total.max(1) as f64),
                ]);
            }
            out.push('\n');
            out.push_str(&rt.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_percentiles() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 5120] {
            h.record(us);
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(0.5) >= 80);
        assert!(h.percentile_us(1.0) >= 5120);
        assert_eq!(h.min_us(), 10);
        assert_eq!(h.max_us(), 5120);
    }

    #[test]
    fn histogram_zero_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn percentile_interpolates_instead_of_upper_bound() {
        // 1000 identical samples: every percentile must be exact, not the
        // bucket's upper bound (the old behaviour returned 128 for 100us).
        let mut h = Histogram::new();
        for _ in 0..1000 {
            h.record(100);
        }
        assert_eq!(h.percentile_us(0.50), 100);
        assert_eq!(h.percentile_us(0.99), 100);
        assert_eq!(h.percentile_us(1.0), 100);

        // mixed: estimates stay inside the sample range and monotone in p
        let mut h = Histogram::new();
        for us in [100u64, 110, 120, 130, 900, 950, 1000, 1100, 1200, 1300] {
            h.record(us);
        }
        let p50 = h.percentile_us(0.50);
        let p90 = h.percentile_us(0.90);
        let p100 = h.percentile_us(1.0);
        // 5th of 10 samples is 900 (bucket [512,1024)); 9th is 1200
        assert!(p50 >= 512 && p50 <= 1024, "p50 {p50}");
        assert!(p90 >= 1024 && p90 <= 1300, "p90 {p90}");
        assert!(p50 <= p90 && p90 <= p100);
        assert_eq!(p100, 1300);
    }

    #[test]
    fn recorder_accumulates_per_policy() {
        // uniform mode policies first, then a named override policy
        let r = Recorder::new(vec!["fp".into(), "m3".into(), "attn-out-fp".into()], 1);
        let fp = PolicyId(0);
        let m3 = PolicyId(1);
        let named = PolicyId(2);
        r.record_request(m3, 1000, 100, false);
        r.record_request(m3, 2000, 200, false);
        r.record_request(fp, 99, 9, true);
        r.record_request(named, 500, 50, false);
        r.record_batch(m3, 8, 500, 0);
        let snap = r.snapshot();
        assert_eq!(snap["m3"].requests, 2);
        assert_eq!(snap["fp"].errors, 1);
        assert_eq!(snap["attn-out-fp"].requests, 1);
        assert_eq!(snap["m3"].mean_batch_size(), 8.0);
        assert!(r.render().contains("m3"));
        assert!(r.render().contains("attn-out-fp"));
        // single-replica serving keeps the plain render (no replica table)
        assert!(!r.render().contains("replica"));
    }

    #[test]
    fn recorder_snapshot_hides_idle_policies() {
        let r = Recorder::new(vec!["fp".into(), "m1".into()], 1);
        r.record_request(PolicyId(0), 10, 1, false);
        let snap = r.snapshot();
        assert!(snap.contains_key("fp"));
        assert!(!snap.contains_key("m1"));
    }

    #[test]
    fn per_replica_batch_counts_sum_to_policy_totals() {
        let r = Recorder::new(vec!["fp".into(), "m3".into()], 3);
        r.record_batch(PolicyId(0), 4, 100, 0);
        r.record_batch(PolicyId(1), 2, 100, 2);
        r.record_batch(PolicyId(1), 1, 100, 2);
        let reps = r.replica_snapshot();
        assert_eq!(reps.len(), 3);
        let per_policy: u64 = r.snapshot().values().map(|s| s.batches).sum();
        let per_replica: u64 = reps.iter().map(|x| x.batches).sum();
        assert_eq!(per_replica, per_policy);
        assert_eq!(reps[0].batches, 1);
        assert_eq!(reps[0].rows, 4);
        assert_eq!(reps[1].batches, 0, "idle replicas keep their slot");
        assert_eq!(reps[2].batches, 2);
        assert_eq!(reps[2].rows, 3);
        // multi-replica render appends the per-replica table
        assert!(r.render().contains("replica"));
    }
}
