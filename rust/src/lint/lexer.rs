//! Minimal Rust lexer for the herolint analyses (DESIGN.md §5.11).
//!
//! Dependency-free, in the spirit of `json`/`prop`/`cli`: `syn` is
//! unavailable offline, and the four lint rules only need a token
//! stream with line numbers plus the suppression annotations — not a
//! full AST.  The lexer understands exactly enough of the language to
//! be line-accurate through the constructs that defeat naive text
//! scans: nested block comments, string/char literals (including raw
//! strings with `#` fences and byte strings), and the lifetime-vs-char
//! ambiguity of `'`.
//!
//! Suppression annotations are ordinary line comments with a required
//! reason:
//!
//! ```text
//! // panic-ok: <invariant that makes the panic unreachable>
//! // relaxed-ok: <why no cross-thread ordering is needed>
//! // block-ok: <why blocking under this guard cannot stall peers>
//! ```
//!
//! An annotation suppresses findings of its kind on its own line and on
//! the line directly below it (so it can sit on the site's line or on a
//! comment line of its own).  When a standalone annotation comment is
//! followed by further whole-line comments, the block extends: the
//! annotation covers the first code line after the comment block, so a
//! justification too long for one line still reaches its site.  A bare
//! `// panic-ok` with no reason does not count: the reason *is* the
//! review artifact.

/// One lexical token.  Numbers keep their text only for debugging; the
/// analyses never interpret them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Str(String),
    Num(String),
    /// Lifetime (`'a`) — distinct from `Ch` so `'a` never opens a
    /// phantom char literal that would swallow the rest of the file.
    Life,
    /// Char or byte literal (contents never matter to the analyses).
    Ch,
    Punct(char),
}

#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

impl Token {
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }
}

/// Which finding kind a comment annotation suppresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnnKind {
    PanicOk,
    RelaxedOk,
    BlockOk,
}

#[derive(Debug, Clone)]
pub struct Annotation {
    pub kind: AnnKind,
    pub line: u32,
    /// Comment sat on its own line (no code before it); only these
    /// extend through a following comment block.
    standalone: bool,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub annotations: Vec<Annotation>,
}

impl Lexed {
    /// True when an annotation of `kind` covers `line` (the annotation
    /// sits on the line itself or on the line directly above).
    pub fn suppressed(&self, kind: AnnKind, line: u32) -> bool {
        self.annotations
            .iter()
            .any(|a| a.kind == kind && (a.line == line || a.line + 1 == line))
    }
}

fn ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Parse a `//` comment body into an annotation, if it is one.
fn annotation_of(body: &str) -> Option<AnnKind> {
    let t = body.trim_start_matches(['/', '!']).trim();
    for (prefix, kind) in [
        ("panic-ok:", AnnKind::PanicOk),
        ("relaxed-ok:", AnnKind::RelaxedOk),
        ("block-ok:", AnnKind::BlockOk),
    ] {
        if let Some(reason) = t.strip_prefix(prefix) {
            if !reason.trim().is_empty() {
                return Some(kind);
            }
        }
    }
    None
}

pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (and annotation capture)
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            let body: String = cs[start..j].iter().collect();
            let own_line = out.tokens.last().map_or(true, |t| t.line != line);
            if let Some(kind) = annotation_of(&body) {
                out.annotations.push(Annotation { kind, line, standalone: own_line });
            } else if own_line {
                // a whole-line comment directly below a standalone
                // annotation continues its block: slide the annotation
                // down so it still covers the code line after the block
                if let Some(a) = out.annotations.last_mut() {
                    if a.standalone && a.line + 1 == line {
                        a.line = line;
                    }
                }
            }
            i = j;
            continue;
        }
        // block comment (nested, per the language)
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte string prefixes: r", r#", b", br", br#", b'
        if (c == 'r' || c == 'b') && i + 1 < n {
            let (raw, skip) = match (c, cs[i + 1]) {
                ('r', '"') | ('r', '#') => (true, 1),
                ('b', 'r') if i + 2 < n && (cs[i + 2] == '"' || cs[i + 2] == '#') => (true, 2),
                ('b', '"') => (false, 1),
                ('b', '\'') => {
                    // byte char literal: scan to the closing quote
                    let start_line = line;
                    let mut j = i + 2;
                    while j < n && cs[j] != '\'' {
                        if cs[j] == '\\' {
                            j += 1;
                        }
                        j += 1;
                    }
                    out.tokens.push(Token { tok: Tok::Ch, line: start_line });
                    i = j + 1;
                    continue;
                }
                _ => (false, 0),
            };
            if raw {
                let start_line = line;
                let mut j = i + skip;
                let mut fences = 0usize;
                while j < n && cs[j] == '#' {
                    fences += 1;
                    j += 1;
                }
                // opening quote
                j += 1;
                let mut body = String::new();
                'raw: while j < n {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    if cs[j] == '"' {
                        let mut k = 0usize;
                        while k < fences && j + 1 + k < n && cs[j + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == fences {
                            j += 1 + fences;
                            break 'raw;
                        }
                    }
                    body.push(cs[j]);
                    j += 1;
                }
                out.tokens.push(Token { tok: Tok::Str(body), line: start_line });
                i = j;
                continue;
            }
            if skip == 1 {
                // b"..." — fall through to the normal string scan below,
                // starting at the quote
                i += 1;
                // (the `"` branch below handles it)
            }
        }
        if cs[i] == '"' {
            let start_line = line;
            let mut j = i + 1;
            let mut body = String::new();
            while j < n {
                let d = cs[j];
                if d == '\\' && j + 1 < n {
                    if cs[j + 1] == '\n' {
                        line += 1;
                    }
                    body.push(d);
                    body.push(cs[j + 1]);
                    j += 2;
                    continue;
                }
                if d == '"' {
                    j += 1;
                    break;
                }
                if d == '\n' {
                    line += 1;
                }
                body.push(d);
                j += 1;
            }
            out.tokens.push(Token { tok: Tok::Str(body), line: start_line });
            i = j;
            continue;
        }
        if cs[i] == '\'' {
            // lifetime or char literal
            if i + 1 < n && cs[i + 1] == '\\' {
                // escaped char: scan to the closing quote
                let mut j = i + 2;
                while j < n && cs[j] != '\'' {
                    if cs[j] == '\\' {
                        j += 1;
                    }
                    j += 1;
                }
                out.tokens.push(Token { tok: Tok::Ch, line });
                i = j + 1;
                continue;
            }
            if i + 2 < n && cs[i + 2] == '\'' {
                // 'x' — any single char (including an ident char)
                out.tokens.push(Token { tok: Tok::Ch, line });
                i = i + 3;
                continue;
            }
            if i + 1 < n && ident_start(cs[i + 1]) {
                // lifetime: 'a, 'static — no closing quote
                let mut j = i + 1;
                while j < n && ident_cont(cs[j]) {
                    j += 1;
                }
                out.tokens.push(Token { tok: Tok::Life, line });
                i = j;
                continue;
            }
            // stray quote (shouldn't happen in valid code)
            out.tokens.push(Token { tok: Tok::Punct('\''), line });
            i += 1;
            continue;
        }
        if ident_start(cs[i]) {
            let mut j = i + 1;
            while j < n && ident_cont(cs[j]) {
                j += 1;
            }
            let s: String = cs[i..j].iter().collect();
            out.tokens.push(Token { tok: Tok::Ident(s), line });
            i = j;
            continue;
        }
        if cs[i].is_ascii_digit() {
            // loose: suffixes and hex ride along; `.` stays punct so
            // ranges (`0..n`) never get eaten
            let mut j = i + 1;
            while j < n && ident_cont(cs[j]) {
                j += 1;
            }
            let s: String = cs[i..j].iter().collect();
            out.tokens.push(Token { tok: Tok::Num(s), line });
            i = j;
            continue;
        }
        out.tokens.push(Token { tok: Tok::Punct(cs[i]), line });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.ident().map(str::to_string))
            .collect()
    }

    #[test]
    fn comments_strings_and_lifetimes_do_not_leak_tokens() {
        let src = r##"
// a comment with fn and lock() in it
/* block /* nested */ still comment fn */
fn real<'a>(x: &'a str) -> char {
    let _s = "fn fake() { lock() }";
    let _r = r#"also "fake" lock()"#;
    let _c = 'l';
    let _e = '\n';
    'x'
}
"##;
        let ids = idents(src);
        assert_eq!(
            ids,
            vec!["fn", "real", "x", "str", "char", "let", "_s", "let", "_r", "let", "_c", "let",
                 "_e"]
        );
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "/* one\ntwo */\nfn f() {}\n\"a\nb\"\nfn g() {}\n";
        let lx = lex(src);
        let f = lx.tokens.iter().find(|t| t.ident() == Some("f")).unwrap();
        assert_eq!(f.line, 3);
        let g = lx.tokens.iter().find(|t| t.ident() == Some("g")).unwrap();
        assert_eq!(g.line, 6);
    }

    #[test]
    fn annotations_require_a_reason_and_cover_two_lines() {
        let src = "\n// panic-ok: guarded by the check above\nx.unwrap();\n// panic-ok\ny.unwrap();\n// relaxed-ok: id allocation only\n";
        let lx = lex(src);
        assert_eq!(lx.annotations.len(), 2, "bare panic-ok must not count");
        assert!(lx.suppressed(AnnKind::PanicOk, 2));
        assert!(lx.suppressed(AnnKind::PanicOk, 3), "annotation covers the next line");
        assert!(!lx.suppressed(AnnKind::PanicOk, 5), "reasonless annotation suppresses nothing");
        assert!(lx.suppressed(AnnKind::RelaxedOk, 6));
        assert!(!lx.suppressed(AnnKind::RelaxedOk, 3));
    }

    #[test]
    fn annotation_blocks_extend_through_continuation_comments() {
        let src = "\n// panic-ok: the invariant is long enough that the\n// justification wraps onto a second comment line\nx.unwrap();\ny.unwrap();\ncode();\n// not an annotation\nz.unwrap();\n";
        let lx = lex(src);
        assert!(lx.suppressed(AnnKind::PanicOk, 4), "block covers first code line");
        assert!(!lx.suppressed(AnnKind::PanicOk, 5), "coverage stops after one code line");
        assert!(!lx.suppressed(AnnKind::PanicOk, 8), "unrelated comment gains nothing");
        // a trailing annotation (code before it on the line) does not
        // slide down a following comment block away from its own line
        let src2 = "a.unwrap(); // panic-ok: checked right above\n// an ordinary comment\nb.unwrap();\n";
        let lx2 = lex(src2);
        assert!(lx2.suppressed(AnnKind::PanicOk, 1));
        assert!(!lx2.suppressed(AnnKind::PanicOk, 3));
    }

    #[test]
    fn raw_and_byte_strings_scan_cleanly() {
        let src = r###"let a = br#"x "quoted" y"#; let b = b"bytes"; let c = b'q';"###;
        let lx = lex(src);
        let strs: Vec<&Tok> =
            lx.tokens.iter().filter(|t| matches!(t.tok, Tok::Str(_))).map(|t| &t.tok).collect();
        assert_eq!(strs.len(), 2);
        assert!(lx.tokens.iter().any(|t| t.tok == Tok::Ch));
    }
}
