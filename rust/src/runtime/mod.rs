//! PJRT runtime: loads the AOT HLO-text artifacts, keeps weights
//! device-resident, and executes inference/calibration on the hot path —
//! no Python anywhere.
//!
//! `Runtime` is intentionally single-threaded (`PjRtClient` is `Rc`-based):
//! CLI commands use it directly on the main thread; the serving coordinator
//! wraps it in a dedicated engine thread (`engine.rs`) and talks to it over
//! channels, the same shape as a GPU-executor thread in a production
//! server.

pub mod engine;

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::manifest::Manifest;
use crate::model::tensor::{DType, Tensor};
use crate::model::Container;

/// Host copy of an executable's output tuple.
pub struct Outputs {
    pub tensors: Vec<Tensor>,
}

/// A compiled artifact plus load/compile timings (reported by `repro info`).
pub struct Exe {
    pub exe: xla::PjRtLoadedExecutable,
    pub path: String,
    pub load_ms: f64,
    pub compile_ms: f64,
}

/// Device-resident checkpoint: one buffer per parameter, in manifest order.
pub struct DeviceCheckpoint {
    pub bufs: Vec<xla::PjRtBuffer>,
    pub nbytes: usize,
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    /// (mode, bucket) -> compiled model executable.
    exes: HashMap<(String, usize), Exe>,
    /// misc executables (calibration artifact, micro benches) by path.
    raw_exes: HashMap<String, Exe>,
    /// (task, mode) -> device-resident weights.
    ckpts: HashMap<(String, String), DeviceCheckpoint>,
}

#[allow(dead_code)]
fn elem_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I8 => xla::ElementType::S8,
        DType::I32 => xla::ElementType::S32,
    }
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: HashMap::new(),
            raw_exes: HashMap::new(),
            ckpts: HashMap::new(),
        })
    }

    // ---------------------------------------------------------------- load

    pub fn compile_hlo_file(client: &xla::PjRtClient, path: &Path) -> Result<Exe> {
        let t0 = Instant::now();
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow::anyhow!("loading {path:?}: {e}"))?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t1 = Instant::now();
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e}"))?;
        Ok(Exe {
            exe,
            path: path.display().to_string(),
            load_ms,
            compile_ms: t1.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Compile (and cache) the model executable for (mode, bucket).
    pub fn model_exe(&mut self, mode: &str, bucket: usize) -> Result<&Exe> {
        let key = (mode.to_string(), bucket);
        if !self.exes.contains_key(&key) {
            let spec = self.manifest.mode(mode)?;
            let rel = spec
                .artifacts
                .get(&bucket)
                .with_context(|| format!("mode {mode} has no bucket {bucket}"))?;
            let exe = Self::compile_hlo_file(&self.client, &self.manifest.path(rel))?;
            self.exes.insert(key.clone(), exe);
        }
        Ok(&self.exes[&key])
    }

    /// Compile (and cache) an arbitrary artifact by manifest-relative path.
    pub fn raw_exe(&mut self, rel: &str) -> Result<&Exe> {
        if !self.raw_exes.contains_key(rel) {
            let exe = Self::compile_hlo_file(&self.client, &self.manifest.path(rel))?;
            self.raw_exes.insert(rel.to_string(), exe);
        }
        Ok(&self.raw_exes[rel])
    }

    // ------------------------------------------------------------- weights

    pub fn upload_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        // NOTE: the typed `buffer_from_host_buffer::<T>` is used on purpose:
        // the crate's `buffer_from_host_raw_bytes` forwards the rust
        // `ElementType` discriminant straight to the C API, which is offset
        // from XLA's `PrimitiveType` (F32 silently becomes F16).  The typed
        // path converts via `T::TY.primitive_type()` and is correct.
        let buf = match &t.data {
            crate::model::TensorData::F32(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
            crate::model::TensorData::I8(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
            crate::model::TensorData::I32(v) => {
                self.client.buffer_from_host_buffer(v, &t.shape, None)
            }
        };
        buf.map_err(|e| anyhow::anyhow!("upload: {e}"))
    }

    /// Upload a checkpoint once; later executions reference the resident
    /// buffers (the per-request upload is only ids+mask — DESIGN.md §5.1).
    pub fn upload_checkpoint(&mut self, task: &str, mode: &str, ckpt: &Container) -> Result<()> {
        let mut bufs = Vec::with_capacity(ckpt.len());
        let mut nbytes = 0;
        for (_, t) in &ckpt.entries {
            bufs.push(self.upload_tensor(t)?);
            nbytes += t.nbytes();
        }
        self.ckpts
            .insert((task.to_string(), mode.to_string()), DeviceCheckpoint { bufs, nbytes });
        Ok(())
    }

    pub fn has_checkpoint(&self, task: &str, mode: &str) -> bool {
        self.ckpts.contains_key(&(task.to_string(), mode.to_string()))
    }

    pub fn checkpoint_nbytes(&self, task: &str, mode: &str) -> Option<usize> {
        self.ckpts.get(&(task.to_string(), mode.to_string())).map(|c| c.nbytes)
    }

    // ------------------------------------------------------------- execute

    fn read_outputs(results: Vec<Vec<xla::PjRtBuffer>>) -> Result<Outputs> {
        let buf = &results
            .first()
            .context("no replica outputs")?
            .first()
            .context("no outputs")?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        // artifacts are lowered with return_tuple=True
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e}"))?;
        let mut tensors = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p.array_shape().map_err(|e| anyhow::anyhow!("shape: {e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
            let t = match shape.ty() {
                xla::ElementType::F32 => {
                    Tensor::f32(dims, p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                xla::ElementType::S8 => {
                    Tensor::i8(dims, p.to_vec::<i8>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                xla::ElementType::S32 => {
                    Tensor::i32(dims, p.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?)
                }
                other => bail!("unsupported output element type {other:?}"),
            };
            tensors.push(t);
        }
        Ok(Outputs { tensors })
    }

    /// Run a model executable with resident weights + fresh input buffers.
    /// `ids`/`type_ids` are `[bucket * seq]` i32, `mask` `[bucket * seq]` f32.
    pub fn infer(
        &mut self,
        task: &str,
        mode: &str,
        bucket: usize,
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Tensor> {
        let seq = self.manifest.seq;
        if ids.len() != bucket * seq {
            bail!("ids len {} != bucket {bucket} * seq {seq}", ids.len());
        }
        self.model_exe(mode, bucket)?; // ensure compiled before borrowing ckpt
        let ckpt = self
            .ckpts
            .get(&(task.to_string(), mode.to_string()))
            .with_context(|| format!("checkpoint ({task},{mode}) not uploaded"))?;

        let up = |e: xla::Error| anyhow::anyhow!("{e}");
        let ids_b = self.client.buffer_from_host_buffer(ids, &[bucket, seq], None).map_err(up)?;
        let ty_b =
            self.client.buffer_from_host_buffer(type_ids, &[bucket, seq], None).map_err(up)?;
        let mask_b =
            self.client.buffer_from_host_buffer(mask, &[bucket, seq], None).map_err(up)?;

        let mut args: Vec<&xla::PjRtBuffer> = ckpt.bufs.iter().collect();
        args.push(&ids_b);
        args.push(&ty_b);
        args.push(&mask_b);

        let exe = &self.exes[&(mode.to_string(), bucket)];
        let out = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let mut outputs = Self::read_outputs(out)?;
        if outputs.tensors.len() != 1 {
            bail!("model artifact returned {} outputs, expected 1", outputs.tensors.len());
        }
        Ok(outputs.tensors.remove(0))
    }

    /// Run the calibration-instrumented artifact for one batch; returns
    /// (logits, stats in manifest order).
    pub fn calibrate_batch(
        &mut self,
        fp_bufs: &[xla::PjRtBuffer],
        ids: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Outputs> {
        let seq = self.manifest.seq;
        let batch = self.manifest.calib.batch;
        if ids.len() != batch * seq {
            bail!("calibration batch must be exactly {batch} x {seq}");
        }
        let rel = self.manifest.calib.artifact.clone();
        self.raw_exe(&rel)?;

        let up = |e: xla::Error| anyhow::anyhow!("{e}");
        let ids_b = self.client.buffer_from_host_buffer(ids, &[batch, seq], None).map_err(up)?;
        let ty_b =
            self.client.buffer_from_host_buffer(type_ids, &[batch, seq], None).map_err(up)?;
        let mask_b =
            self.client.buffer_from_host_buffer(mask, &[batch, seq], None).map_err(up)?;

        let mut args: Vec<&xla::PjRtBuffer> = fp_bufs.iter().collect();
        args.push(&ids_b);
        args.push(&ty_b);
        args.push(&mask_b);

        let exe = &self.raw_exes[&rel];
        let out = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    /// Upload raw tensors (calibration fp params / micro benches).
    pub fn upload_all(&self, tensors: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        tensors.iter().map(|t| self.upload_tensor(t)).collect()
    }

    /// Execute an arbitrary artifact with host tensors (micro benches).
    pub fn run_raw(&mut self, rel: &str, inputs: &[Tensor]) -> Result<Outputs> {
        self.raw_exe(rel)?;
        let bufs = self.upload_all(inputs)?;
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let exe = &self.raw_exes[rel];
        let out = exe.exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    /// Execute an arbitrary artifact with pre-uploaded buffers (hot loop).
    pub fn run_raw_buffers(&mut self, rel: &str, args: &[&xla::PjRtBuffer]) -> Result<Outputs> {
        self.raw_exe(rel)?;
        let exe = &self.raw_exes[rel];
        let out = exe.exe.execute_b(args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        Self::read_outputs(out)
    }

    pub fn loaded_exe_count(&self) -> usize {
        self.exes.len() + self.raw_exes.len()
    }
}
