"""SynGLUE — a synthetic, seeded 8-task suite mirroring the GLUE benchmark
used in the paper's Table 2 (see DESIGN.md §2 for the substitution
argument: PTQ behaviour is a property of the trained model + quantized
graph, not of natural language; SynGLUE preserves the task *types*, label
spaces, metrics, class balances and relative difficulty).

Tasks (paper column -> SynGLUE analogue):
  CoLA   -> cola-syn   single sentence, acceptability grammar, Mcc.
                       Deliberately *hard*: negatives are minimal (single
                       edit) corruptions, concentrating dev examples near
                       the decision boundary like CoLA.
  MNLI   -> mnli-syn   premise/hypothesis 3-way entailment; matched and
                       mismatched dev splits (mm = longer + noisier).
  MRPC   -> mrpc-syn   paraphrase detection, ~68%% positive, F1/Acc.
  QNLI   -> qnli-syn   question/passage entailment, Acc.
  QQP    -> qqp-syn    paraphrase, ~37%% positive, F1/Acc.
  RTE    -> rte-syn    binary entailment, small train set, Acc.
  SST-2  -> sst2-syn   single-sentence sentiment, Acc.
  STS-B  -> stsb-syn   similarity regression in [0,5], Pearson/Spearman.

Vocabulary layout (vocab = 2048):
  0 PAD, 1 CLS, 2 SEP, 3 UNK; content tokens 4..2047.
  "synonym/antonym" partner of t is ``t ^ 1`` (adjacent pairing).
  token classes by residue: verbs  t % 16 == 0, nouns t % 16 == 1;
  sentiment: positive 4..703, negative 704..1403, neutral 1404..2047.
"""

import numpy as np

PAD, CLS, SEP, UNK = 0, 1, 2, 3
CONTENT_LO, CONTENT_HI = 4, 2048  # [lo, hi)
POS_RANGE = (4, 704)
NEG_RANGE = (704, 1404)
NEU_RANGE = (1404, 2048)

TASKS = ("cola", "mnli", "mrpc", "qnli", "qqp", "rte", "sst2", "stsb")

# Closed token pools for the tasks that require exact token-identity
# matching across segments (entailment/QA/similarity): a tiny model trained
# for a few epochs can only learn identity-matching for tokens it has seen
# many times, so these tasks draw content from small dedicated pools
# (mirroring the closed-class trick real GLUE models get from a pretrained
# vocabulary).
# MNLI/RTE: 32 "entity" topics on even ids so antonym(T) = T+1; premise and
# hypothesis carry exactly one marker each — the relation (same / antonym /
# different) decides the label.  Single-marker matching over a 32-token
# closed class is learnable by a tiny model in a few epochs, while keeping
# the task *type* (cross-segment lexical entailment).
ENTITY_TOPICS = [1408 + 2 * k for k in range(32)]  # 1408..1470 even
ENTITY_FILLER = (1472, 1664)
KEY_POOL = (1664, 1696)    # QNLI question keys (32 tokens)
VAL_POOL = (1728, 1792)    # QNLI passage values (filler)
SIM_POOL = (1792, 1856)    # STS-B content (64 tokens)

# task -> (n_classes (0 = regression), metric spec, dev splits)
TASK_META = {
    "cola": {"classes": 2, "metrics": ["mcc"], "splits": ["dev"]},
    "mnli": {"classes": 3, "metrics": ["acc"], "splits": ["dev", "dev_mm"]},
    "mrpc": {"classes": 2, "metrics": ["f1", "acc"], "splits": ["dev"]},
    "qnli": {"classes": 2, "metrics": ["acc"], "splits": ["dev"]},
    "qqp": {"classes": 2, "metrics": ["f1", "acc"], "splits": ["dev"]},
    "rte": {"classes": 2, "metrics": ["acc"], "splits": ["dev"]},
    "sst2": {"classes": 2, "metrics": ["acc"], "splits": ["dev"]},
    "stsb": {"classes": 0, "metrics": ["pearson", "spearman"], "splits": ["dev"]},
}

SIZES = {  # train, dev (mnli dev is per split)
    "cola": (3000, 500), "mnli": (10000, 1000), "mrpc": (3000, 400),
    "qnli": (6000, 600), "qqp": (10000, 800), "rte": (1500, 300),
    "sst2": (6000, 600), "stsb": (3000, 400),
}

FAST_SIZES = {t: (max(256, a // 10), max(128, b // 4)) for t, (a, b) in SIZES.items()}


def partner(t):
    return int(t) ^ 1


def _sample_content(r, n, lo=CONTENT_LO, hi=CONTENT_HI):
    return r.integers(lo, hi, size=n).tolist()


def _encode_single(toks, seq_len):
    ids = [CLS] + list(toks)[: seq_len - 2] + [SEP]
    ty = [0] * len(ids)
    return _pad(ids, ty, seq_len)


def _encode_pair(a, b, seq_len):
    budget = seq_len - 3
    a = list(a)[: budget // 2]
    b = list(b)[: budget - len(a)]
    ids = [CLS] + a + [SEP] + b + [SEP]
    ty = [0] * (len(a) + 2) + [1] * (len(b) + 1)
    return _pad(ids, ty, seq_len)


def _pad(ids, ty, seq_len):
    n = len(ids)
    assert n <= seq_len, (n, seq_len)
    return ids + [PAD] * (seq_len - n), ty + [0] * (seq_len - n)


# --------------------------------------------------------------------------
# per-task generators: each returns (ids, type_ids, label) lists
# --------------------------------------------------------------------------


def gen_sst2(r, seq_len):
    n = int(r.integers(8, 24))
    k = int(r.integers(2, 7))
    label = int(r.integers(0, 2))
    lo, hi = (POS_RANGE if label else NEG_RANGE)
    toks = _sample_content(r, n - k, *NEU_RANGE) + _sample_content(r, k, lo, hi)
    r.shuffle(toks)
    ids, ty = _encode_single(toks, seq_len)
    return ids, ty, label


# Small closed classes: 16 verbs, 16 nouns.  Class membership is easy to
# learn; the *rule* (order + uniqueness) is what makes the task hard, which
# concentrates dev examples near the decision boundary — the CoLA analogue.
VERB_TOKENS = [16 * (k + 1) for k in range(16)]           # 16..256 step 16
NOUN_TOKENS = [16 * (k + 1) + 1 for k in range(16)]


def _cola_filler(r, n):
    toks = []
    for t in _sample_content(r, n):
        t = int(t)
        if t % 16 in (0, 1):
            t += 2  # strip accidental verbs/nouns
        toks.append(t)
    return toks


def _acceptable_sentence(r):
    """Exactly one verb, with at least one noun *before* it."""
    toks = _cola_filler(r, int(r.integers(6, 16)))
    noun = NOUN_TOKENS[int(r.integers(0, 16))]
    verb = VERB_TOKENS[int(r.integers(0, 16))]
    ni = int(r.integers(0, len(toks)))
    toks.insert(ni, noun)
    vi = int(r.integers(ni + 1, len(toks) + 1))
    toks.insert(vi, verb)
    return toks, ni, vi


def gen_cola(r, seq_len):
    toks, ni, vi = _acceptable_sentence(r)
    label = 1
    if r.random() < 0.5:
        label = 0
        mode = int(r.integers(0, 3))
        if mode == 0:      # move verb before the noun
            v = toks.pop(vi)
            toks.insert(int(r.integers(0, ni + 1)), v)
        elif mode == 1:    # duplicate the verb (two verbs = unacceptable)
            toks.insert(int(r.integers(0, len(toks))),
                        VERB_TOKENS[int(r.integers(0, 16))])
        else:              # delete the noun
            toks.pop(ni)
    ids, ty = _encode_single(toks, seq_len)
    return ids, ty, label


def gen_mnli(r, seq_len, mismatched=False):
    plen = int(r.integers(6, 13)) + (4 if mismatched else 0)
    prem = _sample_content(r, plen, *ENTITY_FILLER)
    topic = ENTITY_TOPICS[int(r.integers(0, 32))]
    prem.insert(int(r.integers(0, len(prem))), topic)
    label = int(r.integers(0, 3))  # 0 entail, 1 neutral, 2 contradict
    hyp = _sample_content(r, int(r.integers(2, 6)) + (2 if mismatched else 0),
                          *ENTITY_FILLER)
    if label == 0:
        marker = topic                 # same entity asserted -> entail
    elif label == 2:
        marker = partner(topic)        # antonym entity -> contradict
    else:
        other = topic
        while other == topic:
            other = ENTITY_TOPICS[int(r.integers(0, 32))]
        marker = other                 # unrelated entity -> neutral
    hyp.insert(int(r.integers(0, len(hyp))), marker)
    ids, ty = _encode_pair(prem, hyp, seq_len)
    return ids, ty, label


def _paraphrase_pair(r, pos_rate):
    s1 = _sample_content(r, int(r.integers(6, 14)))
    if r.random() < pos_rate:
        s2 = [partner(t) if r.random() < 0.3 else int(t) for t in s1]
        r.shuffle(s2)
        return s1, s2, 1
    keep = max(1, int(0.4 * len(s1)))
    idx = r.choice(len(s1), size=keep, replace=False)
    s2 = [s1[j] for j in idx] + _sample_content(r, int(r.integers(4, 10)))
    r.shuffle(s2)
    return s1, s2, 0


def gen_mrpc(r, seq_len):
    s1, s2, label = _paraphrase_pair(r, 0.68)
    ids, ty = _encode_pair(s1, s2, seq_len)
    return ids, ty, label


def gen_qqp(r, seq_len):
    s1, s2, label = _paraphrase_pair(r, 0.37)
    ids, ty = _encode_pair(s1, s2, seq_len)
    return ids, ty, label


def gen_qnli(r, seq_len):
    npairs = int(r.integers(3, 7))
    keys = list({int(t) for t in _sample_content(r, npairs, *KEY_POOL)})
    vals = _sample_content(r, len(keys), *VAL_POOL)
    passage = []
    for k_, v_ in zip(keys, vals):
        passage += [int(k_), int(v_)]
    label = int(r.integers(0, 2))
    if label:
        key = keys[int(r.integers(0, len(keys)))]
    else:
        key = keys[0]
        while key in keys:
            key = int(_sample_content(r, 1, *KEY_POOL)[0])
    question = [UNK, key]  # UNK doubles as the question marker
    ids, ty = _encode_pair(question, passage, seq_len)
    return ids, ty, label


def gen_rte(r, seq_len):
    # binary entailment over the same entity-marker design as mnli-syn,
    # with antonym negatives (high lexical overlap, like RTE)
    plen = int(r.integers(6, 13))
    prem = _sample_content(r, plen, *ENTITY_FILLER)
    topic = ENTITY_TOPICS[int(r.integers(0, 32))]
    prem.insert(int(r.integers(0, len(prem))), topic)
    label = int(r.integers(0, 2))  # 1 = entail
    hyp = _sample_content(r, int(r.integers(2, 6)), *ENTITY_FILLER)
    if label:
        marker = topic
    elif r.random() < 0.5:
        marker = partner(topic)
    else:
        marker = topic
        while marker == topic:
            marker = ENTITY_TOPICS[int(r.integers(0, 32))]
    hyp.insert(int(r.integers(0, len(hyp))), marker)
    ids, ty = _encode_pair(prem, hyp, seq_len)
    return ids, ty, label


def gen_stsb(r, seq_len):
    n = 8
    s1 = _sample_content(r, n, *SIM_POOL)
    k = int(r.integers(0, n + 1))
    idx = set(r.choice(n, size=k, replace=False).tolist())
    s2 = [s1[j] if j in idx else int(_sample_content(r, 1, *SIM_POOL)[0]) for j in range(n)]
    r.shuffle(s2)
    score = float(np.clip(5.0 * k / n + r.normal(0, 0.25), 0.0, 5.0))
    ids, ty = _encode_pair(s1, s2, seq_len)
    return ids, ty, score


GENERATORS = {
    "cola": gen_cola, "mnli": gen_mnli, "mrpc": gen_mrpc, "qnli": gen_qnli,
    "qqp": gen_qqp, "rte": gen_rte, "sst2": gen_sst2, "stsb": gen_stsb,
}


def make_split(task, n, seq_len, seed, mismatched=False):
    """Returns dict: input_ids i32 [n,s], type_ids i32 [n,s], labels."""
    r = np.random.default_rng(seed)
    gen = GENERATORS[task]
    ids, tys, labels = [], [], []
    for _ in range(n):
        if task == "mnli":
            i, t, l = gen(r, seq_len, mismatched=mismatched)
        else:
            i, t, l = gen(r, seq_len)
        ids.append(i)
        tys.append(t)
        labels.append(l)
    out = {
        "input_ids": np.asarray(ids, np.int32),
        "type_ids": np.asarray(tys, np.int32),
    }
    if TASK_META[task]["classes"] == 0:
        out["labels_f32"] = np.asarray(labels, np.float32)
    else:
        out["labels_i32"] = np.asarray(labels, np.int32)
    return out


def make_task(task, seq_len=128, fast=False, seed_base=1234):
    """Returns dict split_name -> split dict."""
    import zlib

    ntr, ndev = (FAST_SIZES if fast else SIZES)[task]
    seed = seed_base + zlib.crc32(task.encode()) % 100000  # stable across runs
    splits = {
        "train": make_split(task, ntr, seq_len, seed),
        "dev": make_split(task, ndev, seq_len, seed + 1),
    }
    if task == "mnli":
        splits["dev_mm"] = make_split(task, ndev, seq_len, seed + 2, mismatched=True)
    return splits


def attn_mask(input_ids):
    return (input_ids != PAD).astype(np.float32)
