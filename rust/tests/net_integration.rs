//! TCP front-end integration: JSON requests over a real socket through the
//! full serving stack.  Gated on `make artifacts`.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::{artifacts, ensure_quantized};
use zqhero::coordinator::{Coordinator, NetClient, NetServer, RequestSpec, ServerConfig};
use zqhero::data::Split;
use zqhero::json::Value;
use zqhero::model::manifest::{Manifest, PolicyDraft};
use zqhero::runtime::FaultPlan;

#[test]
fn tcp_round_trip_and_errors() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Arc::new(
        Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig { max_batch: 4, max_wait: Duration::from_millis(2), ..Default::default() },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();
    let mut client = NetClient::connect(&server.addr).unwrap();

    let man = Manifest::load(&dir).unwrap();
    let task = man.task("cola").unwrap();
    let split = Split::load(&man, task, "dev").unwrap();

    // several requests pipeline through the batcher
    for i in 0..6 {
        let (ids, _) = split.row(i);
        let short: Vec<i32> = ids.iter().copied().take_while(|t| *t != 0).collect();
        let resp = client.request("cola", "fp", &short).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        let logits = resp.get("logits").unwrap().as_array().unwrap();
        assert_eq!(logits.len(), man.model.num_labels);
        assert!(logits.iter().all(|v| v.as_f64().unwrap().is_finite()));
        assert!(resp.get("bucket").unwrap().as_usize().unwrap() >= 1);
    }

    // unknown task -> structured error, connection stays usable
    let resp = client.request("nope", "fp", &[1, 2, 3]).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("checkpoint"));

    // malformed json line -> error response, not a dropped connection
    {
        use std::io::{BufRead, Write};
        let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
        raw.write_all(b"this is not json\n").unwrap();
        raw.flush().unwrap();
        let mut line = String::new();
        std::io::BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
        let v = zqhero::json::parse(line.trim()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("bad json"));
    }

    // still healthy after the bad client
    let (ids, _) = split.row(0);
    let resp = client.request("cola", "fp", &ids[..10]).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert!(server.served.load(std::sync::atomic::Ordering::SeqCst) >= 8);
}

/// Acceptance: a per-module-override policy submitted through NetClient
/// executes end to end (admission -> PolicyId grouping -> engine
/// executable selection), and v1 string-mode requests still round-trip
/// through the compatibility shim on the same connection.
#[test]
fn v2_policy_round_trip_and_v1_shim() {
    let Some(dir) = artifacts() else { return };
    ensure_quantized(&dir, "cola", "m1");
    // routes: the fp reference plus m1 — the executable mode the
    // attn-output-fp policy escalates to
    let pairs = vec![("cola".to_string(), "fp".to_string()), ("cola".to_string(), "m1".to_string())];
    let coord = Arc::new(
        Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig { max_batch: 4, max_wait: Duration::from_millis(2), ..Default::default() },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();
    let mut client = NetClient::connect(&server.addr).unwrap();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let (ids, _) = split.row(0);

    // inline per-module-override policy: m3 minus attn_output matches no
    // artifact, the chain escalates to m1.  The interned name depends on
    // whether the manifest ships an identical named policy — compute it.
    let draft = PolicyDraft::base("m3")
        .with_override("attn_output", "fp")
        .with_fallback("m2")
        .with_fallback("m1")
        .with_fallback("fp");
    let interned = man.intern_inline_policy(&draft).unwrap();
    let interned_name = man.policy_name(interned).to_string();
    assert_eq!(man.policy_by_id(interned).exec_mode, man.mode_id("m1").unwrap());
    for _ in 0..6 {
        let spec = RequestSpec::task("cola").policy_inline(draft.clone()).ids(ids.to_vec());
        let resp = client.request_spec(&spec).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get("v").unwrap().as_usize(), Some(2));
        assert_eq!(resp.get("mode").unwrap().as_str(), Some("m1"), "{resp:?}");
        assert_eq!(resp.get("policy").unwrap().as_str(), Some(interned_name.as_str()));
        let logits = resp.get("logits").unwrap().as_array().unwrap();
        assert_eq!(logits.len(), man.model.num_labels);
        assert!(logits.iter().all(|v| v.as_f64().unwrap().is_finite()));
    }

    // named uniform policy over v2
    let resp = client
        .request_spec(&RequestSpec::task("cola").policy("fp").ids(ids.to_vec()))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    assert_eq!(resp.get("policy").unwrap().as_str(), Some("fp"));
    assert_eq!(resp.get("mode").unwrap().as_str(), Some("fp"));

    // v1 shim on the same connection: v1-shaped response (no "v" key)
    let resp = client.request("cola", "fp", &ids[..8]).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    assert!(resp.get("v").is_none());
    assert!(resp.get("logits").unwrap().as_array().unwrap().len() == man.model.num_labels);

    // per-policy stats landed on the interned policy's slot (PolicyId
    // grouping through the batcher)
    let snap = coord.recorder.snapshot();
    assert!(
        snap[&interned_name].requests >= 6,
        "{interned_name} stats: {:?}",
        snap[&interned_name].requests
    );
    assert!(snap["fp"].requests >= 2);

    // unresolvable inline policy -> structured error, connection survives
    let bad = PolicyDraft::base("m3").with_override("attn", "fp");
    let resp = client
        .request_spec(&RequestSpec::task("cola").policy_inline(bad).ids(ids.to_vec()))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("no mode artifact"));
}

/// Regression: a frame that arrives in two halves more than 200 ms apart
/// (the connection handler's read timeout) must still be served — the old
/// loop cleared its line buffer on every iteration, discarding the bytes
/// `read_line` had already buffered when the timeout fired mid-frame.
#[test]
fn slow_writer_frame_split_across_read_timeout() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Arc::new(
        Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig { max_batch: 4, max_wait: Duration::from_millis(2), ..Default::default() },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let (ids, _) = split.row(0);
    let ids_json: Vec<String> = ids.iter().take(8).map(|x| x.to_string()).collect();
    let frame = format!("{{\"task\":\"cola\",\"mode\":\"fp\",\"ids\":[{}]}}\n", ids_json.join(","));

    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
    let (head, tail) = frame.split_at(frame.len() / 2);
    raw.write_all(head.as_bytes()).unwrap();
    raw.flush().unwrap();
    // straddle the 200 ms read timeout more than twice
    std::thread::sleep(Duration::from_millis(600));
    raw.write_all(tail.as_bytes()).unwrap();
    raw.flush().unwrap();

    let mut line = String::new();
    BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
    let v = zqhero::json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
    assert_eq!(
        v.get("logits").unwrap().as_array().unwrap().len(),
        man.model.num_labels
    );
}

/// Satellite for DESIGN.md §5.8: the read timeout is a `ServerConfig`
/// knob, not a constant — and a client slower than the configured
/// timeout but within its request deadline still completes (the partial
/// frame survives every timeout window).
#[test]
fn slow_client_within_deadline_completes_with_configured_timeout() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Arc::new(
        Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                // much shorter than the default 200 ms — the writer below
                // straddles it several times over
                net_read_timeout: Duration::from_millis(40),
                default_deadline: Some(Duration::from_secs(10)),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let (ids, _) = split.row(0);
    let ids_json: Vec<String> = ids.iter().take(8).map(|x| x.to_string()).collect();
    let frame = format!(
        "{{\"v\":2,\"task\":\"cola\",\"policy\":\"fp\",\"deadline_ms\":10000,\"ids\":[{}]}}\n",
        ids_json.join(",")
    );

    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
    let (head, tail) = frame.split_at(frame.len() / 2);
    raw.write_all(head.as_bytes()).unwrap();
    raw.flush().unwrap();
    // ~4 configured timeout windows pass mid-frame; the deadline clock
    // only starts at admission, so the request still completes
    std::thread::sleep(Duration::from_millis(170));
    raw.write_all(tail.as_bytes()).unwrap();
    raw.flush().unwrap();

    let mut line = String::new();
    BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
    let v = zqhero::json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");
    assert!(v.get("expired").is_none(), "{v:?}");
}

/// Backpressure on the wire: with the backlog bound at 1 and a slow
/// engine, a second connection's request answers `busy` (a retryable
/// signal distinct from a terminal error), and a retry after the first
/// request drains succeeds.
#[test]
fn queue_full_maps_to_busy_response() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord = Arc::new(
        Coordinator::start(
            dir.clone(),
            &pairs,
            ServerConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                queue_cap: 1,
                fault_plan: FaultPlan::throttle(Duration::from_millis(250)),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();

    let man = Manifest::load(&dir).unwrap();
    let split = Split::load(&man, man.task("cola").unwrap(), "dev").unwrap();
    let (ids, _) = split.row(0);
    let payload: Vec<i32> = ids.iter().copied().take(8).collect();

    // connection A occupies the single backlog slot for ~250 ms
    let addr = server.addr;
    let a_payload = payload.clone();
    let a = std::thread::spawn(move || {
        let mut client = NetClient::connect(&addr).unwrap();
        client.request("cola", "fp", &a_payload).unwrap()
    });
    std::thread::sleep(Duration::from_millis(60));

    // connection B: shed with a busy response while A is in flight
    let mut client = NetClient::connect(&server.addr).unwrap();
    let resp = client
        .request_spec(&RequestSpec::task("cola").policy("fp").ids(payload.clone()))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
    assert_eq!(resp.get("busy").unwrap().as_bool(), Some(true), "{resp:?}");
    assert_eq!(resp.get("v").unwrap().as_usize(), Some(2), "{resp:?}");
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("busy"));

    let a_resp = a.join().expect("connection A");
    assert_eq!(a_resp.get("ok").unwrap().as_bool(), Some(true), "{a_resp:?}");

    // after A drains, a retry on the same connection succeeds
    let mut ok = false;
    for _ in 0..200 {
        let resp = client
            .request_spec(&RequestSpec::task("cola").policy("fp").ids(payload.clone()))
            .unwrap();
        if resp.get("ok").unwrap().as_bool() == Some(true) {
            ok = true;
            break;
        }
        assert_eq!(resp.get("busy").and_then(|b| b.as_bool()), Some(true), "{resp:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(ok, "retry after drain never succeeded");
}

#[test]
fn oversized_request_rejected() {
    let Some(dir) = artifacts() else { return };
    let pairs = vec![("cola".to_string(), "fp".to_string())];
    let coord =
        Arc::new(Coordinator::start(dir, &pairs, ServerConfig::default()).unwrap());
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1", 0).unwrap();
    let mut client = NetClient::connect(&server.addr).unwrap();
    let huge = vec![1i32; coord.seq() + 1];
    let resp = client.request("cola", "fp", &huge).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    match resp.get("error") {
        Some(Value::String(e)) => assert!(e.contains("too many tokens"), "{e}"),
        other => panic!("expected error, got {other:?}"),
    }
}
