//! SynGLUE dataset loading + batching: reads the container-format splits
//! written by the python build path and produces padded, bucketed batches
//! for the runtime.

use anyhow::{bail, Context, Result};

use crate::model::manifest::{Manifest, TaskSpec};
use crate::model::Container;

pub const PAD: i32 = 0;

#[derive(Debug, Clone)]
pub enum Labels {
    Class(Vec<i32>),
    Score(Vec<f32>),
}

impl Labels {
    pub fn len(&self) -> usize {
        match self {
            Labels::Class(v) => v.len(),
            Labels::Score(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One loaded split: `[n, seq]` row-major token ids.
#[derive(Debug, Clone)]
pub struct Split {
    pub seq: usize,
    pub input_ids: Vec<i32>,
    pub type_ids: Vec<i32>,
    pub labels: Labels,
}

impl Split {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn row(&self, i: usize) -> (&[i32], &[i32]) {
        let s = self.seq;
        (&self.input_ids[i * s..(i + 1) * s], &self.type_ids[i * s..(i + 1) * s])
    }

    pub fn from_container(c: &Container) -> Result<Split> {
        let ids = c.get("input_ids").context("missing input_ids")?;
        let ty = c.get("type_ids").context("missing type_ids")?;
        if ids.shape.len() != 2 || ty.shape != ids.shape {
            bail!("bad split shapes: {:?} vs {:?}", ids.shape, ty.shape);
        }
        let n = ids.shape[0];
        let labels = if let Some(l) = c.get("labels_i32") {
            Labels::Class(l.as_i32()?.to_vec())
        } else if let Some(l) = c.get("labels_f32") {
            Labels::Score(l.as_f32()?.to_vec())
        } else {
            bail!("split has no labels tensor");
        };
        if labels.len() != n {
            bail!("labels len {} != examples {}", labels.len(), n);
        }
        Ok(Split {
            seq: ids.shape[1],
            input_ids: ids.as_i32()?.to_vec(),
            type_ids: ty.as_i32()?.to_vec(),
            labels,
        })
    }

    pub fn load(man: &Manifest, task: &TaskSpec, split: &str) -> Result<Split> {
        let rel = task
            .splits
            .get(split)
            .with_context(|| format!("task {} has no split {split}", task.name))?;
        let c = Container::read_file(&man.path(rel))?;
        let s = Split::from_container(&c)?;
        if s.seq != man.seq {
            bail!("split seq {} != manifest seq {}", s.seq, man.seq);
        }
        Ok(s)
    }

    /// Attention mask derived from PAD tokens.
    pub fn mask_row(ids: &[i32]) -> Vec<f32> {
        ids.iter().map(|t| if *t == PAD { 0.0 } else { 1.0 }).collect()
    }
}

/// Strip one row's trailing PAD tokens (split containers store rows at
/// the model max): the surviving prefix is the request's *real* length,
/// which length-aware admission buckets on (DESIGN.md §5.9).  Always
/// keeps at least one token; `type_ids` is cut to the same prefix (or
/// left whole if already shorter).  The one definition shared by the
/// serve-bench smoke, the e2e bench sweep, and the integration tests —
/// PAD semantics must not drift between them.
pub fn trim_pad_tail(ids: &[i32], type_ids: &[i32]) -> (Vec<i32>, Vec<i32>) {
    let len = ids.iter().rposition(|t| *t != PAD).map_or(1, |i| i + 1);
    (ids[..len].to_vec(), type_ids[..len.min(type_ids.len())].to_vec())
}

/// The canonical mixed-length workload of the §5.9 acceptance runs: rows
/// at their real lengths, with every 4th kept at the container length
/// (the model max) so the top seq bucket stays exercised.  Shared by the
/// e2e seq-bucket sweep (whose ≥2x padded-token assertion runs on it)
/// and the mixed-length integration test, so both validate the same
/// workload shape.
pub fn mixed_length_workload(rows: &[(Vec<i32>, Vec<i32>)]) -> Vec<(Vec<i32>, Vec<i32>)> {
    rows.iter()
        .enumerate()
        .map(|(i, (ids, tys))| {
            if i % 4 == 3 {
                (ids.clone(), tys.clone())
            } else {
                trim_pad_tail(ids, tys)
            }
        })
        .collect()
}

/// A padded batch ready for the runtime: exactly `bucket` rows, the last
/// `bucket - real` rows being PAD padding that callers must drop.
pub struct PaddedBatch {
    pub bucket: usize,
    pub real: usize,
    pub ids: Vec<i32>,
    pub type_ids: Vec<i32>,
    pub mask: Vec<f32>,
}

/// Iterate a split in bucketed batches (the eval path).
pub fn batches(split: &Split, bucket: usize) -> Vec<PaddedBatch> {
    let seq = split.seq;
    let mut out = Vec::new();
    let n = split.len();
    let mut lo = 0;
    while lo < n {
        let real = bucket.min(n - lo);
        let mut ids = Vec::with_capacity(bucket * seq);
        let mut tys = Vec::with_capacity(bucket * seq);
        for i in lo..lo + real {
            let (r_ids, r_ty) = split.row(i);
            ids.extend_from_slice(r_ids);
            tys.extend_from_slice(r_ty);
        }
        ids.resize(bucket * seq, PAD);
        tys.resize(bucket * seq, 0);
        let mask = Split::mask_row(&ids);
        out.push(PaddedBatch { bucket, real, ids, type_ids: tys, mask });
        lo += real;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tensor;

    fn tiny_split() -> Split {
        let mut c = Container::new();
        c.push("input_ids", Tensor::i32(vec![3, 4], vec![1, 5, 2, 0, 1, 6, 2, 0, 1, 7, 8, 2]));
        c.push("type_ids", Tensor::i32(vec![3, 4], vec![0; 12]));
        c.push("labels_i32", Tensor::i32(vec![3], vec![1, 0, 1]));
        Split::from_container(&c).unwrap()
    }

    #[test]
    fn load_and_rows() {
        let s = tiny_split();
        assert_eq!(s.len(), 3);
        let (ids, _) = s.row(2);
        assert_eq!(ids, &[1, 7, 8, 2]);
    }

    #[test]
    fn batching_pads_tail() {
        let s = tiny_split();
        let bs = batches(&s, 2);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].real, 2);
        assert_eq!(bs[1].real, 1);
        assert_eq!(bs[1].ids.len(), 2 * 4);
        // padded row is all PAD -> mask 0
        assert_eq!(&bs[1].mask[4..], &[0.0; 4]);
        // real row mask: PAD position is 0
        assert_eq!(&bs[0].mask[..4], &[1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn trim_pad_tail_keeps_real_prefix() {
        // interior PAD survives; only the tail is stripped
        assert_eq!(trim_pad_tail(&[1, 0, 2, 0, 0], &[0, 0, 1, 1, 1]), (vec![1, 0, 2], vec![0, 0, 1]));
        // no tail: unchanged
        assert_eq!(trim_pad_tail(&[1, 2], &[0, 1]), (vec![1, 2], vec![0, 1]));
        // all-PAD row keeps one token (admission rejects empty ids)
        assert_eq!(trim_pad_tail(&[0, 0, 0], &[0, 0, 0]), (vec![0], vec![0]));
        // short type_ids never panics
        assert_eq!(trim_pad_tail(&[1, 2, 0], &[7]), (vec![1, 2], vec![7]));
    }

    #[test]
    fn regression_labels() {
        let mut c = Container::new();
        c.push("input_ids", Tensor::i32(vec![1, 2], vec![1, 2]));
        c.push("type_ids", Tensor::i32(vec![1, 2], vec![0, 0]));
        c.push("labels_f32", Tensor::f32(vec![1], vec![3.5]));
        let s = Split::from_container(&c).unwrap();
        match s.labels {
            Labels::Score(v) => assert_eq!(v, vec![3.5]),
            _ => panic!("expected scores"),
        }
    }

    #[test]
    fn rejects_mismatched_labels() {
        let mut c = Container::new();
        c.push("input_ids", Tensor::i32(vec![2, 2], vec![1, 2, 3, 4]));
        c.push("type_ids", Tensor::i32(vec![2, 2], vec![0; 4]));
        c.push("labels_i32", Tensor::i32(vec![3], vec![0, 1, 0]));
        assert!(Split::from_container(&c).is_err());
    }
}
