#!/usr/bin/env bash
# CI gate for the rust L3 stack: build, tests, lints, formatting.
#
# Usage: scripts/ci.sh [--skip-clippy] [--skip-fmt] [--skip-lint] [--skip-mck]
#
# Integration tests and benches that need real artifacts self-skip when
# `make artifacts` has not been run, so this script is safe on a bare
# checkout.  Benches (e.g. `cargo run --release --bin e2e_serving` via
# `benches/`) additionally emit BENCH_*.json trajectory files
# (BENCH_e2e_serving.json, BENCH_precision_policy.json,
# BENCH_replica_scaling.json, BENCH_seq_buckets.json); those are not
# part of the gate but should be committed when they change.
#
# The lint stages run with --all-targets so the typed PrecisionPolicy /
# RequestSpec surface stays clean across lib, tests, benches and
# examples — a stale call site anywhere fails the gate, not just in lib.

set -euo pipefail
cd "$(dirname "$0")/.."

SKIP_CLIPPY=0
SKIP_FMT=0
SKIP_LINT=0
SKIP_MCK=0
for arg in "$@"; do
    case "$arg" in
        --skip-clippy) SKIP_CLIPPY=1 ;;
        --skip-fmt) SKIP_FMT=1 ;;
        --skip-lint) SKIP_LINT=1 ;;
        --skip-mck) SKIP_MCK=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "==> cargo build --release"
cargo build --release

# `cargo test -q` includes the no-artifact format gate
# (tests/manifest_format.rs): the manifest format_version 3 `seq_buckets`
# grammar (grid artifact keys, absent => [seq] fallback) and the typed
# --max-batch config validation run on a bare checkout, so a manifest
# writer/loader drift fails CI even where `make artifacts` never ran.
echo "==> cargo test -q"
cargo test -q

# Replica supervision (DESIGN.md §5.10): the chaos suite runs on the
# fake engine (no artifacts needed), so the watchdog / supervised
# restart / circuit-breaker / fault-plan ledger invariants gate every
# checkout, not just artifact-bearing ones.
echo "==> chaos suite (fake engine)"
cargo test -q --test chaos_integration

# Multi-host scale-out (DESIGN.md §5.14): front-end tier + networked
# engine nodes over the v2 wire protocol, on the fake engine — no
# artifacts needed, so node death / typed cross-tier outcomes / exact
# per-tier ledger reconciliation gate every checkout.  The sweep then
# drives a 1-node vs 2-node goodput/p99 comparison through the CLI and
# asserts the >=1.7x 2-node speedup (emits BENCH_multihost.json — a
# trajectory artifact, committed when it changes).
echo "==> multihost suite (fake engine)"
cargo test -q --test multihost_integration
echo "==> multihost serve-bench sweep (1 vs 2 engine nodes)"
cargo run --release -- serve-bench --nodes 2 --requests 128

# herolint (DESIGN.md §5.11): the repo-native static analyses —
# lock-order cycles, under-ordered atomics in cross-thread handshakes,
# panic paths in serving modules, and the Recorder ledger identity —
# gate every checkout (no artifacts needed).  Zero unsuppressed
# findings required; suppressions live in-tree as `// panic-ok:` /
# `// relaxed-ok:` annotations with mandatory reasons.
if [ "$SKIP_LINT" -eq 0 ]; then
    echo "==> cargo run --release -- lint"
    cargo run --release -- lint
fi

# heromck (DESIGN.md §5.12): the dynamic complement to herolint —
# explore real thread schedules over the modeled `crate::sync` spine
# and prove the dispatch/ledger/governor/staging/pool invariants within
# the schedule budget.  The budget is pinned so the stage stays inside
# CI time; a failure prints an MCK_REPLAY token that reproduces the
# exact schedule.  Emits BENCH_lint_mck.json (schedule counts per model
# plus the herolint finding/suppression snapshot) — a trajectory
# artifact, not part of the gate.
if [ "$SKIP_MCK" -eq 0 ]; then
    echo "==> cargo test --features heromck --test mck_models (schedule-bounded)"
    MCK_SCHEDULES="${MCK_SCHEDULES:-2000}" \
    MCK_BENCH_JSON="$PWD/BENCH_lint_mck.json" \
        cargo test -q --features heromck --test mck_models
fi

# Artifact-gated serving smoke: the integration suites already ran
# un-skipped inside `cargo test -q` when artifacts exist; what they do
# not cover is the CLI surface, so drive a 2-replica serve-bench
# (load-aware dispatch end to end; emits
# BENCH_replica_scaling_smoke.json per-replica batch counts).
if [ -f artifacts/manifest.json ]; then
    echo "==> 2-replica serve-bench smoke"
    cargo run --release -- serve-bench --replicas 2 --requests 48 --concurrency 8

    # overload control (DESIGN.md §5.8): re-run the serving-pressure
    # suite explicitly, then smoke the governor through the CLI with a
    # 2x open-loop burst (bounded admission + deadlines + governed
    # downgrade; emits BENCH_overload_smoke.json, whose ledger the
    # binary asserts reconciles exactly)
    echo "==> overload suite"
    cargo test -q --test overload_integration
    echo "==> governor serve-bench smoke (2x open-loop burst)"
    cargo run --release -- serve-bench --governor --overload 2 \
        --queue-cap 64 --default-deadline-ms 250 \
        --modes m3 --policies attn-out-fp --requests 128

    # length-aware serving (DESIGN.md §5.9): drive real-length rows vs a
    # padded single-seq baseline through fresh coordinators and record
    # the padded-token volumes (BENCH_seq_buckets_smoke.json); the full
    # sweep with the >=2x reduction assertion is benches/e2e_serving.rs
    echo "==> mixed-length serve-bench smoke (seq-bucket grid)"
    cargo run --release -- serve-bench --mixed-length \
        --modes m3 --requests 96 --concurrency 16

    # replica supervision on the real engine (DESIGN.md §5.10): panic a
    # replica mid-run, assert every client still gets a terminal reply,
    # the supervisor restarts the replica, and goodput recovers to >=90%
    # of a fault-free baseline (emits BENCH_chaos_smoke.json)
    echo "==> chaos serve-bench smoke (replica kill + supervised restart)"
    cargo run --release -- serve-bench --chaos --replicas 2 \
        --requests 64 --concurrency 16

    # executable residency (DESIGN.md §5.13): pin-set startup vs the old
    # eager full-grid preload on the real engine — asserts startup loads
    # exactly the pin set and the resident-cell count respects the LRU
    # budget; reports the warm/cold-cell latency split (emits
    # BENCH_residency.json)
    echo "==> residency serve-bench smoke (pin set vs eager grid)"
    cargo run --release -- serve-bench --residency \
        --modes fp,m3 --requests 64 --concurrency 8 --max-resident-cells 8
fi

if [ "$SKIP_CLIPPY" -eq 0 ]; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

if [ "$SKIP_FMT" -eq 0 ]; then
    echo "==> cargo fmt --check"
    cargo fmt --check
fi

echo "CI OK"
