//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §2).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

#[derive(Debug)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Option spec: name, takes_value, default, help.
///
/// Route-shaped options (`--mode`, `--task`, `--policy`) must keep
/// `default: None` here: their defaults are derived from the loaded
/// manifest at command time (first mode / task order), so a bad name
/// fails with the manifest's known-name list (`Manifest::mode_id`
/// message shape) instead of a hardcoded string silently drifting from
/// the artifacts.
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

pub struct SubSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
}

pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub subs: Vec<SubSpec>,
}

impl Cli {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n",
            self.bin, self.about, self.bin);
        for sub in &self.subs {
            s.push_str(&format!("  {:<22} {}\n", sub.name, sub.help));
        }
        s.push_str("\nRun with `<COMMAND> --help` for command options.\n");
        s
    }

    pub fn sub_usage(&self, sub: &SubSpec) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.bin, sub.name, sub.help);
        for o in &sub.opts {
            let arg = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {:<26} {}{}\n", arg, o.help, def));
        }
        s
    }

    /// Parse argv (without argv[0]).  Returns Err with a message that the
    /// caller should print (usage text for --help).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Err(CliError(self.usage()));
        }
        let name = &argv[0];
        let sub = self
            .subs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| CliError(format!("unknown command {name:?}\n\n{}", self.usage())))?;

        let mut flags = BTreeMap::new();
        for o in &sub.opts {
            if let Some(d) = o.default {
                flags.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut positional = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.sub_usage(sub)));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = sub.opts.iter().find(|o| o.name == key).ok_or_else(|| {
                    CliError(format!("unknown option --{key}\n\n{}", self.sub_usage(sub)))
                })?;
                let val = if spec.takes_value {
                    match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                        }
                    }
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{key} takes no value")));
                    }
                    "true".to_string()
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { subcommand: sub.name.to_string(), flags, positional })
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{key}: expected number, got {v:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "repro",
            about: "test",
            subs: vec![SubSpec {
                name: "eval",
                help: "run eval",
                opts: vec![
                    // route flags carry no hardcoded default (manifest-derived)
                    OptSpec { name: "mode", takes_value: true, default: None, help: "" },
                    OptSpec { name: "all", takes_value: false, default: None, help: "" },
                    OptSpec { name: "pct", takes_value: true, default: Some("100"), help: "" },
                ],
            }],
        }
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_flags() {
        let a = cli().parse(&sv(&["eval", "--all", "task1"])).unwrap();
        // route flags have no baked-in default; value flags keep theirs
        assert_eq!(a.get("mode"), None);
        assert_eq!(a.get("pct"), Some("100"));
        assert!(a.get_bool("all"));
        assert_eq!(a.positional, vec!["task1"]);
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = cli().parse(&sv(&["eval", "--mode", "m3", "--pct=99.9"])).unwrap();
        assert_eq!(a.get("mode"), Some("m3"));
        assert_eq!(a.get_f64("pct").unwrap(), Some(99.9));
    }

    #[test]
    fn rejects_unknown() {
        assert!(cli().parse(&sv(&["nope"])).is_err());
        assert!(cli().parse(&sv(&["eval", "--bogus"])).is_err());
        assert!(cli().parse(&sv(&["eval", "--mode"])).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = cli().parse(&sv(&["eval", "--help"])).unwrap_err();
        assert!(err.0.contains("OPTIONS"));
    }
}
