//! Cross-language parity: the rust quantization engine must reproduce the
//! python-quantized golden checkpoints bit-exactly (same folding, same
//! rounding, same scale derivation).  Gated on `make artifacts`.

use std::path::Path;

use zqhero::calib::load_history;
use zqhero::model::manifest::Manifest;
use zqhero::model::{Container, DType};
use zqhero::quant::{quantize_checkpoint, AggStats};

fn artifacts() -> Option<std::path::PathBuf> {
    let p = Path::new("artifacts");
    if p.join("golden/fp32.bin").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("skipping golden parity tests: run `make artifacts` first");
        None
    }
}

#[test]
fn quantize_matches_python_bit_exact() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(&dir).unwrap();
    let fp = Container::read_file(&dir.join("golden/fp32.bin")).unwrap();
    let hist = load_history(&dir.join("golden/calib.json")).unwrap();
    let stats = AggStats::from_history(&hist, &man.model, 100.0).unwrap();

    for mode in ["m1", "m2", "m3"] {
        let want = Container::read_file(&dir.join(format!("golden/hero-{mode}.bin"))).unwrap();
        let sw = man.mode(mode).unwrap().switches;
        let got = quantize_checkpoint(&fp, &stats, &man.model, &sw).unwrap();

        assert_eq!(got.len(), want.len(), "{mode}: tensor count");
        let mut max_rel = 0f64;
        for ((gn, gt), (wn, wt)) in got.entries.iter().zip(&want.entries) {
            assert_eq!(gn, wn, "{mode}: name order");
            assert_eq!(gt.shape, wt.shape, "{mode}/{gn}: shape");
            assert_eq!(gt.dtype(), wt.dtype(), "{mode}/{gn}: dtype");
            match gt.dtype() {
                DType::I8 => {
                    let (g, w) = (gt.as_i8().unwrap(), wt.as_i8().unwrap());
                    let diff = g.iter().zip(w).filter(|(a, b)| a != b).count();
                    assert_eq!(diff, 0, "{mode}/{gn}: {diff} int8 mismatches");
                }
                DType::F32 => {
                    let (g, w) = (gt.as_f32().unwrap(), wt.as_f32().unwrap());
                    for (i, (a, b)) in g.iter().zip(w).enumerate() {
                        assert!(
                            a.to_bits() == b.to_bits(),
                            "{mode}/{gn}[{i}]: {a:e} vs {b:e}"
                        );
                        let rel = ((a - b).abs() / b.abs().max(1e-9)) as f64;
                        max_rel = max_rel.max(rel);
                    }
                }
                DType::I32 => unreachable!("no i32 params"),
            }
        }
        eprintln!("{mode}: bit-exact ({} tensors)", got.len());
    }
}

#[test]
fn golden_checkpoints_match_manifest_signatures() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(&dir).unwrap();
    for mode in ["m1", "m2", "m3"] {
        let c = Container::read_file(&dir.join(format!("golden/hero-{mode}.bin"))).unwrap();
        zqhero::quant::validate_against_mode(&c, man.mode(mode).unwrap()).unwrap();
    }
}
