"""SynGLUE generators + container format + metrics oracles."""

import os
import tempfile

import numpy as np
import pytest

from compile import data as D
from compile import metrics as M
from compile.container import write_container, read_container


# ------------------------------------------------------------- container


def test_container_roundtrip():
    tensors = {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "q": np.array([-128, 0, 127], np.int8),
        "ids": np.array([[1, 2], [3, 4]], np.int32),
    }
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.bin")
        write_container(p, tensors)
        r = read_container(p)
    assert list(r.keys()) == ["w", "q", "ids"]
    for k in tensors:
        np.testing.assert_array_equal(r[k], tensors[k])
        assert r[k].dtype == tensors[k].dtype


def test_container_rejects_bad_magic():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "bad.bin")
        open(p, "wb").write(b"NOTMAGIC" + b"\x00" * 10)
        with pytest.raises(ValueError):
            read_container(p)


# ------------------------------------------------------------ generators


@pytest.mark.parametrize("task", D.TASKS)
def test_generators_deterministic_and_wellformed(task):
    s1 = D.make_split(task, 64, 64, seed=7)
    s2 = D.make_split(task, 64, 64, seed=7)
    np.testing.assert_array_equal(s1["input_ids"], s2["input_ids"])
    ids = s1["input_ids"]
    assert ids.shape == (64, 64)
    assert ids.dtype == np.int32
    # starts with CLS, all ids within vocab
    assert (ids[:, 0] == 1).all()
    assert ids.min() >= 0 and ids.max() < 2048
    # every row has at least one SEP and ends in PAD or SEP
    assert ((ids == 2).sum(axis=1) >= 1).all()
    # type ids only 0/1 and 0 on padding
    ty = s1["type_ids"]
    assert set(np.unique(ty)) <= {0, 1}
    assert (ty[ids == 0] == 0).all()


def test_label_balances():
    sst2 = D.make_split("sst2", 500, 64, seed=1)["labels_i32"]
    assert 0.4 < sst2.mean() < 0.6
    mrpc = D.make_split("mrpc", 500, 64, seed=1)["labels_i32"]
    assert 0.6 < mrpc.mean() < 0.76  # ~68% positive like MRPC
    qqp = D.make_split("qqp", 500, 64, seed=1)["labels_i32"]
    assert 0.3 < qqp.mean() < 0.45  # ~37% positive like QQP
    mnli = D.make_split("mnli", 600, 64, seed=1)["labels_i32"]
    for c in range(3):
        assert 0.25 < (mnli == c).mean() < 0.42


def test_stsb_scores_in_range():
    s = D.make_split("stsb", 300, 64, seed=2)["labels_f32"]
    assert s.min() >= 0.0 and s.max() <= 5.0
    assert s.std() > 0.8  # spread across the range


def test_cola_negatives_are_minimal_edits():
    """cola negatives must stay near the decision boundary: token multiset
    differs from an acceptable sentence by a small edit."""
    s = D.make_split("cola", 200, 64, seed=3)
    ids, labels = s["input_ids"], s["labels_i32"]
    verbs = set(D.VERB_TOKENS)
    for row, label in zip(ids, labels):
        toks = [t for t in row.tolist() if t > 3]
        vcount = sum(t in verbs for t in toks)
        if label == 1:
            assert vcount == 1  # exactly one verb in acceptable sentences
        else:
            assert vcount in (0, 1, 2)


def test_mask_matches_pad():
    s = D.make_split("qnli", 50, 64, seed=4)
    m = D.attn_mask(s["input_ids"])
    assert ((m == 0) == (s["input_ids"] == 0)).all()


def test_fast_sizes_smaller():
    for t in D.TASKS:
        assert D.FAST_SIZES[t][0] < D.SIZES[t][0]


# --------------------------------------------------------------- metrics


def test_mcc_against_known():
    preds = np.array([1, 1, 0, 0, 1, 0])
    labels = np.array([1, 0, 0, 1, 1, 0])
    # tp=2 tn=2 fp=1 fn=1 -> mcc = (4-1)/sqrt(3*3*3*3) = 3/9
    assert abs(M.matthews_corrcoef(preds, labels) - 1 / 3) < 1e-12


def test_f1_acc_known():
    preds = np.array([1, 1, 1, 0])
    labels = np.array([1, 0, 1, 1])
    assert abs(M.f1_binary(preds, labels) - 2 * 2 / (2 * 2 + 1 + 1)) < 1e-12
    assert M.accuracy(preds, labels) == 0.5


def test_spearman_ties_and_scipy_parity():
    from scipy import stats as ss
    r = np.random.default_rng(5)
    x = r.normal(size=50)
    y = x + r.normal(scale=0.5, size=50)
    x[:5] = x[5:10]  # inject ties
    want = ss.spearmanr(x, y).statistic
    got = M.spearman(x, y)
    assert abs(got - want) < 1e-10


def test_pearson_scipy_parity():
    from scipy import stats as ss
    r = np.random.default_rng(6)
    x = r.normal(size=40)
    y = 2 * x + r.normal(size=40)
    assert abs(M.pearson(x, y) - ss.pearsonr(x, y).statistic) < 1e-10
