//! Per-function fact extraction for herolint (DESIGN.md §5.11).
//!
//! A single forward walk over the token stream recovers, for every
//! non-test function: lock acquisitions (with the set of guards held at
//! each one, tracked through `let`-bound vs temporary guard scopes),
//! calls made while holding locks (for the inter-procedural lock
//! graph), atomic accesses with their `Ordering`, panic sites
//! (`unwrap`/`expect`/arithmetic slice index), blocking calls made
//! while a guard is live (`send`/`recv`/`join`/`sleep`/IO), counter
//! increments, Condvar usage, and whether the function sends a wire
//! reply.
//!
//! The walk is deliberately syntactic: no types, no name resolution.
//! Where that loses precision the rules compensate (unique-name call
//! resolution, annotation escape hatches) and DESIGN.md §5.11 records
//! the known blind spots (closures attribute to their enclosing
//! function; trait-object indirection is invisible).

use std::collections::HashMap;

use super::lexer::{AnnKind, Lexed, Tok, Token};

/// Methods that acquire a std lock when called with no arguments.
const LOCK_METHODS: [&str; 3] = ["lock", "read", "write"];

const ATOMIC_METHODS: [&str; 13] = [
    "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_and", "fetch_or", "fetch_xor",
    "fetch_max", "fetch_min", "fetch_update", "compare_exchange", "compare_exchange_weak",
];

const CONDVAR_METHODS: [&str; 6] =
    ["wait", "wait_timeout", "wait_while", "wait_timeout_while", "notify_one", "notify_all"];

/// Is this call a potential parking point for the `hold-across-blocking`
/// rule, given its argument shape and the number of guards held?
///
/// A condvar `wait` *releases* the guard it is passed, so it only counts
/// when a *second* guard is held across the park.  `.join()` is only a
/// thread join when it takes no arguments (`slice::join(sep)` takes the
/// separator).
fn blocking_call(m: &str, no_args: bool, guards: usize) -> bool {
    match m {
        "recv" | "recv_timeout" | "send" | "sleep" | "write_all" | "flush" | "read_exact"
        | "read_to_end" | "read_to_string" | "read_line" | "accept" | "connect" => guards >= 1,
        "join" => no_args && guards >= 1,
        "wait" | "wait_timeout" | "wait_while" | "wait_timeout_while" => guards >= 2,
        _ => false,
    }
}

/// One direct lock acquisition.
#[derive(Debug, Clone)]
pub struct Acquire {
    pub class: String,
    pub line: u32,
}

/// A nested acquisition: `class` taken while `held` was already held.
#[derive(Debug, Clone)]
pub struct Nested {
    pub held: String,
    pub class: String,
    pub line: u32,
}

/// A call made while holding at least one lock (inter-procedural edge
/// candidate).
#[derive(Debug, Clone)]
pub struct LockedCall {
    pub callee: String,
    pub held: Vec<String>,
    pub line: u32,
}

#[derive(Debug, Clone)]
pub struct AtomicSite {
    pub field: String,
    pub method: String,
    pub ordering: String,
    pub is_store: bool,
    pub line: u32,
    pub suppressed: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PanicKind {
    Unwrap,
    Expect,
    Index,
}

impl PanicKind {
    pub fn label(self) -> &'static str {
        match self {
            PanicKind::Unwrap => "unwrap()",
            PanicKind::Expect => "expect()",
            PanicKind::Index => "arithmetic slice index",
        }
    }
}

#[derive(Debug, Clone)]
pub struct PanicSite {
    pub kind: PanicKind,
    pub line: u32,
    pub suppressed: bool,
}

/// A call that can park the thread while at least one lock guard is
/// live (`hold-across-blocking`).
#[derive(Debug, Clone)]
pub struct BlockingSite {
    pub callee: String,
    pub held: Vec<String>,
    pub line: u32,
    pub suppressed: bool,
}

/// Everything the rules need to know about one function.
#[derive(Debug, Clone, Default)]
pub struct FnFacts {
    pub file: String,
    /// Bare method name.
    pub name: String,
    /// `Type::name` when inside an impl block, else `name`.
    pub qual: String,
    pub impl_type: Option<String>,
    pub line: u32,
    pub acquires: Vec<Acquire>,
    pub nested: Vec<Nested>,
    pub locked_calls: Vec<LockedCall>,
    /// Every `name(`/`​.name(` call site (coarse; includes enum
    /// constructors — the rules match against known method names only).
    pub calls: Vec<(String, u32)>,
    pub atomics: Vec<AtomicSite>,
    pub panics: Vec<PanicSite>,
    pub blocking: Vec<BlockingSite>,
    /// `field += …` sites.
    pub increments: Vec<(String, u32)>,
    pub uses_condvar: bool,
    pub sends_reply: bool,
    /// Signature returns a `MutexGuard`/`RwLock*Guard` — callers of
    /// this function acquire its lock.
    pub guard_helper: bool,
}

#[derive(Debug)]
struct Guard {
    class: String,
    depth: u32,
    let_bound: bool,
}

struct Frame {
    facts: FnFacts,
    depth: u32,
    guards: Vec<Guard>,
}

/// `exec/mod.rs` → `exec`, `coordinator/stats.rs` → `stats` — the
/// fallback lock-class namespace when an acquisition has no
/// `.expect("label")`.
fn file_stem(file: &str) -> String {
    let base = file.rsplit('/').next().unwrap_or(file);
    let stem = base.strip_suffix(".rs").unwrap_or(base);
    if stem == "mod" || stem == "lib" || stem == "main" {
        let mut parts: Vec<&str> = file.split('/').collect();
        parts.pop();
        if let Some(dir) = parts.pop() {
            return dir.to_string();
        }
    }
    stem.to_string()
}

/// Walk backwards from the token before `.method(` to the receiver's
/// innermost field name: `self.inner.lock()` → `inner`,
/// `shared.effective[i].load(…)` → `effective`.
fn receiver_field(toks: &[Token], mut i: isize) -> String {
    while i >= 0 {
        match &toks[i as usize].tok {
            Tok::Ident(s) => return s.clone(),
            Tok::Punct(']') => {
                let mut depth = 1;
                i -= 1;
                while i >= 0 && depth > 0 {
                    match toks[i as usize].tok {
                        Tok::Punct(']') => depth += 1,
                        Tok::Punct('[') => depth -= 1,
                        _ => {}
                    }
                    i -= 1;
                }
            }
            Tok::Punct(')') => {
                let mut depth = 1;
                i -= 1;
                while i >= 0 && depth > 0 {
                    match toks[i as usize].tok {
                        Tok::Punct(')') => depth += 1,
                        Tok::Punct('(') => depth -= 1,
                        _ => {}
                    }
                    i -= 1;
                }
                // skip the method name + dot of the inner call and keep
                // walking: `self.q.lock().unwrap()` wants `q`.
                if i >= 0 && toks[i as usize].ident().is_some() {
                    i -= 1;
                }
                if i >= 0 && toks[i as usize].is_punct('.') {
                    i -= 1;
                } else {
                    return "?".to_string();
                }
            }
            _ => return "?".to_string(),
        }
    }
    "?".to_string()
}

/// Extract facts for every production (non-`#[cfg(test)]`, non-`#[test]`)
/// function in one file.  `helpers` maps guard-returning helper method
/// names to the lock class they hand out (from a prior pass).
pub fn extract(file: &str, lexed: &Lexed, helpers: &HashMap<String, String>) -> Vec<FnFacts> {
    let toks = &lexed.tokens;
    let n = toks.len();
    let stem = file_stem(file);

    let mut out: Vec<FnFacts> = Vec::new();
    let mut frames: Vec<Frame> = Vec::new();
    let mut impls: Vec<(String, u32)> = Vec::new();
    let mut depth: u32 = 0;
    let mut pb: u32 = 0; // paren + bracket depth (for `;` disambiguation)
    let mut pending_fn: Option<(String, bool, u32)> = None; // (name, guard_helper, line)
    let mut pending_impl: Option<String> = None;
    let mut pending_skip = false;
    let mut stmt_let = false;
    let mut pending_atomic: Option<(String, String, u32)> = None; // (field, method, line)

    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        match &t.tok {
            // ---- attributes: `#[...]` / `#![...]` --------------------
            Tok::Punct('#') => {
                let mut j = i + 1;
                if j < n && toks[j].is_punct('!') {
                    j += 1;
                }
                if j < n && toks[j].is_punct('[') {
                    let mut adepth = 1u32;
                    let mut idents: Vec<&str> = Vec::new();
                    j += 1;
                    while j < n && adepth > 0 {
                        match &toks[j].tok {
                            Tok::Punct('[') => adepth += 1,
                            Tok::Punct(']') => adepth -= 1,
                            Tok::Ident(s) => idents.push(s.as_str()),
                            _ => {}
                        }
                        j += 1;
                    }
                    if idents.contains(&"test") && !idents.contains(&"not") {
                        pending_skip = true;
                    }
                    i = j;
                    continue;
                }
                i += 1;
            }

            Tok::Punct('{') => {
                if pending_skip {
                    // consume the whole test item
                    let mut bdepth = 1u32;
                    let mut j = i + 1;
                    while j < n && bdepth > 0 {
                        match toks[j].tok {
                            Tok::Punct('{') => bdepth += 1,
                            Tok::Punct('}') => bdepth -= 1,
                            _ => {}
                        }
                        j += 1;
                    }
                    pending_skip = false;
                    pending_fn = None;
                    pending_impl = None;
                    i = j;
                    continue;
                }
                depth += 1;
                stmt_let = false;
                pending_atomic = None;
                if let Some((name, helper, line)) = pending_fn.take() {
                    let impl_type = impls.last().map(|(t, _)| t.clone());
                    let qual = match &impl_type {
                        Some(t) => format!("{}::{}", t, name),
                        None => name.clone(),
                    };
                    frames.push(Frame {
                        facts: FnFacts {
                            file: file.to_string(),
                            name,
                            qual,
                            impl_type,
                            line,
                            guard_helper: helper,
                            ..FnFacts::default()
                        },
                        depth,
                        guards: Vec::new(),
                    });
                } else if let Some(ty) = pending_impl.take() {
                    impls.push((ty, depth));
                }
                i += 1;
            }

            Tok::Punct('}') => {
                if let Some(fr) = frames.last_mut() {
                    fr.guards.retain(|g| g.depth < depth);
                    if fr.depth == depth {
                        let fr = frames.pop().expect("frame just checked");
                        out.push(fr.facts);
                    }
                }
                if let Some((_, d)) = impls.last() {
                    if *d == depth {
                        impls.pop();
                    }
                }
                depth = depth.saturating_sub(1);
                stmt_let = false;
                pending_atomic = None;
                i += 1;
            }

            Tok::Punct(';') => {
                if pb == 0 {
                    if pending_skip {
                        // `#[cfg(test)] use …;` — no body to skip
                        pending_skip = false;
                        pending_fn = None;
                    }
                    if let Some(fr) = frames.last_mut() {
                        fr.guards.retain(|g| g.let_bound || g.depth < depth);
                    }
                    stmt_let = false;
                    pending_atomic = None;
                }
                i += 1;
            }

            Tok::Punct('(') | Tok::Punct('[') => {
                // slice-index sub-rule: flag `x[… + …]` / `x[… - …]`
                if t.is_punct('[') && !frames.is_empty() && i > 0 {
                    let prev_ok = matches!(toks[i - 1].tok, Tok::Ident(_))
                        || toks[i - 1].is_punct(']')
                        || toks[i - 1].is_punct(')');
                    if prev_ok {
                        let mut bdepth = 1u32;
                        let mut j = i + 1;
                        let mut arith = false;
                        while j < n && bdepth > 0 {
                            match toks[j].tok {
                                Tok::Punct('[') => bdepth += 1,
                                Tok::Punct(']') => bdepth -= 1,
                                Tok::Punct('+') | Tok::Punct('-') => arith = true,
                                _ => {}
                            }
                            j += 1;
                        }
                        if arith {
                            let suppressed = lexed.suppressed(AnnKind::PanicOk, t.line);
                            if let Some(fr) = frames.last_mut() {
                                fr.facts.panics.push(PanicSite {
                                    kind: PanicKind::Index,
                                    line: t.line,
                                    suppressed,
                                });
                            }
                        }
                    }
                }
                pb += 1;
                i += 1;
            }
            Tok::Punct(')') | Tok::Punct(']') => {
                pb = pb.saturating_sub(1);
                i += 1;
            }

            Tok::Ident(id) if id == "fn" => {
                // signature scan: name, guard-helper return, body-vs-decl
                let mut j = i + 1;
                let name = match toks.get(j).and_then(|t| t.ident()) {
                    Some(s) => s.to_string(),
                    None => {
                        i += 1;
                        continue;
                    }
                };
                let line = toks[j].line;
                j += 1;
                let mut sig_pb = 0u32;
                let mut helper = false;
                while j < n {
                    match &toks[j].tok {
                        Tok::Punct('(') | Tok::Punct('[') => sig_pb += 1,
                        Tok::Punct(')') | Tok::Punct(']') => sig_pb = sig_pb.saturating_sub(1),
                        Tok::Punct('{') if sig_pb == 0 => break,
                        Tok::Punct(';') if sig_pb == 0 => {
                            // trait method declaration: no body
                            j += 1;
                            break;
                        }
                        Tok::Ident(s)
                            if s == "MutexGuard"
                                || s == "RwLockReadGuard"
                                || s == "RwLockWriteGuard" =>
                        {
                            helper = true;
                        }
                        _ => {}
                    }
                    j += 1;
                }
                if j < n && toks[j].is_punct('{') {
                    pending_fn = Some((name, helper, line));
                }
                i = j; // the `{` (or token after `;`) is processed by the main loop
            }

            Tok::Ident(id) if id == "impl" => {
                // header scan up to `{`: last path segment at angle-depth
                // 0 wins; `for` resets (the earlier name was the trait)
                let mut j = i + 1;
                let mut angle = 0i32;
                let mut ty: Option<String> = None;
                while j < n {
                    match &toks[j].tok {
                        Tok::Punct('{') if angle <= 0 => break,
                        Tok::Punct('<') => angle += 1,
                        Tok::Punct('>') => angle -= 1,
                        Tok::Ident(s) if s == "for" => ty = None,
                        Tok::Ident(s) if angle <= 0 && s != "where" => ty = Some(s.clone()),
                        _ => {}
                    }
                    j += 1;
                }
                pending_impl = Some(ty.unwrap_or_else(|| "?".to_string()));
                i = j; // leave the `{` to the main loop
            }

            Tok::Ident(id) if id == "let" => {
                stmt_let = true;
                i += 1;
            }

            // ---- method calls: `.name(` ------------------------------
            Tok::Punct('.') => {
                let m = match toks.get(i + 1).and_then(|t| t.ident()) {
                    Some(s) if toks.get(i + 2).map(|t| t.is_punct('(')).unwrap_or(false) => {
                        s.to_string()
                    }
                    _ => {
                        i += 1;
                        continue;
                    }
                };
                let line = toks[i + 1].line;
                if let Some(fr) = frames.last_mut() {
                    let no_args = toks.get(i + 3).map(|t| t.is_punct(')')).unwrap_or(false);
                    if blocking_call(&m, no_args, fr.guards.len()) {
                        fr.facts.blocking.push(BlockingSite {
                            callee: m.clone(),
                            held: fr.guards.iter().map(|g| g.class.clone()).collect(),
                            line,
                            suppressed: lexed.suppressed(AnnKind::BlockOk, line),
                        });
                    }
                    // Method names the extractor already special-cases
                    // are std-library calls (`.expect(…)`, `.load(…)`)
                    // — recording them as resolvable calls would let a
                    // same-named tree function (e.g. `json::Parser::
                    // expect`, `Manifest::load`) pollute the lock graph.
                    let std_method = LOCK_METHODS.contains(&m.as_str())
                        || ATOMIC_METHODS.contains(&m.as_str())
                        || CONDVAR_METHODS.contains(&m.as_str())
                        || matches!(m.as_str(), "unwrap" | "expect" | "send");
                    if !std_method {
                        fr.facts.calls.push((m.clone(), line));
                        if !fr.guards.is_empty() {
                            fr.facts.locked_calls.push(LockedCall {
                                callee: m.clone(),
                                held: fr.guards.iter().map(|g| g.class.clone()).collect(),
                                line,
                            });
                        }
                    }
                    if LOCK_METHODS.contains(&m.as_str()) && no_args {
                        // class: chained `.expect("label")` names it,
                        // else fall back to `stem::field`
                        let mut class = None;
                        if toks.get(i + 4).map(|t| t.is_punct('.')).unwrap_or(false)
                            && toks.get(i + 5).and_then(|t| t.ident()) == Some("expect")
                        {
                            if let Some(Tok::Str(s)) = toks.get(i + 7).map(|t| &t.tok) {
                                class = Some(s.clone());
                            }
                        }
                        let class = class.unwrap_or_else(|| {
                            format!("{}::{}", stem, receiver_field(toks, i as isize - 1))
                        });
                        record_acquire(fr, class, line, depth, stmt_let);
                    } else if let Some(class) = helpers.get(&m) {
                        record_acquire(fr, class.clone(), line, depth, stmt_let);
                    }
                    if ATOMIC_METHODS.contains(&m.as_str()) {
                        let field = receiver_field(toks, i as isize - 1);
                        pending_atomic = Some((field, m.clone(), line));
                    }
                    if CONDVAR_METHODS.contains(&m.as_str()) {
                        fr.facts.uses_condvar = true;
                    }
                    if m == "send" && receiver_field(toks, i as isize - 1) == "reply" {
                        fr.facts.sends_reply = true;
                    }
                    if m == "unwrap" || m == "expect" {
                        let kind =
                            if m == "unwrap" { PanicKind::Unwrap } else { PanicKind::Expect };
                        let suppressed = lexed.suppressed(AnnKind::PanicOk, line);
                        fr.facts.panics.push(PanicSite { kind, line, suppressed });
                    }
                }
                i += 2; // resume at the `(` so pb stays balanced
            }

            // ---- `Ordering::X` resolves a pending atomic -------------
            Tok::Ident(id) if id == "Ordering" => {
                let is_path = toks.get(i + 1).map(|t| t.is_punct(':')).unwrap_or(false)
                    && toks.get(i + 2).map(|t| t.is_punct(':')).unwrap_or(false);
                if is_path {
                    if let Some(ord) = toks.get(i + 3).and_then(|t| t.ident()) {
                        if let Some((field, method, aline)) = pending_atomic.take() {
                            if let Some(fr) = frames.last_mut() {
                                let suppressed = lexed.suppressed(AnnKind::RelaxedOk, aline)
                                    || lexed.suppressed(AnnKind::RelaxedOk, toks[i].line);
                                fr.facts.atomics.push(AtomicSite {
                                    field,
                                    is_store: method != "load",
                                    method,
                                    ordering: ord.to_string(),
                                    line: aline,
                                    suppressed,
                                });
                            }
                        }
                        i += 4;
                        continue;
                    }
                }
                i += 1;
            }

            // ---- free calls / increments -----------------------------
            Tok::Ident(id) => {
                let is_call = toks.get(i + 1).map(|t| t.is_punct('(')).unwrap_or(false)
                    && (i == 0 || toks[i - 1].ident() != Some("fn"));
                let is_incr = toks.get(i + 1).map(|t| t.is_punct('+')).unwrap_or(false)
                    && toks.get(i + 2).map(|t| t.is_punct('=')).unwrap_or(false);
                if let Some(fr) = frames.last_mut() {
                    if is_call {
                        fr.facts.calls.push((id.clone(), t.line));
                        if !fr.guards.is_empty() {
                            fr.facts.locked_calls.push(LockedCall {
                                callee: id.clone(),
                                held: fr.guards.iter().map(|g| g.class.clone()).collect(),
                                line: t.line,
                            });
                        }
                        // free-call form of the parking points
                        // (`thread::sleep(…)` and friends)
                        let no_args =
                            toks.get(i + 2).map(|t| t.is_punct(')')).unwrap_or(false);
                        if blocking_call(id, no_args, fr.guards.len()) {
                            fr.facts.blocking.push(BlockingSite {
                                callee: id.clone(),
                                held: fr.guards.iter().map(|g| g.class.clone()).collect(),
                                line: t.line,
                                suppressed: lexed.suppressed(AnnKind::BlockOk, t.line),
                            });
                        }
                        if let Some(class) = helpers.get(id) {
                            record_acquire(fr, class.clone(), t.line, depth, stmt_let);
                        }
                    }
                    if is_incr {
                        fr.facts.increments.push((id.clone(), t.line));
                    }
                }
                i += 1;
            }

            _ => {
                i += 1;
            }
        }
    }
    // unterminated frames (shouldn't happen on valid code) still report
    while let Some(fr) = frames.pop() {
        out.push(fr.facts);
    }
    out
}

fn record_acquire(fr: &mut Frame, class: String, line: u32, depth: u32, let_bound: bool) {
    fr.facts.acquires.push(Acquire { class: class.clone(), line });
    for g in &fr.guards {
        fr.facts.nested.push(Nested { held: g.class.clone(), class: class.clone(), line });
    }
    fr.guards.push(Guard { class, depth, let_bound });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn facts_of(src: &str) -> Vec<FnFacts> {
        extract("x/demo.rs", &lex(src), &HashMap::new())
    }

    #[test]
    fn nested_acquisition_and_label_classes() {
        let src = r#"
impl Pool {
    fn submit(&self) {
        let slot = self.slot.lock().expect("replica slot");
        let q = self.queue.lock().expect("job queue");
        q.len();
    }
}
"#;
        let f = &facts_of(src)[0];
        assert_eq!(f.qual, "Pool::submit");
        assert_eq!(f.acquires.len(), 2);
        assert_eq!(f.acquires[0].class, "replica slot");
        assert_eq!(f.nested.len(), 1);
        assert_eq!(f.nested[0].held, "replica slot");
        assert_eq!(f.nested[0].class, "job queue");
        // the two `.expect(` chains are panic sites too
        assert_eq!(f.panics.iter().filter(|p| p.kind == PanicKind::Expect).count(), 2);
    }

    #[test]
    fn temporary_guard_released_at_statement_end() {
        let src = r#"
fn tick(&self) {
    self.a.lock().unwrap().push(1);
    self.b.lock().unwrap().push(2);
}
"#;
        let f = &facts_of(src)[0];
        assert_eq!(f.acquires.len(), 2);
        assert!(f.nested.is_empty(), "statement-scoped guards must not overlap: {:?}", f.nested);
    }

    #[test]
    fn let_guard_held_across_call_sites() {
        let src = r#"
fn drain(&self) {
    let g = self.a.lock().unwrap();
    helper(g.len());
}
"#;
        let f = &facts_of(src)[0];
        let lc: Vec<&str> = f.locked_calls.iter().map(|c| c.callee.as_str()).collect();
        assert!(lc.contains(&"helper"), "call under guard must be recorded: {:?}", lc);
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = r#"
fn real(&self) { self.x.lock().unwrap(); }
#[cfg(test)]
mod tests {
    #[test]
    fn fake() { panic!(); }
    fn helper(&self) { self.y.lock().unwrap(); }
}
"#;
        let fs = facts_of(src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].name, "real");
    }

    #[test]
    fn atomics_condvar_reply_and_increments() {
        let src = r#"
impl Recorder {
    fn record(&self, s: &mut Slots) {
        s.requests += 1;
        self.seq.fetch_add(1, Ordering::Relaxed);
        self.flag.store(true, Ordering::SeqCst);
    }
    fn pump(&self, g: G) {
        let g = self.cv.wait(g).unwrap();
        r.reply.send(g);
    }
}
"#;
        let fs = facts_of(src);
        let rec = fs.iter().find(|f| f.name == "record").unwrap();
        assert_eq!(rec.increments, vec![("requests".to_string(), 4)]);
        assert_eq!(rec.atomics.len(), 2);
        assert_eq!(rec.atomics[0].field, "seq");
        assert_eq!(rec.atomics[0].ordering, "Relaxed");
        assert!(rec.atomics[0].is_store);
        assert_eq!(rec.atomics[1].ordering, "SeqCst");
        assert_eq!(rec.impl_type.as_deref(), Some("Recorder"));
        let pump = fs.iter().find(|f| f.name == "pump").unwrap();
        assert!(pump.uses_condvar);
        assert!(pump.sends_reply);
    }

    #[test]
    fn arithmetic_index_flagged_plain_index_not() {
        let src = r#"
fn pick(&self, i: usize) -> u32 {
    let a = self.chains[i];
    self.chains[i - 1]
}
"#;
        let f = &facts_of(src)[0];
        let idx: Vec<&PanicSite> =
            f.panics.iter().filter(|p| p.kind == PanicKind::Index).collect();
        assert_eq!(idx.len(), 1);
        assert_eq!(idx[0].line, 4);
    }

    #[test]
    fn guard_helper_signature_detected_and_calls_resolve() {
        let src = r#"
impl R {
    fn slots(&self) -> MutexGuard<'_, Slots> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }
}
"#;
        let fs = facts_of(src);
        assert!(fs[0].guard_helper);
        assert_eq!(fs[0].acquires[0].class, "demo::inner");

        let mut helpers = HashMap::new();
        helpers.insert("slots".to_string(), "demo::inner".to_string());
        let caller = r#"
impl R {
    fn bump(&self) {
        let mut g = self.slots();
        g.requests += 1;
        self.other.lock().expect("other lock");
    }
}
"#;
        let fs = extract("x/demo.rs", &lex(caller), &helpers);
        assert_eq!(fs[0].acquires.len(), 2);
        assert_eq!(fs[0].nested.len(), 1);
        assert_eq!(fs[0].nested[0].held, "demo::inner");
        assert_eq!(fs[0].nested[0].class, "other lock");
    }

    #[test]
    fn blocking_sites_capture_held_guards_and_annotations() {
        let src = r#"
fn pump(&self) {
    let q = self.q.lock().expect("job queue");
    let msg = self.rx.recv();
    // block-ok: device latency is the product here
    sleep(Duration::from_millis(2));
}
"#;
        let f = &facts_of(src)[0];
        assert_eq!(f.blocking.len(), 2, "{:?}", f.blocking);
        assert_eq!(f.blocking[0].callee, "recv");
        assert_eq!(f.blocking[0].held, vec!["job queue".to_string()]);
        assert!(!f.blocking[0].suppressed);
        assert_eq!(f.blocking[1].callee, "sleep");
        assert!(f.blocking[1].suppressed);
    }

    #[test]
    fn suppression_annotations_reach_sites() {
        let src = "fn f(&self) {\n    // panic-ok: checked non-empty above\n    self.v.last().unwrap();\n    self.w.first().unwrap();\n}\n";
        let f = &facts_of(src)[0];
        assert_eq!(f.panics.len(), 2);
        assert!(f.panics[0].suppressed);
        assert!(!f.panics[1].suppressed);
    }
}
