//! Per-operator micro-benchmarks: FP vs quantization-aware kernels from
//! `artifacts/micro/`, executed on CPU PJRT with device-resident inputs.
//! CPU timings validate plumbing + relative shapes; the A100 projection
//! for the same ops lives in hw_perf_model.

use zqhero::bench::{bench_seconds, fmt_us, Table};
use zqhero::model::manifest::Manifest;
use zqhero::model::Tensor;
use zqhero::prop::Rng;
use zqhero::runtime::Runtime;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("micro_kernels: run `make artifacts` first");
        return;
    }
    let man = Manifest::load(&dir).unwrap();
    let (d, f) = (man.model.hidden, man.model.ffn);
    let (n, bh, s, dh) = (2048usize, 16 * man.model.heads, man.seq, man.model.head_dim());
    let micro = man.micro.clone();
    let mut rt = Runtime::new(man).unwrap();
    let mut rng = Rng::new(42);

    let f32t = |rng: &mut Rng, shape: Vec<usize>, lo: f32, hi: f32| {
        let numel = shape.iter().product();
        Tensor::f32(shape, rng.vec_f32(numel, lo, hi))
    };
    let i8t = |rng: &mut Rng, shape: Vec<usize>| {
        let numel = shape.iter().product();
        Tensor::i8(shape, rng.vec_i8(numel))
    };
    let scale = |rng: &mut Rng, shape: Vec<usize>| {
        let numel: usize = shape.iter().product();
        Tensor::f32(shape, (0..numel).map(|_| rng.log_uniform(1e-3, 1e-1) as f32).collect())
    };

    // inputs per micro artifact, matching aot.py lower_micro
    let inputs: Vec<(&str, Vec<Tensor>)> = vec![
        ("ln_fp", vec![f32t(&mut rng, vec![n, d], -3.0, 3.0),
                       f32t(&mut rng, vec![d], 0.5, 1.5),
                       f32t(&mut rng, vec![d], -0.5, 0.5)]),
        ("ln_quant", vec![i8t(&mut rng, vec![n, d]), scale(&mut rng, vec![n, 1]),
                          i8t(&mut rng, vec![n, d]), scale(&mut rng, vec![1, d]),
                          f32t(&mut rng, vec![d], 0.5, 1.5),
                          f32t(&mut rng, vec![d], -0.5, 0.5)]),
        ("gemm_fp", vec![f32t(&mut rng, vec![n, d], -2.0, 2.0),
                         f32t(&mut rng, vec![d, d], -0.5, 0.5),
                         f32t(&mut rng, vec![d], -0.5, 0.5)]),
        ("gemm_int8", vec![i8t(&mut rng, vec![n, d]), i8t(&mut rng, vec![d, d]),
                           scale(&mut rng, vec![n, 1]), scale(&mut rng, vec![1, d]),
                           f32t(&mut rng, vec![1, d], -1.0, 1.0)]),
        ("gemm_fp_ffn", vec![f32t(&mut rng, vec![n, d], -2.0, 2.0),
                             f32t(&mut rng, vec![d, f], -0.5, 0.5),
                             f32t(&mut rng, vec![f], -0.5, 0.5)]),
        ("gemm_int8_ffn", vec![i8t(&mut rng, vec![n, d]), i8t(&mut rng, vec![d, f]),
                               scale(&mut rng, vec![n, 1]), scale(&mut rng, vec![1, f]),
                               f32t(&mut rng, vec![1, f], -1.0, 1.0)]),
        ("gelu_fp", vec![f32t(&mut rng, vec![n, f], -4.0, 4.0)]),
        ("gelu_quant", vec![f32t(&mut rng, vec![n, f], -4.0, 4.0),
                            scale(&mut rng, vec![1, f])]),
        ("attn_fp", vec![f32t(&mut rng, vec![bh, s, dh], -1.0, 1.0),
                         f32t(&mut rng, vec![bh, s, dh], -1.0, 1.0),
                         f32t(&mut rng, vec![bh, s, dh], -1.0, 1.0),
                         Tensor::f32(vec![bh, s], vec![1.0; bh * s])]),
        ("attn_int8", vec![i8t(&mut rng, vec![bh, s, dh]), i8t(&mut rng, vec![bh, s, dh]),
                           i8t(&mut rng, vec![bh, s, dh]),
                           Tensor::f32(vec![bh, s], vec![1.0; bh * s]),
                           Tensor::f32(vec![1, 1], vec![1.6e-5]),
                           Tensor::f32(vec![1, 1], vec![1.0 / 255.0]),
                           scale(&mut rng, vec![bh, 1, dh])]),
    ];

    println!("\nmicro-kernel latency (CPU PJRT, device-resident inputs):\n");
    let mut table = Table::new(&["kernel", "p50", "mean", "p95"]);
    let mut times: std::collections::BTreeMap<String, f64> = Default::default();
    for (name, tensors) in &inputs {
        let Some(rel) = micro.get(*name).cloned() else {
            eprintln!("  (skipping {name}: not in manifest)");
            continue;
        };
        let bufs = rt.upload_all(tensors).unwrap();
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        // warm once (compiles)
        rt.run_raw_buffers(&rel, &refs).unwrap();
        let stats = bench_seconds(2, 0.5, || {
            rt.run_raw_buffers(&rel, &refs).unwrap();
        });
        times.insert(name.to_string(), stats.p50_us);
        table.row(vec![
            name.to_string(),
            fmt_us(stats.p50_us),
            fmt_us(stats.mean_us),
            fmt_us(stats.p95_us),
        ]);
    }
    table.print();

    println!("\nFP vs quant pairs (CPU ratios; interpret-mode INT8 is not a");
    println!("TPU/GPU perf proxy — see DESIGN.md §8 — but plumbing + shape hold):");
    for (a, b) in [("ln_fp", "ln_quant"), ("gemm_fp", "gemm_int8"),
                   ("gemm_fp_ffn", "gemm_int8_ffn"), ("gelu_fp", "gelu_quant"),
                   ("attn_fp", "attn_int8")] {
        if let (Some(x), Some(y)) = (times.get(a), times.get(b)) {
            println!("  {a:14} {:>9}  vs  {b:14} {:>9}  ratio {:.2}x",
                     fmt_us(*x), fmt_us(*y), x / y);
        }
    }
}
