"""Build-time Python for the ZeroQuant-HERO reproduction.

This package is compile-path only: it authors the Pallas kernels (L1) and
the JAX encoder (L2), trains the SynGLUE task models, and AOT-lowers
everything to HLO text consumed by the rust runtime (L3).  Nothing in here
runs on the request path.
"""
