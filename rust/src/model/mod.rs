//! Model substrate: tensors, the checkpoint container format, and the
//! typed manifest (the L2->L3 contract).

pub mod container;
pub mod manifest;
pub mod tensor;

pub use container::Container;
pub use manifest::{
    CalibSpec, Manifest, ModeId, ModeSpec, ModelCfg, ModuleGroup, ModulePrecision, ParamSpec,
    PolicyDraft, PolicyId, PolicySpec, Switches, TaskId, TaskSpec,
};
pub use tensor::{DType, Tensor, TensorData};
