//! Engine thread: owns the (non-`Send`) PJRT runtime and serves execution
//! requests over channels — the executor-thread pattern a production GPU
//! server uses.  The coordinator and its worker pool stay fully `Send`.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::model::manifest::Manifest;
use crate::model::tensor::Tensor;
use crate::model::Container;

use super::Runtime;

pub struct InferJob {
    pub task: String,
    pub mode: String,
    pub bucket: usize,
    pub ids: Vec<i32>,
    pub type_ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub reply: Sender<Result<InferDone>>,
}

pub struct InferDone {
    pub logits: Tensor,
    /// device-side execution time (engine-thread measured), microseconds.
    pub exec_us: u64,
}

enum Msg {
    Infer(Box<InferJob>),
    Stop,
}

/// `Send` handle to the engine thread.
pub struct Engine {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine: loads the manifest, uploads every (task, mode)
    /// checkpoint in `preload`, and pre-compiles the executables for the
    /// requested (mode, bucket) pairs so the serving hot path never
    /// compiles.
    pub fn spawn(
        artifacts: PathBuf,
        preload: Vec<(String, String, Container)>,
        precompile: Vec<(String, usize)>,
    ) -> Result<Engine> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("zqhero-engine".into())
            .spawn(move || engine_main(artifacts, preload, precompile, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Engine { tx, join: Some(join) })
    }

    pub fn submit(&self, job: InferJob) -> Result<()> {
        self.tx
            .send(Msg::Infer(Box::new(job)))
            .map_err(|_| anyhow!("engine thread gone"))
    }

    /// Synchronous convenience call (CLI paths, tests).
    pub fn infer_blocking(
        &self,
        task: &str,
        mode: &str,
        bucket: usize,
        ids: Vec<i32>,
        type_ids: Vec<i32>,
        mask: Vec<f32>,
    ) -> Result<InferDone> {
        let (reply, rx) = channel();
        self.submit(InferJob {
            task: task.into(),
            mode: mode.into(),
            bucket,
            ids,
            type_ids,
            mask,
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_main(
    artifacts: PathBuf,
    preload: Vec<(String, String, Container)>,
    precompile: Vec<(String, usize)>,
    rx: Receiver<Msg>,
    ready_tx: Sender<Result<()>>,
) {
    let mut rt = match Manifest::load(&artifacts).and_then(Runtime::new) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let mut init = || -> Result<()> {
        for (task, mode, ckpt) in &preload {
            rt.upload_checkpoint(task, mode, ckpt)?;
        }
        for (mode, bucket) in &precompile {
            rt.model_exe(mode, *bucket)?;
        }
        Ok(())
    };
    if ready_tx.send(init()).is_err() {
        return;
    }

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Stop => break,
            Msg::Infer(job) => {
                let t0 = Instant::now();
                let res = rt
                    .infer(&job.task, &job.mode, job.bucket, &job.ids, &job.type_ids, &job.mask)
                    .map(|logits| InferDone {
                        logits,
                        exec_us: t0.elapsed().as_micros() as u64,
                    });
                let _ = job.reply.send(res);
            }
        }
    }
}
