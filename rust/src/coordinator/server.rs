//! The serving coordinator: bounded admission queue -> dynamic batcher
//! thread -> engine (PJRT) thread -> completion workers.  This is the
//! "end-to-end system" the paper leaves as future work: batched W8A8
//! inference with per-request precision modes and zero Python anywhere.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::Split;
use crate::exec::ThreadPool;
use crate::model::manifest::Manifest;
use crate::model::Container;
use crate::runtime::engine::{Engine, InferJob};

use super::batcher::{Batch, Batcher};
use super::request::{Request, Response, Timing};
use super::stats::Recorder;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
    pub completion_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 1024,
            completion_workers: 4,
        }
    }
}

pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    batcher_join: Option<std::thread::JoinHandle<()>>,
    pub recorder: Arc<Recorder>,
    next_id: AtomicU64,
    seq: usize,
    num_labels: usize,
    pub config: ServerConfig,
}

impl Coordinator {
    /// Load checkpoints for the given (task, mode) pairs, spawn the engine
    /// and batcher, pre-compile every (mode, bucket) executable.
    pub fn start(
        artifacts: std::path::PathBuf,
        pairs: &[(String, String)],
        config: ServerConfig,
    ) -> Result<Coordinator> {
        let manifest = Manifest::load(&artifacts)?;
        let seq = manifest.seq;
        let num_labels = manifest.model.num_labels;
        let buckets = manifest.buckets.clone();

        // load quantized/fp checkpoints from disk
        let mut preload = Vec::new();
        let mut modes_used = std::collections::BTreeSet::new();
        for (task, mode) in pairs {
            let t = manifest.task(task)?;
            let rel = checkpoint_rel(t, mode);
            let path = manifest.path(&rel);
            let ckpt = Container::read_file(&path)
                .with_context(|| {
                    format!("loading checkpoint {path:?} (run `repro quantize` first?)")
                })?
                .reordered(&manifest.mode(mode)?.params)?;
            preload.push((task.clone(), mode.clone(), ckpt));
            modes_used.insert(mode.clone());
        }
        let precompile: Vec<(String, usize)> = modes_used
            .iter()
            .flat_map(|m| buckets.iter().map(move |b| (m.clone(), *b)))
            .collect();

        let engine = Arc::new(Engine::spawn(artifacts, preload, precompile)?);
        let recorder = Arc::new(Recorder::new());
        let pool = ThreadPool::new(config.completion_workers, "zqh-complete");

        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(config.queue_cap);
        let batcher_cfg = config.clone();
        let b_recorder = Arc::clone(&recorder);
        let b_engine = Arc::clone(&engine);
        let man = Arc::new(manifest);
        let b_man = Arc::clone(&man);
        let batcher_join = std::thread::Builder::new()
            .name("zqh-batcher".into())
            .spawn(move || {
                batcher_main(rx, batcher_cfg, b_man, b_engine, b_recorder, pool)
            })
            .context("spawn batcher")?;

        Ok(Coordinator {
            tx: Some(tx),
            batcher_join: Some(batcher_join),
            recorder,
            next_id: AtomicU64::new(0),
            seq,
            num_labels,
            config,
        })
    }

    /// Submit a request; `Err` on backpressure (queue full) or bad input.
    pub fn submit(
        &self,
        task: &str,
        mode: &str,
        ids: Vec<i32>,
        type_ids: Vec<i32>,
    ) -> Result<Receiver<Response>> {
        if ids.len() != self.seq || type_ids.len() != self.seq {
            bail!("request must be exactly seq={} tokens (got {})", self.seq, ids.len());
        }
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            task: task.to_string(),
            mode: mode.to_string(),
            ids,
            type_ids,
            enqueued: Instant::now(),
            reply,
        };
        match self.tx.as_ref().expect("live").try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => Err(anyhow!("admission queue full (backpressure)")),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("coordinator stopped")),
        }
    }

    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    pub fn seq(&self) -> usize {
        self.seq
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the queue; batcher drains and exits
        if let Some(j) = self.batcher_join.take() {
            let _ = j.join();
        }
    }
}

pub fn checkpoint_rel(task: &crate::model::manifest::TaskSpec, mode: &str) -> String {
    if mode == "fp" {
        task.checkpoint.clone()
    } else {
        format!("checkpoints/{}/hero-{}.bin", task.name, mode)
    }
}

fn batcher_main(
    rx: Receiver<Request>,
    config: ServerConfig,
    man: Arc<Manifest>,
    engine: Arc<Engine>,
    recorder: Arc<Recorder>,
    pool: ThreadPool,
) {
    let mut batcher = Batcher::new(config.max_batch, config.max_wait);
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req) {
                    dispatch(batch, &man, &engine, &recorder, &pool);
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                for batch in batcher.drain_all() {
                    dispatch(batch, &man, &engine, &recorder, &pool);
                }
                pool.wait_idle();
                break;
            }
        }
        for batch in batcher.tick(Instant::now()) {
            dispatch(batch, &man, &engine, &recorder, &pool);
        }
    }
}

fn dispatch(
    batch: Batch,
    man: &Arc<Manifest>,
    engine: &Arc<Engine>,
    recorder: &Arc<Recorder>,
    pool: &ThreadPool,
) {
    let seq = man.seq;
    let real = batch.requests.len();
    let bucket = man.bucket_for(real);
    let dispatched = Instant::now();

    let mut ids = Vec::with_capacity(bucket * seq);
    let mut tys = Vec::with_capacity(bucket * seq);
    for r in &batch.requests {
        ids.extend_from_slice(&r.ids);
        tys.extend_from_slice(&r.type_ids);
    }
    ids.resize(bucket * seq, crate::data::PAD);
    tys.resize(bucket * seq, 0);
    let mask = Split::mask_row(&ids);

    let (reply_tx, reply_rx) = channel();
    let job = InferJob {
        task: batch.key.task.clone(),
        mode: batch.key.mode.clone(),
        bucket,
        ids,
        type_ids: tys,
        mask,
        reply: reply_tx,
    };
    if engine.submit(job).is_err() {
        fail_batch(batch, recorder, "engine unavailable");
        return;
    }

    let recorder = Arc::clone(recorder);
    let mode = batch.key.mode.clone();
    let requests = batch.requests;
    pool.spawn(move || {
        let result = reply_rx.recv().map_err(|_| anyhow!("engine dropped reply")).and_then(|r| r);
        match result {
            Ok(done) => {
                let logits = match done.logits.as_f32() {
                    Ok(v) => v.to_vec(),
                    Err(e) => {
                        for r in requests {
                            send_error(&r, &mode, &recorder, &format!("bad logits: {e}"));
                        }
                        return;
                    }
                };
                let nl = logits.len() / bucket;
                recorder.record_batch(&mode, real, done.exec_us);
                for (row, r) in requests.into_iter().enumerate() {
                    let now = Instant::now();
                    let timing = Timing {
                        queue_us: dispatched.duration_since(r.enqueued).as_micros() as u64,
                        exec_us: done.exec_us,
                        total_us: now.duration_since(r.enqueued).as_micros() as u64,
                        batch_real: real,
                        bucket,
                    };
                    recorder.record_request(&mode, timing.total_us, timing.queue_us, false);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        logits: logits[row * nl..(row + 1) * nl].to_vec(),
                        timing,
                        error: None,
                    });
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in requests {
                    send_error(&r, &mode, &recorder, &msg);
                }
            }
        }
    });
}

fn fail_batch(batch: Batch, recorder: &Arc<Recorder>, msg: &str) {
    for r in &batch.requests {
        send_error(r, &batch.key.mode, recorder, msg);
    }
}

fn send_error(r: &Request, mode: &str, recorder: &Recorder, msg: &str) {
    recorder.record_request(mode, 0, 0, true);
    let _ = r.reply.send(Response {
        id: r.id,
        logits: vec![],
        timing: Timing::default(),
        error: Some(msg.to_string()),
    });
}
