//! # zqhero — ZeroQuant-HERO reproduction (rust L3)
//!
//! A hardware-enhanced W8A8 post-training-quantization *system* for
//! BERT-style transformers, reproducing
//! *ZeroQuant-HERO: Hardware-Enhanced Robust Optimized Post-Training
//! Quantization Framework for W8A8 Transformers* (Yao et al., 2023) as a
//! three-layer Rust + JAX + Pallas stack.  This crate is Layer 3:
//!
//! * [`quant`] — the PTQ engine: TWQ/FWQ/SQ schemes, column-wise weight
//!   quantization, and the scale folding (eqs. 20-23, 32) that makes the
//!   hot path division-free;
//! * [`calib`] — the calibration orchestrator (paper §3);
//! * [`runtime`] — PJRT loading/execution of the AOT HLO artifacts with
//!   device-resident weights (Python never runs at request time);
//! * [`coordinator`] — the serving system the paper leaves as future
//!   work: typed request specs, dynamic batching, per-request precision
//!   policies (base mode + per-module overrides + fallback escalation),
//!   backpressure, metrics;
//! * [`evalharness`] — Table 2 + ablation regeneration;
//! * [`perfmodel`] — the analytic A100 roofline behind the paper's
//!   hardware claims;
//! * [`traceflow`] — Figures 1/2 as checkable precision-flow traces;
//! * substrates built from scratch for the offline environment:
//!   [`json`], [`cli`], [`exec`], [`prop`], [`bench`], [`lint`] — the
//!   repo-native static analyses gating the concurrency discipline —
//!   and [`mck`], the schedule-exploring model checker behind the
//!   [`sync`] primitive facade.

pub mod bench;
pub mod calib;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod evalharness;
pub mod exec;
pub mod json;
pub mod lint;
pub mod mck;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod prop;
pub mod quant;
pub mod runtime;
pub mod sync;
pub mod traceflow;
