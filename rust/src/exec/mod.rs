//! Thread-pool executor (tokio is unavailable offline — DESIGN.md §2).
//!
//! A small fixed-size worker pool over an mpsc job queue, with graceful
//! shutdown and panic isolation.  The serving coordinator uses it for
//! the readback completion stage (de-batching + reply dispatch); PJRT
//! execution stays on the dedicated engine thread.
//!
//! Job accounting lives behind one mutex with a condvar, so `wait_idle`
//! parks instead of burning a core on `yield_now`, and `run` ships the
//! panic payload back to the caller instead of silently dropping the
//! reply channel.

use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::thread::JoinHandle;
use crate::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

#[derive(Default)]
struct Counts {
    queued: usize,
    completed: usize,
    panicked: usize,
}

struct Shared {
    counts: Mutex<Counts>,
    idle: Condvar,
}

pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

/// Best-effort text from a panic payload (`panic!` with `&str`/`String`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker job panicked (non-string payload)".to_string()
    }
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let shared =
            Arc::new(Shared { counts: Mutex::new(Counts::default()), idle: Condvar::new() });
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let shared = Arc::clone(&shared);
                crate::sync::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_main(rx, shared))
                    // panic-ok: spawn fails only on OS thread exhaustion at
                    // startup; there is no pool to degrade into yet
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, workers, shared }
    }

    /// Enqueue a job; returns false if the pool is shut down.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        // panic-ok: counts is only touched by this accounting code, which
        // cannot panic while holding it (job panics are caught unlocked)
        self.shared.counts.lock().expect("pool counts").queued += 1;
        self.tx.send(Msg::Run(Box::new(f))).is_ok()
    }

    /// Run a closure on the pool; the receiver yields `Ok(value)` or
    /// `Err(panic message)` if the job panicked — a worker panic is never
    /// silently swallowed into a dropped channel.
    pub fn run<T, F>(&self, f: F) -> Receiver<Result<T, String>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        self.spawn(move || {
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
                Ok(v) => {
                    let _ = tx.send(Ok(v));
                }
                Err(payload) => {
                    let _ = tx.send(Err(panic_message(payload.as_ref())));
                    // propagate so the pool's panic accounting still sees it
                    std::panic::resume_unwind(payload);
                }
            }
        });
        rx
    }

    pub fn pending(&self) -> usize {
        // panic-ok: counts critical sections are panic-free accounting
        let c = self.shared.counts.lock().expect("pool counts");
        c.queued - c.completed
    }

    pub fn completed(&self) -> usize {
        // panic-ok: counts critical sections are panic-free accounting
        self.shared.counts.lock().expect("pool counts").completed
    }

    pub fn panicked(&self) -> usize {
        // panic-ok: counts critical sections are panic-free accounting
        self.shared.counts.lock().expect("pool counts").panicked
    }

    /// Park until every queued job has finished (no spinning).
    pub fn wait_idle(&self) {
        // panic-ok: counts critical sections are panic-free accounting
        let mut c = self.shared.counts.lock().expect("pool counts");
        while c.completed < c.queued {
            // panic-ok: wait() re-acquires the same panic-free lock
            c = self.shared.idle.wait(c).expect("pool counts");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_main(rx: Arc<Mutex<Receiver<Msg>>>, shared: Arc<Shared>) {
    loop {
        let msg = {
            // panic-ok: the receiver lock only guards recv(), which does
            // not panic; a poisoned queue means memory corruption
            let guard = rx.lock().expect("queue poisoned");
            // block-ok: the receiver mutex IS the work handoff — exactly
            // one idle worker holds it while parked in recv(), and peers
            // queue on the lock until a job is taken; nothing else is
            // ever guarded by it
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                // panic-ok: job panics were caught above, unlocked
                let mut c = shared.counts.lock().expect("pool counts");
                if res.is_err() {
                    c.panicked += 1;
                }
                c.completed += 1;
                if c.completed == c.queued {
                    shared.idle.notify_all();
                }
            }
            Ok(Msg::Stop) | Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.spawn(move || {
                sum.fetch_add(i, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
        assert_eq!(pool.completed(), 100);
    }

    #[test]
    fn run_returns_value() {
        let pool = ThreadPool::new(2, "t");
        let rx = pool.run(|| 6 * 7);
        assert_eq!(rx.recv().unwrap().unwrap(), 42);
    }

    #[test]
    fn run_surfaces_panic_to_caller() {
        let pool = ThreadPool::new(2, "t");
        let rx = pool.run(|| -> u32 { panic!("kaboom: divided by cucumber") });
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("kaboom"), "panic message lost: {err}");
        pool.wait_idle();
        assert_eq!(pool.panicked(), 1);
        // pool still healthy
        assert_eq!(pool.run(|| 1 + 1).recv().unwrap().unwrap(), 2);
    }

    #[test]
    fn panics_are_isolated() {
        let pool = ThreadPool::new(2, "t");
        pool.spawn(|| panic!("boom"));
        let rx = pool.run(|| "still alive");
        assert_eq!(rx.recv().unwrap().unwrap(), "still alive");
        pool.wait_idle();
        assert_eq!(pool.panicked(), 1);
    }

    #[test]
    fn wait_idle_parks_until_done() {
        let pool = ThreadPool::new(2, "t");
        for _ in 0..8 {
            pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        }
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.completed(), 8);
        pool.wait_idle(); // idempotent when already idle
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2, "t");
        for _ in 0..10 {
            pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        }
        drop(pool); // must not hang or panic
    }
}
