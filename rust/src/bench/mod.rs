//! Bench harness (criterion is unavailable offline — DESIGN.md §2):
//! warmup + timed iterations with mean/percentile reporting, plus table
//! formatting shared by the paper-reproduction benches.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl Stats {
    pub fn from_samples_us(mut v: Vec<f64>) -> Stats {
        assert!(!v.is_empty());
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| {
            let idx = ((v.len() - 1) as f64 * p).round() as usize;
            v[idx]
        };
        Stats {
            iters: v.len(),
            mean_us: v.iter().sum::<f64>() / v.len() as f64,
            p50_us: pick(0.50),
            p95_us: pick(0.95),
            p99_us: pick(0.99),
            min_us: v[0],
            max_us: v[v.len() - 1],
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    Stats::from_samples_us(samples)
}

/// Adaptive: run for at least `min_time_s` seconds, at least 5 iters.
pub fn bench_seconds<F: FnMut()>(warmup: usize, min_time_s: f64, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_time_s || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    Stats::from_samples_us(samples)
}

// ----------------------------------------------------- open-loop driver

/// One cell of an open-loop overload run, in the DESIGN.md §5.8 ledger
/// vocabulary shared by `BENCH_overload*.json`: `admitted` counts the
/// total *offered* arrivals at the admission gate (including those shed
/// there — the acceptance ledger is
/// `admitted = completed + shed + expired + failed`, reconciling
/// exactly), while the recorder's per-policy `requests` counter holds
/// only `admitted - shed` (what actually entered the queue).  `shed`
/// folds both shapes of backpressure together: the synchronous
/// `SubmitError::Busy` a local admission gate raises and the terminal
/// `busy` response a remote tier sends after the fact (DESIGN.md §5.14)
/// — same outcome class, different transport.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub admitted: usize,
    pub completed: usize,
    pub shed: usize,
    pub expired: usize,
    /// Replica/node failures surfaced as typed `failed` responses (0 in
    /// fault-free runs; the chaos drivers assert on it).
    pub failed: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub wall_s: f64,
}

impl OpenLoopReport {
    /// Completed-request throughput (expired/shed are not goodput).
    pub fn goodput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    /// The §5.8 accounting identity; `open_loop_burst` guarantees it by
    /// construction (every non-shed submission yields exactly one
    /// terminal reply), so a `false` here is a coordinator bug.
    pub fn reconciles(&self) -> bool {
        self.admitted == self.completed + self.shed + self.expired + self.failed
    }
}

/// Fire `arrivals` paced submissions at `rate` req/s independent of
/// completions (open loop), then harvest every outcome.  Shared by
/// `repro serve-bench --overload` and the `e2e_serving` overload sweep
/// so the CLI smoke and the bench trajectory measure the same thing.
/// Generic over [`Admission`](crate::coordinator::Admission), so the
/// same driver loads a single-process coordinator or a multi-host front
/// end.  `Err` only on a transport-level failure (dead reply channel, a
/// response outside the typed outcome classes, or a non-busy submit
/// error).
#[allow(clippy::too_many_arguments)]
pub fn open_loop_burst<A: crate::coordinator::Admission>(
    adm: &A,
    task: &str,
    policy: &str,
    rows: &[(Vec<i32>, Vec<i32>)],
    arrivals: usize,
    rate: f64,
    deadline: std::time::Duration,
) -> anyhow::Result<OpenLoopReport> {
    let groups = [(task.to_string(), policy.to_string())];
    open_loop_burst_groups(adm, &groups, rows, arrivals, rate, deadline)
}

/// [`open_loop_burst`] over several (task, policy) groups, round-robined
/// per arrival.  Multi-host scaling needs this shape: one group pins to
/// one engine node while it has requests in flight, so a single-group
/// burst can never exercise more than one node — concurrent groups are
/// what `NodeDispatch` spreads across the fleet (DESIGN.md §5.14).
#[allow(clippy::too_many_arguments)]
pub fn open_loop_burst_groups<A: crate::coordinator::Admission>(
    adm: &A,
    groups: &[(String, String)],
    rows: &[(Vec<i32>, Vec<i32>)],
    arrivals: usize,
    rate: f64,
    deadline: std::time::Duration,
) -> anyhow::Result<OpenLoopReport> {
    use anyhow::Context;
    anyhow::ensure!(!groups.is_empty(), "open-loop burst needs at least one group");
    let interval = std::time::Duration::from_secs_f64(1.0 / rate.max(1.0));
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    let mut shed = 0usize;
    for i in 0..arrivals {
        let next = t0 + interval.mul_f64(i as f64);
        if let Some(wait) = next.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        let (ids, tys) = rows[i % rows.len()].clone();
        let (task, policy) = &groups[i % groups.len()];
        let spec = crate::coordinator::RequestSpec::task(task)
            .policy(policy)
            .ids(ids)
            .type_ids(tys)
            .deadline(deadline);
        match adm.submit_spec(spec) {
            Ok(rx) => rxs.push(rx),
            Err(e) if e.is_busy() => shed += 1,
            Err(e) => anyhow::bail!("burst submit failed: {e}"),
        }
    }
    let (mut completed, mut expired, mut failed) = (0usize, 0usize, 0usize);
    let mut lat = Vec::new();
    for rx in rxs {
        let resp = rx.recv().context("burst response channel closed")?;
        if resp.busy {
            // remote-tier shed: backpressure arrived as a terminal
            // response instead of a SubmitError (same ledger class)
            shed += 1;
        } else if resp.expired {
            expired += 1;
        } else if resp.failed {
            failed += 1;
        } else {
            anyhow::ensure!(resp.error.is_none(), "burst request failed: {:?}", resp.error);
            completed += 1;
            lat.push(resp.timing.total_us as f64);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |p: f64| -> f64 {
        if lat.is_empty() {
            return 0.0;
        }
        lat[((lat.len() - 1) as f64 * p) as usize] / 1e3
    };
    Ok(OpenLoopReport {
        admitted: arrivals,
        completed,
        shed,
        expired,
        failed,
        p50_ms: pick(0.50),
        p99_ms: pick(0.99),
        wall_s,
    })
}

/// Closed-loop load driver: keep up to `concurrency` requests of one
/// (task, policy) route in flight until `requests` complete, backing off
/// on admission backpressure (another concurrent route may own the
/// queue) with a 30 s no-progress stall guard.  Returns per-request
/// end-to-end latencies (µs) in completion order.  The one driver shared
/// by `serve-bench` and the e2e serving sweeps, so the CLI smoke and the
/// bench trajectories measure identical serving behavior (same
/// backpressure and stall semantics) — the closed-loop sibling of
/// [`open_loop_burst`].  Generic over admission like its sibling.
pub fn closed_loop<A: crate::coordinator::Admission>(
    adm: &A,
    task: &str,
    policy: &crate::coordinator::PolicyRef,
    rows: &[(Vec<i32>, Vec<i32>)],
    requests: usize,
    concurrency: usize,
) -> anyhow::Result<Vec<f64>> {
    use anyhow::Context;
    let mut inflight = std::collections::VecDeque::new();
    let (mut submitted, mut done) = (0usize, 0usize);
    let mut last_progress = Instant::now();
    let mut lat = Vec::with_capacity(requests);
    while done < requests {
        while submitted < requests && inflight.len() < concurrency {
            let (ids, tys) = rows[submitted % rows.len()].clone();
            let spec = crate::coordinator::RequestSpec::task(task)
                .policy_ref(policy.clone())
                .ids(ids)
                .type_ids(tys);
            match adm.submit_spec(spec) {
                Ok(rx) => {
                    inflight.push_back(rx);
                    submitted += 1;
                    last_progress = Instant::now();
                }
                Err(_) => break, // backpressure: drain first
            }
        }
        if let Some(rx) = inflight.pop_front() {
            let resp = rx.recv().context("response channel closed")?;
            anyhow::ensure!(resp.error.is_none(), "request failed: {:?}", resp.error);
            lat.push(resp.timing.total_us as f64);
            done += 1;
            last_progress = Instant::now();
        } else {
            // backpressured with nothing of ours in flight: another
            // route owns the queue — wait, but not forever (submit
            // errors are also how a stopped coordinator presents)
            anyhow::ensure!(
                last_progress.elapsed() < std::time::Duration::from_secs(30),
                "no progress for 30s ({done}/{requests} done) — coordinator stalled or stopped"
            );
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
    Ok(lat)
}

/// Sum a recorder snapshot's padding ledger into (real tokens, padded
/// token slots) — the one definition both `serve-bench --mixed-length`
/// (BENCH_seq_buckets_smoke.json) and the e2e seq-bucket sweep
/// (BENCH_seq_buckets.json) report, so the two files' token semantics
/// cannot drift apart.
pub fn padding_totals(
    snap: &std::collections::BTreeMap<String, crate::coordinator::PolicyStats>,
) -> (u64, u64) {
    (
        snap.values().map(|s| s.real_tokens).sum(),
        snap.values().map(|s| s.padded_tokens).sum(),
    )
}

// ------------------------------------------------------------- formatting

/// Simple monospace table printer for the paper-reproduction benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn fmt_us(us: f64) -> String {
    if us >= 1e6 {
        format!("{:.2}s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{us:.0}us")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples_us((1..=100).map(|i| i as f64).collect());
        assert!(s.min_us <= s.p50_us);
        assert!(s.p50_us <= s.p95_us);
        assert!(s.p95_us <= s.p99_us);
        assert!(s.p99_us <= s.max_us);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench(2, 10, || n += 1);
        assert_eq!(s.iters, 10);
        assert_eq!(n, 12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(vec!["xxxx".into(), "1".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_us(500.0), "500us");
        assert_eq!(fmt_us(1500.0), "1.50ms");
        assert_eq!(fmt_us(2.5e6), "2.50s");
    }
}
