"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes/scale regimes; int8 outputs are compared exactly
(kernel and oracle are written with bit-identical op sequences), f32 outputs
with tight tolerances.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ln_quant, ln_quant_embed, twq_quantize,
    gemm_twq_to_i8, gemm_twq_to_f32, gemm_folded_to_i8, gemm_folded_to_f32,
    gelu_quant, gelu_fp, softmax_quant, attention_quant,
)
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

DIMS = st.sampled_from([8, 16, 32, 64, 128])
TOKENS = st.sampled_from([4, 8, 32, 64, 128])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)
HSET = settings(max_examples=12, deadline=None)


def rng_f32(seed, shape, lo=-4.0, hi=4.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, size=shape), jnp.float32)


def rng_i8(seed, shape):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.integers(-127, 128, size=shape), jnp.int8)


def rng_scale(seed, shape, lo=1e-3, hi=0.2):
    r = np.random.default_rng(seed)
    return jnp.asarray(np.exp(r.uniform(np.log(lo), np.log(hi), size=shape)), jnp.float32)


# ---------------------------------------------------------------- TWQ


@HSET
@given(n=TOKENS, d=DIMS, seed=SEEDS)
def test_twq_quantize(n, d, seed):
    x = rng_f32(seed, (n, d))
    q, s = twq_quantize(x)
    qr, sr = ref.twq_quantize(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)


def test_twq_roundtrip_error_bound():
    x = rng_f32(0, (32, 64))
    q, s = twq_quantize(x)
    recon = np.asarray(q, np.float32) * np.asarray(s)
    err = np.abs(recon - np.asarray(x))
    # round-to-nearest: |err| <= scale/2 per token
    assert (err <= np.asarray(s) / 2 + 1e-6).all()


def test_twq_zero_input():
    q, s = twq_quantize(jnp.zeros((4, 16), jnp.float32))
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(s) > 0)  # floor guard, no NaN


# ---------------------------------------------------------------- LN^quant


@HSET
@given(n=TOKENS, d=DIMS, seed=SEEDS,
       a_q=st.booleans(), b_q=st.booleans(), out_q=st.booleans())
def test_ln_quant_all_variants(n, d, seed, a_q, b_q, out_q):
    gamma = rng_f32(seed + 1, (d,), 0.5, 1.5)
    beta = rng_f32(seed + 2, (d,), -0.5, 0.5)
    if a_q:
        a = rng_i8(seed + 3, (n, d))
        a_scale = rng_scale(seed + 4, (n, 1))
    else:
        a = rng_f32(seed + 3, (n, d))
        a_scale = None
    if b_q:
        b = rng_i8(seed + 5, (n, d))
        b_scale = rng_scale(seed + 6, (1, d))
    else:
        b = rng_f32(seed + 5, (n, d))
        b_scale = None

    got = ln_quant(a, b, gamma, beta, a_scale=a_scale, b_scale=b_scale,
                   quantize_out=out_q)
    want = ref.ln_quant(a, b, gamma.reshape(1, d), beta.reshape(1, d),
                        a_scale=a_scale, b_scale=b_scale, quantize_out=out_q)
    if out_q:
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6)
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@HSET
@given(n=TOKENS, d=DIMS, seed=SEEDS, t_q=st.booleans())
def test_ln_quant_embed(n, d, seed, t_q):
    gamma = rng_f32(seed + 1, (d,), 0.5, 1.5)
    beta = rng_f32(seed + 2, (d,), -0.5, 0.5)
    x_pb = rng_f32(seed + 3, (n, d), -1, 1)
    if t_q:
        x_t = rng_i8(seed + 4, (n, d))
        t_scale = rng_scale(seed + 5, (n, 1))
    else:
        x_t = rng_f32(seed + 4, (n, d))
        t_scale = None
    got = ln_quant_embed(x_t, x_pb, gamma, beta, t_scale=t_scale)
    want = ref.ln_quant_embed(x_t, x_pb, gamma.reshape(1, d), beta.reshape(1, d),
                              t_scale=t_scale)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6)


# ---------------------------------------------------------------- GeMM^quant


@HSET
@given(n=TOKENS, k=DIMS, m=DIMS, seed=SEEDS)
def test_gemm_twq_to_i8(n, k, m, seed):
    x = rng_i8(seed, (n, k))
    w = rng_i8(seed + 1, (k, m))
    xs = rng_scale(seed + 2, (n, 1))
    ws = rng_scale(seed + 3, (1, m), 1e-4, 1e-2)
    b = rng_f32(seed + 4, (1, m), -2, 2)
    got = gemm_twq_to_i8(x, w, xs, ws, b)
    want = ref.gemm_twq_to_i8(x, w, xs, ws, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@HSET
@given(n=TOKENS, k=DIMS, m=DIMS, seed=SEEDS)
def test_gemm_twq_to_f32(n, k, m, seed):
    x = rng_i8(seed, (n, k))
    w = rng_i8(seed + 1, (k, m))
    xs = rng_scale(seed + 2, (n, 1))
    ws = rng_scale(seed + 3, (1, m), 1e-4, 1e-2)
    b = rng_f32(seed + 4, (1, m), -2, 2)
    got = gemm_twq_to_f32(x, w, xs, ws, b)
    want = ref.gemm_twq_to_f32(x, w, xs, ws, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@HSET
@given(n=TOKENS, k=DIMS, m=DIMS, seed=SEEDS)
def test_gemm_folded_to_i8(n, k, m, seed):
    x = rng_i8(seed, (n, k))
    w = rng_i8(seed + 1, (k, m))
    ws = rng_scale(seed + 2, (1, m), 1e-4, 1e-2)
    b = rng_f32(seed + 3, (1, m), -2, 2)
    got = gemm_folded_to_i8(x, w, ws, b)
    want = ref.gemm_folded_to_i8(x, w, ws, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@HSET
@given(n=TOKENS, k=DIMS, m=DIMS, seed=SEEDS)
def test_gemm_folded_to_f32(n, k, m, seed):
    x = rng_i8(seed, (n, k))
    w = rng_i8(seed + 1, (k, m))
    ws = rng_scale(seed + 2, (1, m), 1e-4, 1e-2)
    b = rng_f32(seed + 3, (1, m), -2, 2)
    got = gemm_folded_to_f32(x, w, ws, b)
    want = ref.gemm_folded_to_f32(x, w, ws, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_gemm_int32_accumulation_no_overflow_path():
    # worst case: all +-127 over the largest contraction in the model (ffn=512)
    n, k, m = 8, 512, 16
    x = jnp.full((n, k), 127, jnp.int8)
    w = jnp.full((k, m), -127, jnp.int8)
    ws = jnp.full((1, m), 1e-6, jnp.float32)
    b = jnp.zeros((1, m), jnp.float32)
    got = gemm_folded_to_f32(x, w, ws, b)
    want = ref.gemm_folded_to_f32(x, w, ws, b)  # -127*127*512 = -8258048 fits i32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------- GELU^quant


@HSET
@given(n=TOKENS, f=DIMS, seed=SEEDS)
def test_gelu_quant(n, f, seed):
    x = rng_f32(seed, (n, f), -6, 6)
    sa = rng_scale(seed + 1, (1, f))
    got = gelu_quant(x, sa)
    want = ref.gelu_quant(x, sa)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@HSET
@given(n=TOKENS, f=DIMS, seed=SEEDS)
def test_gelu_fp(n, f, seed):
    x = rng_f32(seed, (n, f), -6, 6)
    got = gelu_fp(x)
    want = ref.gelu(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- Softmax^quant


@HSET
@given(r=TOKENS, n=DIMS, seed=SEEDS)
def test_softmax_quant(r, n, seed):
    a = rng_f32(seed, (r, n), -8, 8)
    sp = 1.0 / 255.0
    got = softmax_quant(a, sp)
    want = ref.softmax_quant(a, sp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_softmax_quant_range():
    a = rng_f32(3, (16, 32), -8, 8)
    q = np.asarray(softmax_quant(a, 1.0 / 255.0))
    assert q.min() >= -128 and q.max() <= 127
    # dequantized rows still ~sum to 1
    p = (q.astype(np.float32) + 128) / 255.0
    np.testing.assert_allclose(p.sum(axis=-1), 1.0, atol=0.15)


# ---------------------------------------------------------------- attention


@HSET
@given(bh=st.sampled_from([1, 2, 4, 8]), n=st.sampled_from([16, 32, 64, 128]),
       dh=st.sampled_from([16, 32]), seed=SEEDS, frac=st.floats(0.25, 1.0))
def test_attention_quant(bh, n, dh, seed, frac):
    q = rng_i8(seed, (bh, n, dh))
    k = rng_i8(seed + 1, (bh, n, dh))
    v = rng_i8(seed + 2, (bh, n, dh))
    valid = max(1, int(n * frac))
    mask = np.zeros((bh, n), np.float32)
    mask[:, :valid] = 1.0
    mask = jnp.asarray(mask)
    qk_scale = 0.02 * 0.02 / np.sqrt(dh)
    sp = 1.0 / 255.0
    pv = rng_scale(seed + 3, (bh, 1, dh), 1e-3, 1e-1)
    got = attention_quant(q, k, v, mask, qk_scale, sp, pv)
    want = ref.attention_quant(q, k, v, mask, qk_scale, sp, pv)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_attention_quant_masked_keys_do_not_contribute():
    # identical q/k/v except in masked region -> identical outputs
    bh, n, dh = 2, 32, 16
    q = rng_i8(0, (bh, n, dh)); k1 = np.asarray(rng_i8(1, (bh, n, dh))).copy()
    v1 = np.asarray(rng_i8(2, (bh, n, dh))).copy()
    k2, v2 = k1.copy(), v1.copy()
    k2[:, 16:, :] = 99 - k2[:, 16:, :]
    v2[:, 16:, :] = 99 - v2[:, 16:, :]
    mask = np.zeros((bh, n), np.float32); mask[:, :16] = 1.0
    args = (jnp.asarray(mask), 1e-4, 1.0 / 255.0, jnp.full((bh, 1, dh), 0.05, jnp.float32))
    o1 = attention_quant(q, jnp.asarray(k1), jnp.asarray(v1), *args)
    o2 = attention_quant(q, jnp.asarray(k2), jnp.asarray(v2), *args)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_attention_quant_vs_fp_reference_close():
    """Dequantized INT8 attention must approximate FP attention."""
    bh, n, dh = 4, 64, 32
    r = np.random.default_rng(7)
    qf = r.normal(0, 1, (bh, n, dh)).astype(np.float32)
    kf = r.normal(0, 1, (bh, n, dh)).astype(np.float32)
    vf = r.normal(0, 1, (bh, n, dh)).astype(np.float32)
    mask = jnp.ones((bh, n), jnp.float32)

    sq = float(np.abs(qf).max() / 127); sk = float(np.abs(kf).max() / 127)
    sv = float(np.abs(vf).max() / 127)
    qi = jnp.asarray(np.clip(np.round(qf / sq), -127, 127), jnp.int8)
    ki = jnp.asarray(np.clip(np.round(kf / sk), -127, 127), jnp.int8)
    vi = jnp.asarray(np.clip(np.round(vf / sv), -127, 127), jnp.int8)

    fp = ref.attention_fp(jnp.asarray(qf), jnp.asarray(kf), jnp.asarray(vf),
                          mask, 1.0 / np.sqrt(dh))
    s_attn = np.maximum(np.abs(np.asarray(fp)).max(axis=(0, 1)), 1e-6) / 127.0
    sp = 1.0 / 255.0
    pv = jnp.asarray((sp * sv / s_attn)[None, None, :], jnp.float32)
    pv = jnp.broadcast_to(pv, (bh, 1, dh))
    qi8 = attention_quant(qi, ki, vi, mask, sq * sk / np.sqrt(dh), sp, pv)
    deq = np.asarray(qi8, np.float32) * s_attn[None, None, :]
    err = np.abs(deq - np.asarray(fp))
    # int8 end-to-end attention should track FP within a few quant steps
    assert np.median(err) < 0.05, np.median(err)
    assert err.max() < 0.25, err.max()
