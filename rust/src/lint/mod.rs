//! herolint — repo-native static analysis for the serving spine
//! (DESIGN.md §5.11).
//!
//! loom and clippy-with-custom-lints are unavailable offline, so — in
//! the same spirit as `prop::forall` — the race/deadlock/panic
//! discipline the concurrent modules rely on is checked by this
//! dependency-free pass instead: a lightweight lexer ([`lexer`]), a
//! per-function fact extractor ([`facts`]), and five rules tuned to
//! this codebase ([`rules`]): lock-order cycles, under-ordered atomics
//! in cross-thread handshakes, panic paths in serving modules, the
//! Recorder ledger identity, and lock guards held across blocking
//! calls.  The dynamic complement — heromck ([`crate::mck`]) — explores
//! real schedules over the same spine and cross-checks its runtime
//! lock-order witness against the static `lock_edges` reported here.
//!
//! Entry points: [`lint_sources`] for in-memory `(path, source)` pairs
//! (fixtures, tests) and [`lint_tree`] for a source directory; the
//! `lint` CLI subcommand and the `scripts/ci.sh` gate sit on top.

pub mod facts;
pub mod lexer;
pub mod rules;

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

pub use rules::{Analysis, Finding, LockEdge};

use crate::json::{self, Value};

/// Full lint result for one run.
pub struct Report {
    /// Root the relative paths in findings are resolved against.
    pub root: String,
    pub analysis: Analysis,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.analysis.findings.is_empty()
    }

    /// The CLI exit-status gate, shared by the `--json` and human output
    /// paths of `repro lint`: `Err` on any unsuppressed finding, so both
    /// modes exit nonzero identically (CI keys off the status, not the
    /// format).
    pub fn gate(&self) -> Result<()> {
        anyhow::ensure!(
            self.clean(),
            "{} unsuppressed lint finding(s)",
            self.analysis.findings.len()
        );
        Ok(())
    }

    /// Human-readable report: findings grouped by rule, then the
    /// observed lock order (the cross-referenced edge list that
    /// documents the discipline the checker enforces).
    pub fn render(&self) -> String {
        let a = &self.analysis;
        let mut out = String::new();
        out.push_str(&format!(
            "herolint: {} files, {} functions — {} finding(s), {} suppressed (panic-ok {}, relaxed-ok {}, block-ok {})\n",
            a.files,
            a.functions,
            a.findings.len(),
            a.suppressed_panic + a.suppressed_relaxed + a.suppressed_block,
            a.suppressed_panic,
            a.suppressed_relaxed,
            a.suppressed_block,
        ));
        for rule in [
            rules::RULE_LOCK_ORDER,
            rules::RULE_ATOMIC,
            rules::RULE_PANIC,
            rules::RULE_LEDGER,
            rules::RULE_HOLD_BLOCKING,
        ] {
            let of_rule: Vec<&Finding> =
                a.findings.iter().filter(|f| f.rule == rule).collect();
            if of_rule.is_empty() {
                continue;
            }
            out.push_str(&format!("\n[{}] {} finding(s)\n", rule, of_rule.len()));
            for f in of_rule {
                if f.file.is_empty() {
                    out.push_str(&format!("  {}\n", f.message));
                } else {
                    out.push_str(&format!("  {}:{}: {}\n", f.file, f.line, f.message));
                }
            }
        }
        if !a.edges.is_empty() {
            out.push_str("\nobserved lock order (acquire left before right):\n");
            for e in &a.edges {
                let via = e.via.as_ref().map(|v| format!(" via {}()", v)).unwrap_or_default();
                out.push_str(&format!(
                    "  `{}` -> `{}`  ({}:{}{})\n",
                    e.from, e.to, e.file, e.line, via
                ));
            }
        }
        out
    }

    /// Machine-readable report for CI trend tooling (`lint --json`).
    pub fn to_json(&self) -> Value {
        let a = &self.analysis;
        let findings: Vec<Value> = a
            .findings
            .iter()
            .map(|f| {
                json::obj(vec![
                    ("rule", json::s(f.rule)),
                    ("file", json::s(&f.file)),
                    ("line", json::num(f.line as f64)),
                    ("message", json::s(&f.message)),
                ])
            })
            .collect();
        let edges: Vec<Value> = a
            .edges
            .iter()
            .map(|e| {
                json::obj(vec![
                    ("from", json::s(&e.from)),
                    ("to", json::s(&e.to)),
                    ("file", json::s(&e.file)),
                    ("line", json::num(e.line as f64)),
                    (
                        "via",
                        e.via.as_ref().map(|v| json::s(v)).unwrap_or(Value::Null),
                    ),
                ])
            })
            .collect();
        json::obj(vec![
            ("root", json::s(&self.root)),
            ("files", json::num(a.files as f64)),
            ("functions", json::num(a.functions as f64)),
            (
                "suppressed",
                json::obj(vec![
                    ("panic_ok", json::num(a.suppressed_panic as f64)),
                    ("relaxed_ok", json::num(a.suppressed_relaxed as f64)),
                    ("block_ok", json::num(a.suppressed_block as f64)),
                ]),
            ),
            ("findings", Value::Array(findings)),
            ("lock_edges", Value::Array(edges)),
        ])
    }
}

/// Lint in-memory sources; `(relative_path, source)` pairs.
pub fn lint_sources(files: &[(String, String)]) -> Report {
    Report {
        root: "<memory>".to_string(),
        analysis: rules::analyze(files),
    }
}

/// Lint every `.rs` file under `root` (deterministic order).
pub fn lint_tree(root: &Path) -> Result<Report> {
    let mut files: Vec<(String, String)> = Vec::new();
    collect(root, root, &mut files)
        .with_context(|| format!("walking {}", root.display()))?;
    files.sort_by(|a, b| a.0.cmp(&b.0));
    anyhow::ensure!(
        !files.is_empty(),
        "no .rs files under {} — wrong --src root?",
        root.display()
    );
    Ok(Report {
        root: root.display().to_string(),
        analysis: rules::analyze(&files),
    })
}

fn collect(root: &Path, dir: &Path, out: &mut Vec<(String, String)>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("read_dir {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let src =
                fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
            out.push((rel, src));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_shape() {
        let files = vec![(
            "coordinator/demo.rs".to_string(),
            "fn hot(&self) { self.m.get(&k).unwrap(); }\n".to_string(),
        )];
        let rep = lint_sources(&files);
        assert!(!rep.clean());
        let v = rep.to_json();
        let findings = v.get("findings").and_then(|f| f.as_array()).unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(
            findings[0].get("rule").and_then(|r| r.as_str()),
            Some("panic-path")
        );
        assert_eq!(findings[0].get("line").and_then(|l| l.as_usize()), Some(1));
        // round-trips through the in-repo parser
        let text = json::to_string_pretty(&v);
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("files").and_then(|f| f.as_usize()), Some(1));
    }

    #[test]
    fn gate_fails_on_findings_and_passes_clean() {
        // the same gate backs `repro lint` and `repro lint --json`: a
        // finding-bearing report must be an Err (nonzero exit) in both
        let dirty = lint_sources(&[(
            "coordinator/demo.rs".to_string(),
            "fn hot(&self) { self.m.get(&k).unwrap(); }\n".to_string(),
        )]);
        let err = dirty.gate().expect_err("findings must gate the exit status");
        assert!(err.to_string().contains("1 unsuppressed lint finding"));

        let clean = lint_sources(&[(
            "coordinator/demo.rs".to_string(),
            "fn cold(&self) -> usize { 1 }\n".to_string(),
        )]);
        assert!(clean.clean());
        clean.gate().expect("clean tree must gate Ok");
    }

    #[test]
    fn render_mentions_rule_and_lock_order_section() {
        let files = vec![(
            "x/demo.rs".to_string(),
            r#"
impl P {
    fn one(&self) {
        let a = self.a.lock().expect("lock A");
        let b = self.b.lock().expect("lock B");
    }
}
"#
            .to_string(),
        )];
        let rep = lint_sources(&files);
        assert!(rep.clean());
        let text = rep.render();
        assert!(text.contains("observed lock order"));
        assert!(text.contains("`lock A` -> `lock B`"));
    }
}
