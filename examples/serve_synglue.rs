//! END-TO-END DRIVER (DESIGN.md "System" experiment): serve batched
//! SynGLUE requests through the full coordinator stack — admission ->
//! dynamic batcher -> PJRT engine (INT8 artifacts) -> completion — and
//! report latency percentiles, throughput, mean batch size AND online
//! accuracy per precision mode.  This is the "end-to-end system
//! performance measurement" the paper explicitly leaves as future work.
//!
//!     cargo run --release --example serve_synglue [requests-per-pair]

use std::collections::VecDeque;
use std::time::Duration;

use anyhow::{Context, Result};
use zqhero::bench::Table;
use zqhero::coordinator::{Coordinator, RequestSpec, ServerConfig};
use zqhero::data::{Labels, Split};
use zqhero::evalharness as eh;
use zqhero::metrics;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::Runtime;

const TASKS: [&str; 3] = ["sst2", "mrpc", "cola"];
const MODES: [&str; 3] = ["fp", "m1", "m3"];

fn main() -> Result<()> {
    let requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(192);
    let dir = std::path::PathBuf::from("artifacts");

    // ---- offline PTQ prep (calibrate + quantize once per task/mode)
    {
        let mut rt = Runtime::new(Manifest::load(&dir)?)?;
        for t in TASKS {
            let task = rt.manifest.task(t)?.clone();
            for m in MODES {
                if m != "fp" {
                    let rel = task.checkpoint_rel(m);
                    if !rt.manifest.path(&rel).exists() {
                        eprintln!("[prep] quantizing {t}/{m}...");
                        let hist = eh::ensure_calibration(&mut rt, &task, 100, false)?;
                        eh::quantize_task(&mut rt, &task, m, &hist, 100.0, None)?;
                    }
                }
            }
        }
    }

    // ---- start the serving stack
    let pairs: Vec<(String, String)> = TASKS
        .iter()
        .flat_map(|t| MODES.iter().map(move |m| (t.to_string(), m.to_string())))
        .collect();
    let config = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(4),
        queue_cap: 512,
        completion_workers: 4,
        ..ServerConfig::default()
    };
    eprintln!("[serve] starting coordinator: {} (task,mode) pairs, max_batch={}, max_wait={:?}",
              pairs.len(), config.max_batch, config.max_wait);
    let coord = Coordinator::start(dir.clone(), &pairs, config)?;

    // ---- load payloads + labels
    let man = Manifest::load(&dir)?;
    let mut table = Table::new(&[
        "task", "mode", "reqs", "thr req/s", "p50 ms", "p95 ms", "metric", "value",
    ]);
    let mut per_mode_metric: Vec<(String, String, f64, f64)> = Vec::new();

    for t in TASKS {
        let task = man.task(t)?;
        let split = Split::load(&man, task, "dev")?;
        let n = requests.min(split.len());

        for m in MODES {
            // closed-loop: keep up to 48 requests in flight
            let t0 = std::time::Instant::now();
            let mut inflight: VecDeque<(usize, std::sync::mpsc::Receiver<_>)> = VecDeque::new();
            let mut preds = vec![0i32; n];
            let mut lat_us: Vec<f64> = Vec::with_capacity(n);
            let mut submitted = 0;
            let mut done = 0;
            while done < n {
                while submitted < n && inflight.len() < 48 {
                    let (ids, tys) = split.row(submitted);
                    let spec =
                        RequestSpec::task(t).mode(m).ids(ids.to_vec()).type_ids(tys.to_vec());
                    match coord.submit(spec) {
                        Ok(rx) => {
                            inflight.push_back((submitted, rx));
                            submitted += 1;
                        }
                        Err(_) => break, // backpressure
                    }
                }
                let (idx, rx) = inflight.pop_front().context("inflight empty")?;
                let resp = rx.recv()?;
                anyhow::ensure!(resp.error.is_none(), "{:?}", resp.error);
                lat_us.push(resp.timing.total_us as f64);
                let lg = &resp.logits;
                preds[idx] = if task.classes == 0 {
                    0
                } else {
                    let mut bi = 0;
                    for c in 1..task.classes {
                        if lg[c] > lg[bi] {
                            bi = c;
                        }
                    }
                    bi as i32
                };
                done += 1;
            }
            let wall = t0.elapsed().as_secs_f64();
            lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let pick = |p: f64| lat_us[((lat_us.len() - 1) as f64 * p) as usize] / 1e3;

            // online accuracy
            let metric_name = &task.metrics[0];
            let value = match &split.labels {
                Labels::Class(ls) => {
                    let ls = &ls[..n];
                    metrics::compute(metric_name, &metrics::MetricInput::Class {
                        preds: &preds,
                        labels: ls,
                    })
                }
                Labels::Score(_) => f64::NAN,
            };
            per_mode_metric.push((t.to_string(), m.to_string(), value, wall));
            table.row(vec![
                t.into(),
                eh::mode_label(m),
                n.to_string(),
                format!("{:.1}", n as f64 / wall),
                format!("{:.1}", pick(0.50)),
                format!("{:.1}", pick(0.95)),
                metric_name.clone(),
                format!("{:.4}", value),
            ]);
        }
    }

    println!("\n== serve_synglue: end-to-end serving (batched, W8A8, no python) ==");
    table.print();
    println!("\n== coordinator internal metrics ==");
    print!("{}", coord.recorder.render());

    // accuracy sanity: quantized modes should track fp online accuracy
    for t in TASKS {
        let fp = per_mode_metric.iter().find(|(a, b, _, _)| a == t && b == "fp").unwrap().2;
        for m in ["m1", "m3"] {
            let q = per_mode_metric.iter().find(|(a, b, _, _)| a == t && b == m).unwrap().2;
            anyhow::ensure!(
                (fp - q).abs() < 0.25,
                "{t}/{m}: online metric {q:.3} too far from fp {fp:.3}"
            );
        }
    }
    println!("\nOK: quantized serving accuracy tracks FP online.");
    Ok(())
}
