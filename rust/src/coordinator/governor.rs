//! Load-adaptive precision governor (DESIGN.md §5.8): a pure state
//! machine — like the replica pool's `DispatchState` — that watches
//! admission-queue pressure and walks each policy's declared degradation
//! chain (`Manifest::downgrade_chain`) toward cheaper executable modes
//! under sustained overload, restoring toward the base policy with
//! hysteresis once pressure clears.
//!
//! Purity discipline: the machine is fed explicit `observe(depth)` calls
//! and returns the transitions it made; it never reads clocks or
//! channels, so every invariant (never leaves the chain, no oscillation
//! inside the hysteresis window, returns to base after sustained calm)
//! is unit- and property-testable without threads.  The serving side
//! (`batcher_main`) ticks it at a wall-clock cadence and publishes the
//! effective routes through the lock-free `GovernorShared` table that
//! `Coordinator::submit` reads at admission.

use std::time::Duration;

use crate::sync::atomic::{AtomicU16, Ordering};

use crate::model::manifest::PolicyId;

/// Governor tuning.  Watermarks are absolute queue depths (the serving
/// side derives them from `queue_cap`); the `*_after` counts are
/// consecutive observations, which makes the hysteresis window explicit:
/// after any step, the opposite step needs a full fresh streak.
#[derive(Debug, Clone)]
pub struct GovernorConfig {
    /// Depth at or above which an observation counts as pressure.
    pub high_watermark: usize,
    /// Depth at or below which an observation counts as clear.  Must be
    /// `< high_watermark`; the band between them is neutral (both
    /// streaks reset — a wobbling queue neither degrades nor restores).
    pub low_watermark: usize,
    /// Optional latency trip wire: an observation whose queue-delay
    /// sample reaches this is pressure regardless of depth.  The serving
    /// side feeds each dispatched batch's queue delay into *at most one*
    /// observation (consumed on read — neither a cumulative histogram
    /// nor a sticky last-value, either of which would latch high after a
    /// burst and pin the governor degraded).  `None` = depth-only.
    pub high_queue_us: Option<u64>,
    /// Consecutive pressure observations per downgrade step.
    pub degrade_after: u32,
    /// Consecutive clear observations per restore step.  Restoring
    /// slower than degrading (`restore_after > degrade_after`) is the
    /// hysteresis that keeps a saturated server from flapping.
    pub restore_after: u32,
    /// Serving-side observation cadence (the pure machine never reads a
    /// clock; `batcher_main` ticks at this interval).
    pub tick: Duration,
}

impl GovernorConfig {
    /// Defaults scaled to the admission queue: pressure at half the cap,
    /// clear below an eighth, ~3 ticks to degrade, ~4x that to restore.
    pub fn for_queue(queue_cap: usize) -> GovernorConfig {
        GovernorConfig {
            high_watermark: (queue_cap / 2).max(1),
            low_watermark: queue_cap / 8,
            high_queue_us: None,
            degrade_after: 3,
            restore_after: 12,
            tick: Duration::from_millis(5),
        }
    }
}

/// One observation of serving pressure, sampled by the batcher thread at
/// the governor cadence: the admission backlog (channel occupancy plus
/// formed-but-undispatched requests) and the queue delay of the most
/// recently dispatched batch.
#[derive(Debug, Clone, Copy, Default)]
pub struct Signals {
    pub depth: usize,
    pub queue_us: u64,
}

/// One governed transition: `policy`'s effective route moved from
/// `from` to `to` (`level` is the new chain depth; 0 = the policy
/// itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepEvent {
    pub policy: PolicyId,
    pub from: PolicyId,
    pub to: PolicyId,
    pub level: usize,
}

/// The pure governor.  One global pressure signal (the admission queue
/// is shared by every route), per-policy chain positions: a pressure
/// step moves every governable policy one step cheaper, a clear step
/// moves every one a step back toward base.
pub struct PrecisionGovernor {
    cfg: GovernorConfig,
    /// `[policy] -> downgrade chain` (closest-first; empty = ungovernable).
    chains: Vec<Vec<PolicyId>>,
    /// `[policy] -> current chain depth` (0 = base, i.e. the policy itself).
    level: Vec<usize>,
    pressure_run: u32,
    calm_run: u32,
}

impl PrecisionGovernor {
    /// `chains[i]` is `Manifest::downgrade_chain(PolicyId(i))`.
    pub fn new(chains: Vec<Vec<PolicyId>>, cfg: GovernorConfig) -> PrecisionGovernor {
        assert!(
            cfg.low_watermark < cfg.high_watermark,
            "governor watermarks inverted ({} >= {})",
            cfg.low_watermark,
            cfg.high_watermark
        );
        assert!(cfg.degrade_after > 0 && cfg.restore_after > 0);
        let level = vec![0; chains.len()];
        PrecisionGovernor { cfg, chains, level, pressure_run: 0, calm_run: 0 }
    }

    pub fn config(&self) -> &GovernorConfig {
        &self.cfg
    }

    /// The route `policy` currently resolves to (itself at level 0).
    pub fn effective(&self, policy: PolicyId) -> PolicyId {
        let lvl = self.level[policy.index()];
        if lvl == 0 {
            policy
        } else {
            // panic-ok: lvl > 0 here and tick() clamps level to chain len
            self.chains[policy.index()][lvl - 1]
        }
    }

    /// Current chain depth of `policy` (0 = running as asked).
    pub fn level(&self, policy: PolicyId) -> usize {
        self.level[policy.index()]
    }

    /// True if any policy is currently degraded.
    pub fn degraded(&self) -> bool {
        self.level.iter().any(|l| *l > 0)
    }

    /// Feed one pressure observation; returns the transitions it caused
    /// (empty almost always).  Pressure = deep backlog OR (when the trip
    /// wire is set) a slow batch; clear = shallow backlog without a trip.
    pub fn observe(&mut self, s: Signals) -> Vec<StepEvent> {
        let tripped = matches!(self.cfg.high_queue_us, Some(t) if s.queue_us >= t);
        if s.depth >= self.cfg.high_watermark || tripped {
            self.calm_run = 0;
            self.pressure_run += 1;
            if self.pressure_run >= self.cfg.degrade_after {
                self.pressure_run = 0;
                return self.shift(true);
            }
        } else if s.depth <= self.cfg.low_watermark {
            self.pressure_run = 0;
            self.calm_run += 1;
            if self.calm_run >= self.cfg.restore_after {
                self.calm_run = 0;
                return self.shift(false);
            }
        } else {
            // neutral band: a queue hovering between the watermarks is
            // neither overload nor recovery — both streaks restart
            self.pressure_run = 0;
            self.calm_run = 0;
        }
        Vec::new()
    }

    fn shift(&mut self, down: bool) -> Vec<StepEvent> {
        let mut events = Vec::new();
        for (i, chain) in self.chains.iter().enumerate() {
            let policy = PolicyId(i as u16);
            let from = self.effective(policy);
            let lvl = &mut self.level[i];
            if down {
                if *lvl < chain.len() {
                    *lvl += 1;
                }
            } else if *lvl > 0 {
                *lvl -= 1;
            }
            let to = self.effective(policy);
            if from != to {
                events.push(StepEvent { policy, from, to, level: self.level[i] });
            }
        }
        events
    }
}

/// Lock-free `policy -> effective policy` table published by the
/// batcher thread after each governed transition and read by
/// `Coordinator::submit` at admission.  Starts as the identity map.
pub struct GovernorShared {
    effective: Vec<AtomicU16>,
}

impl GovernorShared {
    pub fn new(num_policies: usize) -> GovernorShared {
        GovernorShared {
            effective: (0..num_policies).map(|i| AtomicU16::new(i as u16)).collect(),
        }
    }

    pub fn effective(&self, policy: PolicyId) -> PolicyId {
        // relaxed-ok: each cell is a self-contained PolicyId — admission
        // reads no other memory ordered against this load, and a stale
        // route for a few requests only delays the downgrade by one beat
        PolicyId(self.effective[policy.index()].load(Ordering::Relaxed))
    }

    pub fn publish(&self, policy: PolicyId, effective: PolicyId) {
        // relaxed-ok: single-cell publish with no dependent payload; the
        // batcher owns all writes, so no ordering between cells matters
        self.effective[policy.index()].store(effective.0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Rng};

    fn cfg(high: usize, low: usize, degrade: u32, restore: u32) -> GovernorConfig {
        GovernorConfig {
            high_watermark: high,
            low_watermark: low,
            high_queue_us: None,
            degrade_after: degrade,
            restore_after: restore,
            tick: Duration::from_millis(1),
        }
    }

    fn sig(depth: usize) -> Signals {
        Signals { depth, queue_us: 0 }
    }

    /// policy 0: ungovernable (uniform); policy 1: two-step chain to the
    /// uniform policies 2 then 3 (cheaper and cheapest).
    fn two_step() -> PrecisionGovernor {
        let chains = vec![vec![], vec![PolicyId(2), PolicyId(3)], vec![], vec![]];
        PrecisionGovernor::new(chains, cfg(8, 2, 3, 6))
    }

    #[test]
    fn degrades_after_sustained_pressure_only() {
        let mut g = two_step();
        let p = PolicyId(1);
        assert_eq!(g.effective(p), p);
        // two pressure ticks broken by a neutral one: streak resets
        assert!(g.observe(sig(10)).is_empty());
        assert!(g.observe(sig(10)).is_empty());
        assert!(g.observe(sig(5)).is_empty());
        assert!(g.observe(sig(10)).is_empty());
        assert!(g.observe(sig(10)).is_empty());
        // third consecutive pressure tick: one step down the chain
        let ev = g.observe(sig(10));
        assert_eq!(
            ev,
            vec![StepEvent { policy: p, from: p, to: PolicyId(2), level: 1 }]
        );
        assert_eq!(g.effective(p), PolicyId(2));
        assert!(g.degraded());
        // ungovernable policies never move
        assert_eq!(g.effective(PolicyId(0)), PolicyId(0));
        assert_eq!(g.level(PolicyId(0)), 0);
        // continued pressure: next step lands on the chain floor and stays
        for _ in 0..2 {
            g.observe(sig(10));
        }
        assert_eq!(g.effective(p), PolicyId(3));
        for _ in 0..9 {
            g.observe(sig(10));
        }
        assert_eq!(g.effective(p), PolicyId(3), "must not step past the chain");
        assert_eq!(g.level(p), 2);
    }

    #[test]
    fn restores_with_hysteresis_after_calm() {
        let mut g = two_step();
        let p = PolicyId(1);
        for _ in 0..3 {
            g.observe(sig(10));
        }
        assert_eq!(g.level(p), 1);
        // five calm ticks then one neutral: restore streak resets
        for _ in 0..5 {
            assert!(g.observe(sig(0)).is_empty());
        }
        assert!(g.observe(sig(5)).is_empty());
        for _ in 0..5 {
            assert!(g.observe(sig(0)).is_empty());
        }
        // sixth consecutive calm tick: one step back toward base
        let ev = g.observe(sig(0));
        assert_eq!(
            ev,
            vec![StepEvent { policy: p, from: PolicyId(2), to: p, level: 0 }]
        );
        assert_eq!(g.effective(p), p);
        assert!(!g.degraded());
        // already at base: further calm is a no-op
        for _ in 0..20 {
            assert!(g.observe(sig(0)).is_empty());
        }
        assert_eq!(g.level(p), 0);
    }

    #[test]
    fn shared_table_starts_as_identity_and_publishes() {
        let s = GovernorShared::new(4);
        for i in 0..4u16 {
            assert_eq!(s.effective(PolicyId(i)), PolicyId(i));
        }
        s.publish(PolicyId(1), PolicyId(3));
        assert_eq!(s.effective(PolicyId(1)), PolicyId(3));
        s.publish(PolicyId(1), PolicyId(1));
        assert_eq!(s.effective(PolicyId(1)), PolicyId(1));
    }

    // ------------------------------------------------------- properties

    /// Under random pressure/clear/neutral interleavings the governor
    /// (1) never leaves any policy's chain, (2) never emits opposite
    /// transitions within the hysteresis window (a downgrade needs
    /// `degrade_after` consecutive pressure observations since the last
    /// step, a restore `restore_after` consecutive clears), and (3)
    /// always returns every policy to base after sustained calm.
    #[test]
    fn prop_chain_bounds_hysteresis_and_return_to_base() {
        forall("governor-invariants", 60, |r: &mut Rng| {
            let degrade = 1 + r.below(4) as u32;
            let restore = degrade + r.below(6) as u32;
            let n_policies = 2 + r.below(4);
            let chains: Vec<Vec<PolicyId>> = (0..n_policies)
                .map(|_| {
                    (0..r.below(4)).map(|k| PolicyId((n_policies + k) as u16)).collect()
                })
                .collect();
            let max_chain = chains.iter().map(Vec::len).max().unwrap_or(0);
            let mut full = chains.clone();
            full.extend((0..4).map(|_| Vec::new())); // chain targets are ungovernable
            let mut g = PrecisionGovernor::new(full, cfg(10, 3, degrade, restore));

            // model the streak bookkeeping independently to check the
            // hysteresis window on every emitted transition
            let (mut run_p, mut run_c) = (0u32, 0u32);
            for _ in 0..400 {
                let depth = match r.below(3) {
                    0 => 10 + r.below(20), // pressure
                    1 => r.below(4),       // clear (<= 3)
                    _ => 4 + r.below(6),   // neutral band (4..=9)
                };
                let events = g.observe(sig(depth));
                if depth >= 10 {
                    run_c = 0;
                    run_p += 1;
                } else if depth <= 3 {
                    run_p = 0;
                    run_c += 1;
                } else {
                    run_p = 0;
                    run_c = 0;
                }
                for ev in &events {
                    let idx = ev.policy.index();
                    // (1) stays on the chain: the new effective route is
                    // the policy itself or one of its declared steps
                    assert!(ev.level <= chains[idx].len(), "left the chain: {ev:?}");
                    if ev.level == 0 {
                        assert_eq!(ev.to, ev.policy);
                    } else {
                        assert_eq!(ev.to, chains[idx][ev.level - 1]);
                    }
                }
                // (2) hysteresis: a transition only fires at the end of
                // a full streak of its own kind (the mirrored streak
                // counters must sit exactly at the threshold)
                if !events.is_empty() {
                    if depth >= 10 {
                        assert_eq!(run_p, degrade, "downgrade fired off-streak");
                        run_p = 0;
                    } else {
                        assert!(depth <= 3, "neutral observation caused a transition");
                        assert_eq!(run_c, restore, "restore fired off-streak");
                        run_c = 0;
                    }
                }
                // (1) levels always inside [0, chain_len]
                for (i, chain) in chains.iter().enumerate() {
                    assert!(g.level(PolicyId(i as u16)) <= chain.len());
                }
            }

            // (3) sustained calm returns every policy to base
            let worst = (max_chain as u32 + 1) * restore;
            for _ in 0..worst {
                g.observe(sig(0));
            }
            assert!(!g.degraded(), "sustained calm must restore every policy");
            for i in 0..n_policies {
                let p = PolicyId(i as u16);
                assert_eq!(g.effective(p), p);
                assert_eq!(g.level(p), 0);
            }
        });
    }

    /// Opposite transitions are always separated by at least the
    /// relevant streak length — the no-oscillation guarantee stated in
    /// terms of observation counts.
    #[test]
    fn prop_no_oscillation_within_hysteresis_window() {
        forall("governor-no-flap", 60, |r: &mut Rng| {
            let degrade = 1 + r.below(4) as u32;
            let restore = 1 + r.below(8) as u32;
            let chains = vec![vec![PolicyId(1), PolicyId(2)], vec![], vec![]];
            let mut g = PrecisionGovernor::new(chains, cfg(10, 3, degrade, restore));
            // (observation index, was_downgrade) — the direction is the
            // observation kind that fired it (only pressure degrades,
            // only clear restores)
            let mut transitions: Vec<(usize, bool)> = Vec::new();
            let mut prev_level = 0usize;
            for i in 0..600 {
                let depth = if r.bool() { 10 + r.below(5) } else { r.below(4) };
                let events = g.observe(sig(depth));
                if let Some(ev) = events.first() {
                    let was_down = depth >= 10;
                    // downgrades raise the level, restores lower it
                    assert_eq!(ev.level > prev_level, was_down, "{ev:?} vs depth {depth}");
                    prev_level = ev.level;
                    transitions.push((i, was_down));
                }
            }
            for w in transitions.windows(2) {
                let ((i0, d0), (i1, d1)) = (w[0], w[1]);
                if d0 != d1 {
                    let need = if d1 { degrade } else { restore } as usize;
                    assert!(
                        i1 - i0 >= need,
                        "opposite transitions {need}-window violated: {i0}({d0}) -> {i1}({d1})"
                    );
                }
            }
        });
    }
}
