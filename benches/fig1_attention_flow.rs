//! Figure 1: the attention-module dataflow with quantization annotations,
//! regenerated as a precision-flow trace per mode and verified against the
//! lowered HLO (int8 GeMM census).

use zqhero::bench::Table;
use zqhero::model::manifest::Manifest;
use zqhero::traceflow;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("fig1_attention_flow: run `make artifacts` first");
        return;
    }
    let man = Manifest::load(&dir).expect("manifest");
    for mode in &man.mode_order {
        let sw = man.modes[mode].switches;
        println!("\nFigure 1 — attention module, {} (switches {})",
                 mode, sw.tag());
        let mut t = Table::new(&["tensor", "producer", "scheme", "dtype"]);
        for r in traceflow::attention_flow(&sw) {
            t.row(vec![r.tensor.into(), r.producer.into(), r.scheme, r.dtype]);
        }
        t.print();
        let bucket = *man.buckets.last().unwrap();
        let (want, got) = traceflow::verify_mode_artifact(&man, mode, bucket).unwrap();
        println!("HLO census b{bucket}: {got} int8 GeMMs (expected {want}) {}",
                 if want == got { "OK" } else { "MISMATCH" });
        assert_eq!(want, got);
    }
}
