"""``GELU^quant`` (paper eq. 29): fused GELU + FWQ int8 output.

The FWQ scale ``S_a`` is calibrated, so quantization is a per-column
multiply by ``1/S_a`` fused into the GELU epilogue — no reduction, no extra
pass.  The reciprocal is precomputed by the quantize step and passed in, so
the kernel contains no division (paper §2.2.2: FWQ/SQ quantization reduces
to round-to-integer).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 127.0
GELU_C = 0.7978845608028654  # sqrt(2/pi)


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(jnp.float32(GELU_C) * (x + 0.044715 * x * x * x)))


def _pick(n, want=256):
    b = min(n, want)
    while n % b:
        b -= 1
    return b


def _gelu_quant_kernel(x_ref, inv_sa_ref, q_ref):
    a = _gelu(x_ref[...])
    q_ref[...] = jnp.clip(jnp.round(a * inv_sa_ref[...]), -QMAX, QMAX).astype(jnp.int8)


def gelu_quant(x, s_a, *, block_tokens=None):
    """f32 [n,f] -> GELU -> FWQ int8 [n,f]; ``s_a`` [f] or [1,f]."""
    n, f = x.shape
    bt = block_tokens or _pick(n)
    inv_sa = (1.0 / s_a.reshape(1, f)).astype(jnp.float32)
    return pl.pallas_call(
        _gelu_quant_kernel,
        grid=(n // bt,),
        in_specs=[
            pl.BlockSpec((bt, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((bt, f), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, f), jnp.int8)],
        interpret=True,
    )(x, inv_sa)[0]


def _gelu_kernel(x_ref, y_ref):
    y_ref[...] = _gelu(x_ref[...])


def gelu_fp(x, *, block_tokens=None):
    """Plain f32 GELU kernel (FP baseline / fc2-off fallback)."""
    n, f = x.shape
    bt = block_tokens or _pick(n)
    return pl.pallas_call(
        _gelu_kernel,
        grid=(n // bt,),
        in_specs=[pl.BlockSpec((bt, f), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bt, f), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, f), jnp.float32)],
        interpret=True,
    )(x)[0]
