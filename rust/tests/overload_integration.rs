//! Deterministic serving-pressure harness (DESIGN.md §5.8, §9): a
//! throttled engine plus burst load must produce an exactly-reconciling
//! overload ledger (admitted = completed + shed + expired), keep FIFO
//! order among survivors, and never cancel a request after its batch
//! reached the device (expired replies carry no engine timings).  A
//! second test drives the precision governor end to end: sustained
//! pressure walks a manifest policy down its degradation chain, and
//! sustained calm restores it.  Gated on `make artifacts`.

mod common;

use std::time::{Duration, Instant};

use common::{artifacts, ensure_quantized};
use zqhero::coordinator::{Coordinator, GovernorConfig, RequestSpec, Response, ServerConfig};
use zqhero::data::Split;
use zqhero::model::manifest::Manifest;
use zqhero::runtime::FaultPlan;

fn payload(dir: &std::path::Path, task: &str) -> Vec<(Vec<i32>, Vec<i32>)> {
    let man = Manifest::load(dir).unwrap();
    let split = Split::load(&man, man.task(task).unwrap(), "dev").unwrap();
    (0..16.min(split.len()))
        .map(|i| {
            let (a, b) = split.row(i);
            (a.to_vec(), b.to_vec())
        })
        .collect()
}

/// The §5.8 invariant on one terminal response: expired replies must be
/// device-untouched (cancelled at batch formation or via the
/// cancel-before-submit hook), completed ones must carry real work.
fn assert_outcome_shape(resp: &Response) {
    if resp.expired {
        assert!(resp.logits.is_empty(), "expired reply with logits");
        assert_eq!(
            (resp.timing.exec_us, resp.timing.upload_us, resp.timing.engine_us),
            (0, 0, 0),
            "post-submit cancellation: expired req {} carries engine timings {:?}",
            resp.id,
            resp.timing
        );
    } else {
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.logits.is_empty());
    }
}

#[test]
fn overload_ledger_reconciles_fifo_survivors_zero_post_submit_cancellations() {
    let Some(dir) = artifacts() else { return };
    let rows = payload(&dir, "cola");

    // throttled engine (25 ms per batch) + small backlog bound + tight
    // deadlines: a burst must shed at the bound, expire what queues too
    // long (at batch formation or the engine's cancel-before-submit
    // hook), and complete the rest — all three outcomes exercised
    let coord = Coordinator::start(
        dir.clone(),
        &[("cola".to_string(), "fp".to_string())],
        ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 8,
            fault_plan: FaultPlan::throttle(Duration::from_millis(25)),
            default_deadline: Some(Duration::from_millis(60)),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let total = 120usize;
    let mut shed = 0usize;
    let mut rxs = Vec::new();
    let mut submitted = 0usize;
    // waves keep the pipeline fed well past the backlog bound without
    // any timing assumptions about who wins the submit/drain race
    while submitted < total {
        let spec = RequestSpec::task("cola")
            .mode("fp")
            .ids(rows[submitted % rows.len()].0.clone())
            .type_ids(rows[submitted % rows.len()].1.clone());
        match coord.submit(spec) {
            Ok(rx) => rxs.push((submitted as u64, rx)),
            Err(e) if e.is_busy() => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        submitted += 1;
        if submitted % 16 == 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    assert!(coord.queue_depth() <= 8, "backlog bound exceeded: {}", coord.queue_depth());

    let mut completed = 0usize;
    let mut expired = 0usize;
    let mut survivors: Vec<Response> = Vec::new();
    for (_, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        assert_outcome_shape(&resp);
        if resp.expired {
            expired += 1;
        } else {
            completed += 1;
            survivors.push(resp);
        }
    }

    // the ledger reconciles exactly, client side ...
    assert_eq!(total, completed + shed + expired, "admitted != completed + shed + expired");
    assert!(shed > 0, "burst never hit the backlog bound — not an overload test");
    assert!(completed > 0, "nothing completed — throttle too harsh");

    // ... and recorder side
    let snap = coord.recorder.snapshot();
    let s = &snap["fp"];
    assert_eq!(s.shed as usize, shed);
    assert_eq!(s.expired as usize, expired);
    assert_eq!(s.completed as usize, completed);
    assert_eq!(s.requests as usize, total - shed);
    assert_eq!(s.errors, 0);

    // FIFO preserved among survivors: response ids are submit-ordered,
    // so their dispatch sequence numbers must be non-decreasing, and on
    // the single replica the execution serial must follow dispatch order
    survivors.sort_by_key(|r| r.id);
    let seqs: Vec<u64> = survivors.iter().map(|r| r.timing.batch_seq).collect();
    let mut sorted = seqs.clone();
    sorted.sort_unstable();
    assert_eq!(seqs, sorted, "survivors out of batch order");
    let execs: Vec<u64> = survivors.iter().map(|r| r.timing.engine_seq).collect();
    let mut sorted = execs.clone();
    sorted.sort_unstable();
    assert_eq!(execs, sorted, "survivors executed out of submit order");

    // after full drain the backlog accounting returns to zero
    assert_eq!(coord.queue_depth(), 0, "backlog slots leaked");
}

#[test]
fn governor_degrades_under_pressure_and_restores_on_calm() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(&dir).unwrap();
    // the manifest ships attn-out-fp (base m3, fallback [m2, m1, fp],
    // exec m1) with degradation chain [m2, m3]; skip if absent
    let Ok(pid) = man.policy_id("attn-out-fp") else {
        eprintln!("skipping governor test: manifest has no attn-out-fp policy");
        return;
    };
    let chain = man.downgrade_chain(pid);
    assert!(!chain.is_empty(), "attn-out-fp must be governable");
    assert_eq!(chain, vec![man.policy_id("m2").unwrap(), man.policy_id("m3").unwrap()]);
    for mode in ["m1", "m2", "m3"] {
        ensure_quantized(&dir, "sst2", mode);
    }
    let rows = payload(&dir, "sst2");

    // tiny watermarks + fast ticks so the test converges in milliseconds;
    // restore_after > degrade_after is the hysteresis under test
    let coord = Coordinator::start(
        dir.clone(),
        &[("sst2".to_string(), "attn-out-fp".to_string())],
        ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 16,
            fault_plan: FaultPlan::throttle(Duration::from_millis(20)),
            governor: Some(GovernorConfig {
                high_watermark: 4,
                low_watermark: 1,
                high_queue_us: None,
                degrade_after: 2,
                restore_after: 6,
                tick: Duration::from_millis(2),
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    assert_eq!(coord.effective_policy(pid), pid, "governor must start at base");

    // sustained pressure: keep the backlog above the high watermark until
    // the governor walks the chain (bounded wait, no sleep-tuning)
    let mut rxs = Vec::new();
    let mut governed_seen = false;
    let t0 = Instant::now();
    let mut i = 0usize;
    while t0.elapsed() < Duration::from_secs(30) {
        let spec = RequestSpec::task("sst2")
            .policy("attn-out-fp")
            .ids(rows[i % rows.len()].0.clone())
            .type_ids(rows[i % rows.len()].1.clone());
        i += 1;
        match coord.submit(spec) {
            Ok(rx) => rxs.push(rx),
            Err(e) if e.is_busy() => std::thread::sleep(Duration::from_millis(2)),
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        if coord.effective_policy(pid) != pid {
            governed_seen = true;
            break;
        }
    }
    assert!(governed_seen, "governor never degraded under sustained pressure");
    let stepped = coord.effective_policy(pid);
    assert!(chain.contains(&stepped), "degraded off the declared chain: {stepped:?}");

    // now submit a few requests *while* degraded: they must ride the
    // cheaper effective route and be ledgered as governed
    let mut governed_accepted = 0usize;
    let t1 = Instant::now();
    // (if a restore races us because the backlog drained, continued
    // submission rebuilds pressure and re-degrades within the window)
    while governed_accepted < 3 && t1.elapsed() < Duration::from_secs(30) {
        let spec = RequestSpec::task("sst2")
            .policy("attn-out-fp")
            .ids(rows[i % rows.len()].0.clone())
            .type_ids(rows[i % rows.len()].1.clone());
        i += 1;
        let was_degraded = coord.effective_policy(pid) != pid;
        match coord.submit(spec) {
            Ok(rx) => {
                if was_degraded {
                    governed_accepted += 1;
                }
                rxs.push(rx);
            }
            Err(e) if e.is_busy() => std::thread::sleep(Duration::from_millis(2)),
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(governed_accepted >= 3, "could not land governed traffic while degraded");

    // drain; governed traffic rode the cheaper route (the response names
    // the effective policy it actually executed under)
    let mut rode_cheaper = false;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        if resp.policy != pid {
            assert!(chain.contains(&resp.policy), "rode an undeclared route");
            rode_cheaper = true;
        }
    }
    assert!(rode_cheaper, "no response rode a downgraded route");
    let snap = coord.recorder.snapshot();
    let s = &snap["attn-out-fp"];
    assert!(s.governed > 0, "no request was ledgered as governed");
    // governed rows landed on chain policies' batch slots, under the
    // requested policy's request ledger
    assert_eq!(s.requests, s.completed + s.errors + s.expired);

    // sustained calm: the backlog is empty, so the governor must walk
    // back to base within chain_len * restore_after ticks (plus slack)
    let t0 = Instant::now();
    while coord.effective_policy(pid) != pid && t0.elapsed() < Duration::from_secs(30) {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(coord.effective_policy(pid), pid, "sustained calm must restore the base policy");
}
