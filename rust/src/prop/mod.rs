//! Mini property-testing framework (proptest is unavailable offline —
//! DESIGN.md §2): a deterministic xorshift PRNG, value generators, and a
//! `forall` runner with shrinking-free failure reporting (cases are seeded,
//! so any failure is reproducible from the printed seed).

/// xorshift64* — deterministic, fast, good enough for test-case generation.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.unit() * (hi - lo)
    }

    /// Log-uniform in [lo, hi) — the natural distribution for scales.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform(lo as f64, hi as f64) as f32).collect()
    }

    pub fn vec_i8(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.range_i64(-127, 127) as i8).collect()
    }

    pub fn vec_i32(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i64(lo as i64, hi as i64) as i32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

/// Run `body` over `cases` seeded cases; panics with the failing seed.
pub fn forall<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut body: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1) ^ 0xD1B54A32D192ED03;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_hold() {
        forall("ranges", 200, |r| {
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
            let s = r.log_uniform(1e-4, 1e-1);
            assert!((1e-4..1e-1).contains(&s));
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failure_reports_seed() {
        forall("always-fails", 3, |_| panic!("nope"));
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|b| *b));
    }
}
