//! Serving metrics: lock-light latency/throughput recording with
//! log-bucketed histograms, keyed by interned precision policy.
//! Recording is index-addressed (`PolicyId` -> dense slot) so the
//! steady-state path never allocates; names reappear only in
//! `snapshot`/`render`.  Uniform per-mode policies occupy the first
//! slots, so v1 (string-mode) traffic keeps its mode-name keys.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::sync::{Mutex, MutexGuard};

use crate::model::manifest::PolicyId;
use crate::runtime::engine::PoolEvent;

/// Log2-bucketed latency histogram (microseconds).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) us; 64 buckets.
    counts: Vec<u64>,
    total: u64,
    sum_us: u128,
    max_us: u64,
    min_us: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; 64], total: 0, sum_us: 0, max_us: 0, min_us: u64::MAX }
    }

    pub fn record(&mut self, us: u64) {
        let bucket = 63 - us.max(1).leading_zeros() as usize;
        self.counts[bucket.min(63)] += 1;
        self.total += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
        self.min_us = self.min_us.min(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    /// Percentile estimate: linear interpolation inside the target
    /// log2 bucket (assuming uniform spread), clamped to the observed
    /// [min, max].  Returning the bucket's upper bound — the previous
    /// behaviour — over-reported by up to 2x; with the clamp, a
    /// single-valued histogram is exact at every percentile.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let want = (self.total as f64 * p).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            if *c == 0 {
                continue;
            }
            if seen + c >= want {
                let lo = (1u64 << i) as f64;
                let hi = lo * 2.0; // avoids 1<<64 overflow in the top bucket
                // midpoint of the k-th sample's share of the bucket
                let frac = ((want - seen) as f64 - 0.5) / *c as f64;
                let v = lo + frac * (hi - lo);
                return (v as u64).clamp(self.min_us, self.max_us);
            }
            seen += c;
        }
        self.max_us
    }

    pub fn max_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.max_us }
    }

    pub fn min_us(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min_us }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug, Default, Clone)]
pub struct PolicyStats {
    pub latency: Histogram,
    pub exec: Histogram,
    pub queue: Histogram,
    /// Admitted requests with a terminal outcome:
    /// `requests == completed + errors + expired + failed` at every
    /// instant (each outcome increments both under one lock acquisition).
    pub requests: u64,
    pub batches: u64,
    pub batched_rows: u64,
    /// Caller-provided tokens across this policy's batches (pre-padding).
    pub real_tokens: u64,
    /// Token slots the device actually processed (`bucket * seq_bucket`
    /// summed over batches).  `real_tokens / padded_tokens` is the
    /// padding efficiency the render table reports — the memory-traffic
    /// share that carried real work (DESIGN.md §5.9).
    pub padded_tokens: u64,
    pub errors: u64,
    /// Replied with logits.
    pub completed: u64,
    /// Overload-control ledger (DESIGN.md §5.8), keyed by the policy the
    /// client *requested* (traffic governed onto a cheaper route still
    /// reconciles under the name the client used):
    /// rejected at admission with `Busy` (never entered the queue),
    pub shed: u64,
    /// cancelled at de-queue / cancel-before-submit because the deadline
    /// passed (counted in `requests` too — they were admitted),
    pub expired: u64,
    /// admitted while the governor had this policy downgraded.
    pub governed: u64,
    /// Batch swept off a dead engine replica with `ReplicaFailed`
    /// (DESIGN.md §5.10) — a terminal class of its own, distinct from
    /// request `errors`: the request was well-formed, the engine was not.
    pub failed: u64,
}

impl PolicyStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rows as f64 / self.batches as f64
        }
    }

    /// real tokens / padded tokens over this policy's batches, in [0, 1]
    /// (1.0 when no batch has executed yet: an idle policy wastes nothing).
    pub fn padding_efficiency(&self) -> f64 {
        if self.padded_tokens == 0 {
            1.0
        } else {
            self.real_tokens as f64 / self.padded_tokens as f64
        }
    }

    fn active(&self) -> bool {
        self.requests > 0 || self.batches > 0 || self.errors > 0 || self.shed > 0
    }
}

/// Per-replica batch accounting for the engine pool (DESIGN.md §5.7),
/// plus the supervision health ledger (§5.10) fed by `PoolEvent`s: how
/// many batches (and request rows) each replica executed, which
/// incarnation is serving, how many supervised restarts it has survived,
/// how many batches its deaths failed, and how stale its heartbeat is.
#[derive(Debug, Default, Clone)]
pub struct ReplicaStats {
    pub batches: u64,
    pub rows: u64,
    /// Current incarnation (0 = original; bumped by supervised restart).
    pub generation: u64,
    /// Supervised restarts that reached ready and rejoined dispatch.
    pub restarts: u64,
    /// Device-committed batches swept with `ReplicaFailed` across all of
    /// this replica's deaths (named apart from the policy-ledger
    /// `failed` counter: this one is outside the reconciliation
    /// identity).
    pub swept: u64,
    /// Heartbeat age at the supervisor's last liveness sample, us.
    pub beat_age_us: u64,
    /// Circuit breaker tripped: the replica is out for the pool's life.
    pub excluded: bool,
}

/// Per-replica executable-residency ledger (DESIGN.md §5.13), fed by
/// `CellLoaded`/`CellEvicted`/`ResidencyLookup` pool events: how often
/// batches found their cell resident, what misses cost, and how the
/// LRU budget churned.  The reconciliation identity the property tests
/// pin: `hits + misses == lookups` and every miss either loaded or
/// failed — `loads <= misses` (warm/pin loads are not lookups, so
/// `loads` can also exceed `misses` on a warm-heavy profile; the table
/// reports both rather than deriving one from the other).
#[derive(Debug, Default, Clone)]
pub struct ResidencyStats {
    /// Batch lookups that found their cell resident.
    pub hits: u64,
    /// Batch lookups that had to load (or wait on a failed load).
    pub misses: u64,
    /// Cells that became resident (pins, warms, and demand misses).
    pub loads: u64,
    /// The subset of `loads` that were pinned cells.
    pub pinned_loads: u64,
    /// LRU evictions plus version-drain drops.
    pub evictions: u64,
    /// Resident cells after the most recent load/evict event.
    pub resident: usize,
    /// Compile+upload latency per load.
    pub load_us: Histogram,
    /// What miss-path batches actually waited on the residency table.
    pub wait_us: Histogram,
}

impl ResidencyStats {
    fn active(&self) -> bool {
        self.hits > 0 || self.misses > 0 || self.loads > 0 || self.evictions > 0
    }
}

/// All slot tables behind the recorder's single mutex: per-policy,
/// per-replica, and residency counters update atomically together, so
/// "per-replica batch counts sum to per-policy batch totals" holds for
/// every observer, not just quiescent ones.  `names` lives here too:
/// hot manifest reload appends a whole block of versioned policy slots
/// (`"fp@v1"`, ...), and the names must grow under the same lock as the
/// stats they label.
struct Slots {
    /// Slot names: version 0's block carries the bare policy names;
    /// version N's block (registered on reload) carries `"name@vN"`.
    names: Vec<String>,
    policies: Vec<PolicyStats>,
    replicas: Vec<ReplicaStats>,
    residency: Vec<ResidencyStats>,
}

/// Shared recorder (single mutex — recording is tiny next to inference).
/// Slots are dense by `(version, PolicyId)`: version v's block starts at
/// `v * base` where `base` is the manifest's policy count, so each
/// manifest version reconciles on its own ledger
/// (`requests == completed + errors + expired + failed` per slot).
/// Replica slots are dense by replica index, fixed at startup;
/// per-replica batch counts always sum to the per-policy batch totals
/// (every batch is recorded once, with the replica that ran it, under
/// one lock).
pub struct Recorder {
    start: Instant,
    /// Policies per version block (the manifest's policy count — reload
    /// requires an identical policy order, so every version's block is
    /// the same width).
    base: usize,
    inner: Mutex<Slots>,
}

impl Recorder {
    /// `policies` is the manifest's `policy_order` — the `PolicyId` space
    /// (uniform mode policies first, then the `policies` section).
    /// `replicas` is the engine-pool size (min 1).
    pub fn new(policies: Vec<String>, replicas: usize) -> Self {
        let base = policies.len();
        let slots = Slots {
            policies: policies.iter().map(|_| PolicyStats::default()).collect(),
            names: policies,
            replicas: vec![ReplicaStats::default(); replicas.max(1)],
            residency: vec![ResidencyStats::default(); replicas.max(1)],
        };
        Recorder { start: Instant::now(), base, inner: Mutex::new(slots) }
    }

    /// Ensure slot blocks exist through `version` (called by the
    /// coordinator *before* it publishes a reloaded version, so no event
    /// can arrive carrying an unregistered version; the record paths
    /// also self-heal under the same lock as defense in depth).
    pub fn register_version(&self, version: u32) {
        let mut g = self.slots();
        self.grow_to(&mut g, version);
    }

    fn grow_to(&self, g: &mut Slots, version: u32) {
        if self.base == 0 {
            return;
        }
        let want = (version as usize + 1) * self.base;
        while g.policies.len() < want {
            let s = g.policies.len();
            let name = format!("{}@v{}", g.names[s % self.base], s / self.base);
            g.names.push(name);
            g.policies.push(PolicyStats::default());
        }
    }

    /// The `(version, policy)` slot, growing the version's block if it
    /// does not exist yet.
    fn policy_slot<'a>(
        &self,
        g: &'a mut Slots,
        version: u32,
        policy: PolicyId,
    ) -> &'a mut PolicyStats {
        self.grow_to(g, version);
        // slots are policy_order-sized per block; a foreign PolicyId is
        // a bug, not a slot
        &mut g.policies[version as usize * self.base + policy.index()]
    }

    /// Lock the slot tables, recovering from poisoning.  Every mutation
    /// under this lock is a monotone counter bump or histogram append —
    /// a panicking holder cannot leave torn state — so recovery keeps
    /// the ledger serving instead of cascading opaque poison panics
    /// through the supervisor and every connection thread.
    fn slots(&self) -> MutexGuard<'_, Slots> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub fn record_request(&self, policy: PolicyId, total_us: u64, queue_us: u64, err: bool) {
        self.record_request_at(0, policy, total_us, queue_us, err);
    }

    /// Versioned spelling of [`Recorder::record_request`]: the slot is
    /// `(version, policy)`, so each manifest version's ledger reconciles
    /// on its own (the unversioned methods are v0 sugar for callers that
    /// never reload).
    pub fn record_request_at(
        &self,
        version: u32,
        policy: PolicyId,
        total_us: u64,
        queue_us: u64,
        err: bool,
    ) {
        let mut g = self.slots();
        let s = self.policy_slot(&mut g, version, policy);
        s.requests += 1;
        if err {
            s.errors += 1;
        } else {
            s.completed += 1;
            s.latency.record(total_us);
            s.queue.record(queue_us);
        }
    }

    /// A submission rejected with `Busy` at admission (queue at cap).
    pub fn record_shed(&self, policy: PolicyId) {
        self.record_shed_at(0, policy);
    }

    pub fn record_shed_at(&self, version: u32, policy: PolicyId) {
        let mut g = self.slots();
        self.policy_slot(&mut g, version, policy).shed += 1;
    }

    /// An admitted request cancelled because its deadline passed before
    /// its batch reached the device (de-queue cull or the engine's
    /// cancel-before-submit hook).  Counts in `requests` too, so
    /// `requests == completed + errors + expired` stays exact.
    pub fn record_expired(&self, policy: PolicyId, queue_us: u64) {
        self.record_expired_at(0, policy, queue_us);
    }

    pub fn record_expired_at(&self, version: u32, policy: PolicyId, queue_us: u64) {
        let mut g = self.slots();
        let s = self.policy_slot(&mut g, version, policy);
        s.requests += 1;
        s.expired += 1;
        s.queue.record(queue_us);
    }

    /// A request admitted while the governor had `requested` downgraded
    /// (it rides a cheaper route; the ledger stays under the asked name).
    pub fn record_governed(&self, requested: PolicyId) {
        self.record_governed_at(0, requested);
    }

    pub fn record_governed_at(&self, version: u32, requested: PolicyId) {
        let mut g = self.slots();
        self.policy_slot(&mut g, version, requested).governed += 1;
    }

    /// An admitted request whose batch was swept off a dead replica with
    /// `ReplicaFailed` (DESIGN.md §5.10).  Counts in `requests` too, so
    /// `requests == completed + errors + expired + failed` stays exact.
    pub fn record_failed(&self, policy: PolicyId) {
        self.record_failed_at(0, policy);
    }

    pub fn record_failed_at(&self, version: u32, policy: PolicyId) {
        let mut g = self.slots();
        let s = self.policy_slot(&mut g, version, policy);
        s.requests += 1;
        s.failed += 1;
    }

    /// Fold a supervision lifecycle event into the replica health ledger
    /// (the coordinator installs this as the pool's event hook; events
    /// arrive from the supervisor thread).
    pub fn record_pool_event(&self, ev: PoolEvent) {
        let mut g = self.slots();
        match ev {
            PoolEvent::ReplicaFailed { replica, failed_batches, .. } => {
                g.replicas[replica].swept += failed_batches;
            }
            PoolEvent::ReplicaRestarted { replica, generation } => {
                let rs = &mut g.replicas[replica];
                rs.restarts += 1;
                rs.generation = generation;
            }
            PoolEvent::ReplicaExcluded { replica } => g.replicas[replica].excluded = true,
            PoolEvent::Heartbeat { replica, generation, age_us } => {
                let rs = &mut g.replicas[replica];
                rs.generation = generation;
                rs.beat_age_us = age_us;
            }
            PoolEvent::CellLoaded { replica, load_us, pinned, resident } => {
                let rs = &mut g.residency[replica];
                rs.loads += 1;
                if pinned {
                    rs.pinned_loads += 1;
                }
                rs.load_us.record(load_us);
                rs.resident = resident;
            }
            PoolEvent::CellEvicted { replica, resident } => {
                let rs = &mut g.residency[replica];
                rs.evictions += 1;
                rs.resident = resident;
            }
            PoolEvent::ResidencyLookup { replica, hit, wait_us } => {
                let rs = &mut g.residency[replica];
                if hit {
                    rs.hits += 1;
                } else {
                    rs.misses += 1;
                    rs.wait_us.record(wait_us);
                }
            }
        }
    }

    /// `real_tokens` / `padded_tokens` are the batch's caller-token count
    /// and device token-slot count (`bucket * seq_bucket`) — recorded
    /// under the same lock as the batch so the padding ledger can never
    /// tear against the batch count.
    pub fn record_batch(
        &self,
        policy: PolicyId,
        rows: usize,
        real_tokens: usize,
        padded_tokens: usize,
        exec_us: u64,
        replica: usize,
    ) {
        self.record_batch_at(0, policy, rows, real_tokens, padded_tokens, exec_us, replica);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_batch_at(
        &self,
        version: u32,
        policy: PolicyId,
        rows: usize,
        real_tokens: usize,
        padded_tokens: usize,
        exec_us: u64,
        replica: usize,
    ) {
        let mut g = self.slots();
        {
            let s = self.policy_slot(&mut g, version, policy);
            s.batches += 1;
            s.batched_rows += rows as u64;
            s.real_tokens += real_tokens as u64;
            s.padded_tokens += padded_tokens as u64;
            s.exec.record(exec_us);
        }
        // replica slots are fixed at startup; an out-of-range index is an
        // engine-pool bug, not a slot to grow
        let rs = &mut g.replicas[replica];
        rs.batches += 1;
        rs.rows += rows as u64;
    }

    /// Per-replica batch counts, dense by replica index (all replicas,
    /// including idle ones — the imbalance is the signal).
    pub fn replica_snapshot(&self) -> Vec<ReplicaStats> {
        self.slots().replicas.clone()
    }

    /// Per-replica residency ledger, dense by replica index (DESIGN.md
    /// §5.13).  On a freshly started pool, `loads` across replicas equals
    /// the pin-set size times the replica count — the acceptance witness
    /// that startup loaded only the pin set, not the preload cross-product.
    pub fn residency_snapshot(&self) -> Vec<ResidencyStats> {
        self.slots().residency.clone()
    }

    fn policy_snapshot_of(&self, slots: &Slots) -> BTreeMap<String, PolicyStats> {
        slots
            .policies
            .iter()
            .enumerate()
            .filter(|(_, s)| s.active())
            .map(|(i, s)| (slots.names[i].clone(), s.clone()))
            .collect()
    }

    /// Per-policy stats keyed by policy name, active policies only (so
    /// callers see the same shape as traffic they actually sent).
    pub fn snapshot(&self) -> BTreeMap<String, PolicyStats> {
        let g = self.slots();
        self.policy_snapshot_of(&g)
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Human-readable summary table.  Both tables come from one lock
    /// acquisition, so the replica counts always sum to the policy batch
    /// totals even while traffic is flowing.
    pub fn render(&self) -> String {
        use crate::bench::Table;
        let (snap, reps, res) = {
            let g = self.slots();
            (self.policy_snapshot_of(&g), g.replicas.clone(), g.residency.clone())
        };
        let elapsed = self.elapsed_s();
        let mut t = Table::new(&[
            "policy", "reqs", "errs", "shed", "expired", "failed", "governed", "goodput(r/s)",
            "mean batch", "pad eff", "p50 lat", "p95 lat", "p99 lat", "mean exec/batch",
        ]);
        for (policy, s) in &snap {
            t.row(vec![
                policy.clone(),
                s.requests.to_string(),
                s.errors.to_string(),
                s.shed.to_string(),
                s.expired.to_string(),
                s.failed.to_string(),
                s.governed.to_string(),
                // completed-only: under overload, counting expired
                // requests here would read as "keeping up" exactly when
                // the server is shedding accuracy and load to survive
                format!("{:.1}", s.completed as f64 / elapsed.max(1e-9)),
                format!("{:.2}", s.mean_batch_size()),
                // real / padded tokens: the share of device memory
                // traffic that carried real work (DESIGN.md §5.9)
                format!("{:.0}%", 100.0 * s.padding_efficiency()),
                format!("{:.1}ms", s.latency.percentile_us(0.50) as f64 / 1e3),
                format!("{:.1}ms", s.latency.percentile_us(0.95) as f64 / 1e3),
                format!("{:.1}ms", s.latency.percentile_us(0.99) as f64 / 1e3),
                format!("{:.1}ms", s.exec.mean_us() / 1e3),
            ]);
        }
        let mut out = t.render();
        if reps.len() > 1 {
            let total: u64 = reps.iter().map(|r| r.batches).sum();
            // replica health table (DESIGN.md §5.10): load share plus the
            // supervision ledger — generation, restarts, swept batches,
            // last-heartbeat age, breaker state
            let mut rt = Table::new(&[
                "replica", "batches", "rows", "share", "gen", "restarts", "swept", "beat age",
                "state",
            ]);
            for (i, r) in reps.iter().enumerate() {
                rt.row(vec![
                    i.to_string(),
                    r.batches.to_string(),
                    r.rows.to_string(),
                    format!("{:.0}%", 100.0 * r.batches as f64 / total.max(1) as f64),
                    r.generation.to_string(),
                    r.restarts.to_string(),
                    r.swept.to_string(),
                    format!("{:.1}ms", r.beat_age_us as f64 / 1e3),
                    if r.excluded { "excluded".to_string() } else { "live".to_string() },
                ]);
            }
            out.push('\n');
            out.push_str(&rt.render());
        }
        if res.iter().any(|r| r.active()) {
            // executable residency table (DESIGN.md §5.13): cache
            // effectiveness, load latency, and budget churn per replica
            let mut ct = Table::new(&[
                "replica", "hits", "misses", "loads", "pinned", "evicted", "resident",
                "p50 load", "p99 load", "p99 miss wait",
            ]);
            for (i, r) in res.iter().enumerate() {
                ct.row(vec![
                    i.to_string(),
                    r.hits.to_string(),
                    r.misses.to_string(),
                    r.loads.to_string(),
                    r.pinned_loads.to_string(),
                    r.evictions.to_string(),
                    r.resident.to_string(),
                    format!("{:.1}ms", r.load_us.percentile_us(0.50) as f64 / 1e3),
                    format!("{:.1}ms", r.load_us.percentile_us(0.99) as f64 / 1e3),
                    format!("{:.1}ms", r.wait_us.percentile_us(0.99) as f64 / 1e3),
                ]);
            }
            out.push('\n');
            out.push_str(&ct.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_percentiles() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 5120] {
            h.record(us);
        }
        assert_eq!(h.count(), 10);
        assert!(h.percentile_us(0.5) >= 80);
        assert!(h.percentile_us(1.0) >= 5120);
        assert_eq!(h.min_us(), 10);
        assert_eq!(h.max_us(), 5120);
    }

    #[test]
    fn histogram_zero_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn percentile_interpolates_instead_of_upper_bound() {
        // 1000 identical samples: every percentile must be exact, not the
        // bucket's upper bound (the old behaviour returned 128 for 100us).
        let mut h = Histogram::new();
        for _ in 0..1000 {
            h.record(100);
        }
        assert_eq!(h.percentile_us(0.50), 100);
        assert_eq!(h.percentile_us(0.99), 100);
        assert_eq!(h.percentile_us(1.0), 100);

        // mixed: estimates stay inside the sample range and monotone in p
        let mut h = Histogram::new();
        for us in [100u64, 110, 120, 130, 900, 950, 1000, 1100, 1200, 1300] {
            h.record(us);
        }
        let p50 = h.percentile_us(0.50);
        let p90 = h.percentile_us(0.90);
        let p100 = h.percentile_us(1.0);
        // 5th of 10 samples is 900 (bucket [512,1024)); 9th is 1200
        assert!(p50 >= 512 && p50 <= 1024, "p50 {p50}");
        assert!(p90 >= 1024 && p90 <= 1300, "p90 {p90}");
        assert!(p50 <= p90 && p90 <= p100);
        assert_eq!(p100, 1300);
    }

    #[test]
    fn recorder_accumulates_per_policy() {
        // uniform mode policies first, then a named override policy
        let r = Recorder::new(vec!["fp".into(), "m3".into(), "attn-out-fp".into()], 1);
        let fp = PolicyId(0);
        let m3 = PolicyId(1);
        let named = PolicyId(2);
        r.record_request(m3, 1000, 100, false);
        r.record_request(m3, 2000, 200, false);
        r.record_request(fp, 99, 9, true);
        r.record_request(named, 500, 50, false);
        // 8 rows in a (bucket 8, seq 64) batch: 300 of 512 slots real
        r.record_batch(m3, 8, 300, 512, 500, 0);
        let snap = r.snapshot();
        assert_eq!(snap["m3"].requests, 2);
        assert_eq!(snap["fp"].errors, 1);
        assert_eq!(snap["attn-out-fp"].requests, 1);
        assert_eq!(snap["m3"].mean_batch_size(), 8.0);
        assert_eq!(snap["m3"].real_tokens, 300);
        assert_eq!(snap["m3"].padded_tokens, 512);
        assert!((snap["m3"].padding_efficiency() - 300.0 / 512.0).abs() < 1e-12);
        // an idle policy reports perfect efficiency, not a 0/0 artifact
        assert_eq!(snap["fp"].padding_efficiency(), 1.0);
        assert!(r.render().contains("m3"));
        assert!(r.render().contains("attn-out-fp"));
        assert!(r.render().contains("pad eff"));
        // single-replica serving keeps the plain render (no replica table)
        assert!(!r.render().contains("replica"));
    }

    #[test]
    fn recorder_snapshot_hides_idle_policies() {
        let r = Recorder::new(vec!["fp".into(), "m1".into()], 1);
        r.record_request(PolicyId(0), 10, 1, false);
        let snap = r.snapshot();
        assert!(snap.contains_key("fp"));
        assert!(!snap.contains_key("m1"));
    }

    #[test]
    fn overload_counters_reconcile_and_render() {
        let r = Recorder::new(vec!["fp".into(), "attn-out-fp".into()], 1);
        let p = PolicyId(1);
        r.record_request(p, 1000, 100, false);
        r.record_request(p, 2000, 200, true);
        r.record_expired(p, 5000);
        r.record_shed(p);
        r.record_shed(p);
        r.record_governed(p);
        let snap = r.snapshot();
        let s = &snap["attn-out-fp"];
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 1);
        assert_eq!(s.errors, 1);
        assert_eq!(s.expired, 1);
        assert_eq!(s.shed, 2);
        assert_eq!(s.governed, 1);
        assert_eq!(s.requests, s.completed + s.errors + s.expired);
        let table = r.render();
        assert!(table.contains("shed") && table.contains("expired") && table.contains("governed"));
        // a policy that only ever shed still shows up (the overload story
        // must be visible even when nothing was admitted)
        let r = Recorder::new(vec!["fp".into()], 1);
        r.record_shed(PolicyId(0));
        assert!(r.snapshot().contains_key("fp"));
    }

    /// Satellite coverage for DESIGN.md §5.8/§9: the recorder under
    /// concurrent load.  Writer threads hammer every record path while a
    /// reader snapshots/renders continuously; every *observed* snapshot
    /// must satisfy the invariants the single-lock design promises —
    /// per-replica batch counts summing to per-policy batch totals, and
    /// `requests == completed + errors + expired` per policy — and the
    /// final state must reconcile exactly with what the writers did.
    /// (`loom` is unavailable offline, so interleavings are driven by
    /// seeded real threads via `prop::forall` instead.)
    #[test]
    fn recorder_concurrent_snapshot_render_coherence() {
        use crate::prop::{forall, Rng};
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        forall("recorder-race", 8, |r: &mut Rng| {
            let policies: Vec<String> = vec!["fp".into(), "m3".into(), "attn-out-fp".into()];
            let replicas = 1 + r.below(3);
            let rec = Arc::new(Recorder::new(policies, replicas));
            // pre-generate each writer's op tape so the work is seeded
            // and the expected totals are known exactly
            #[derive(Clone, Copy)]
            enum Op {
                Req { p: u16, err: bool },
                Expired { p: u16 },
                Shed { p: u16 },
                Governed { p: u16 },
                Failed { p: u16 },
                Event(PoolEvent),
                Batch { p: u16, rows: usize, real_tok: usize, padded_tok: usize, rep: usize },
            }
            let n_writers = 3;
            let tapes: Vec<Vec<Op>> = (0..n_writers)
                .map(|_| {
                    (0..150 + r.below(150))
                        .map(|_| {
                            let p = r.below(3) as u16;
                            match r.below(7) {
                                0 => Op::Req { p, err: r.below(8) == 0 },
                                1 => Op::Expired { p },
                                2 => Op::Shed { p },
                                3 => Op::Governed { p },
                                4 => Op::Failed { p },
                                // supervision events race the request
                                // ledger through the same lock
                                5 => {
                                    let replica = r.below(replicas);
                                    Op::Event(match r.below(4) {
                                        0 => PoolEvent::ReplicaFailed {
                                            replica,
                                            generation: r.below(3) as u64,
                                            failed_batches: r.below(4) as u64,
                                        },
                                        1 => PoolEvent::ReplicaRestarted {
                                            replica,
                                            generation: 1 + r.below(3) as u64,
                                        },
                                        2 => PoolEvent::ReplicaExcluded { replica },
                                        _ => PoolEvent::Heartbeat {
                                            replica,
                                            generation: r.below(4) as u64,
                                            age_us: r.below(5000) as u64,
                                        },
                                    })
                                }
                                _ => {
                                    // a plausible batch: padded slots are
                                    // a (bucket, seq bucket) cell, real
                                    // tokens never exceed them
                                    let padded_tok = 16 * (1 + r.below(128));
                                    Op::Batch {
                                        p,
                                        rows: 1 + r.below(16),
                                        real_tok: 1 + r.below(padded_tok),
                                        padded_tok,
                                        rep: r.below(replicas),
                                    }
                                }
                            }
                        })
                        .collect()
                })
                .collect();

            let stop = Arc::new(AtomicBool::new(false));
            std::thread::scope(|s| {
                let writers: Vec<_> = tapes
                    .iter()
                    .map(|tape| {
                        let rec = Arc::clone(&rec);
                        s.spawn(move || {
                            for op in tape {
                                match *op {
                                    Op::Req { p, err } => {
                                        rec.record_request(PolicyId(p), 1000, 100, err)
                                    }
                                    Op::Expired { p } => rec.record_expired(PolicyId(p), 500),
                                    Op::Shed { p } => rec.record_shed(PolicyId(p)),
                                    Op::Governed { p } => rec.record_governed(PolicyId(p)),
                                    Op::Failed { p } => rec.record_failed(PolicyId(p)),
                                    Op::Event(ev) => rec.record_pool_event(ev),
                                    Op::Batch { p, rows, real_tok, padded_tok, rep } => rec
                                        .record_batch(
                                            PolicyId(p),
                                            rows,
                                            real_tok,
                                            padded_tok,
                                            200,
                                            rep,
                                        ),
                                }
                            }
                        })
                    })
                    .collect();
                // reader: race snapshot/render/replica_snapshot against
                // the writers and check coherence on every observation
                let rec_r = Arc::clone(&rec);
                let stop_r = Arc::clone(&stop);
                let reader = s.spawn(move || {
                    let mut observations = 0u32;
                    while !stop_r.load(Ordering::SeqCst) || observations == 0 {
                        let (snap, reps) = (rec_r.snapshot(), rec_r.replica_snapshot());
                        for (name, s) in &snap {
                            assert_eq!(
                                s.requests,
                                s.completed + s.errors + s.expired + s.failed,
                                "{name} ledger tore mid-flight"
                            );
                            // tokens are recorded under the same lock as
                            // the batch, so no observation can see real
                            // tokens outrun the padded slots (or tokens
                            // without a batch)
                            assert!(
                                s.real_tokens <= s.padded_tokens,
                                "{name} token ledger tore mid-flight"
                            );
                            assert!(s.batches > 0 || s.padded_tokens == 0, "{name} tokens sans batch");
                        }
                        // NB: snapshot() then replica_snapshot() are two
                        // lock acquisitions, so writers may land between
                        // them — replica totals can only run *ahead* of
                        // the policy totals observed earlier, never behind
                        let policy_batches: u64 = snap.values().map(|s| s.batches).sum();
                        let replica_batches: u64 = reps.iter().map(|x| x.batches).sum();
                        assert!(
                            replica_batches >= policy_batches,
                            "replica batch counts ({replica_batches}) behind the \
                             policy totals ({policy_batches}) observed earlier"
                        );
                        // render must never deadlock or panic mid-traffic
                        let _ = rec_r.render();
                        observations += 1;
                    }
                    observations
                });
                for w in writers {
                    w.join().expect("writer");
                }
                stop.store(true, Ordering::SeqCst);
                assert!(reader.join().expect("reader lives") > 0);
            });

            // final reconciliation: exactly what the tapes did
            let mut want: Vec<PolicyStats> =
                vec![PolicyStats::default(), PolicyStats::default(), PolicyStats::default()];
            let mut want_reps = vec![ReplicaStats::default(); replicas];
            for op in tapes.iter().flatten() {
                match *op {
                    Op::Req { p, err } => {
                        let w = &mut want[p as usize];
                        w.requests += 1;
                        if err {
                            w.errors += 1;
                        } else {
                            w.completed += 1;
                        }
                    }
                    Op::Expired { p } => {
                        want[p as usize].requests += 1;
                        want[p as usize].expired += 1;
                    }
                    Op::Shed { p } => want[p as usize].shed += 1,
                    Op::Governed { p } => want[p as usize].governed += 1,
                    Op::Failed { p } => {
                        want[p as usize].requests += 1;
                        want[p as usize].failed += 1;
                    }
                    // additive health fields reconcile exactly; the
                    // last-writer-wins ones (generation, beat age) race
                    // across tapes by design and are only bounds-checked
                    Op::Event(PoolEvent::ReplicaFailed { replica, failed_batches, .. }) => {
                        want_reps[replica].swept += failed_batches;
                    }
                    Op::Event(PoolEvent::ReplicaRestarted { replica, .. }) => {
                        want_reps[replica].restarts += 1;
                    }
                    Op::Event(PoolEvent::ReplicaExcluded { replica }) => {
                        want_reps[replica].excluded = true;
                    }
                    Op::Event(PoolEvent::Heartbeat { .. }) => {}
                    Op::Batch { p, rows, real_tok, padded_tok, rep } => {
                        want[p as usize].batches += 1;
                        want[p as usize].batched_rows += rows as u64;
                        want[p as usize].real_tokens += real_tok as u64;
                        want[p as usize].padded_tokens += padded_tok as u64;
                        want_reps[rep].batches += 1;
                        want_reps[rep].rows += rows as u64;
                    }
                }
            }
            let snap = rec.snapshot();
            for (i, name) in ["fp", "m3", "attn-out-fp"].iter().enumerate() {
                let got = snap.get(*name).cloned().unwrap_or_default();
                let w = &want[i];
                assert_eq!(
                    (got.requests, got.completed, got.errors, got.expired, got.failed),
                    (w.requests, w.completed, w.errors, w.expired, w.failed),
                    "{name} terminal counts"
                );
                assert_eq!((got.shed, got.governed), (w.shed, w.governed), "{name} ledger");
                assert_eq!(
                    (got.batches, got.batched_rows),
                    (w.batches, w.batched_rows),
                    "{name} batches"
                );
                assert_eq!(
                    (got.real_tokens, got.padded_tokens),
                    (w.real_tokens, w.padded_tokens),
                    "{name} padding ledger"
                );
            }
            let reps = rec.replica_snapshot();
            for (i, w) in want_reps.iter().enumerate() {
                assert_eq!((reps[i].batches, reps[i].rows), (w.batches, w.rows), "replica {i}");
                assert_eq!(
                    (reps[i].restarts, reps[i].swept, reps[i].excluded),
                    (w.restarts, w.swept, w.excluded),
                    "replica {i} health ledger"
                );
            }
        });
    }

    #[test]
    fn per_replica_batch_counts_sum_to_policy_totals() {
        let r = Recorder::new(vec!["fp".into(), "m3".into()], 3);
        r.record_batch(PolicyId(0), 4, 200, 512, 100, 0);
        r.record_batch(PolicyId(1), 2, 30, 32, 100, 2);
        r.record_batch(PolicyId(1), 1, 10, 16, 100, 2);
        let reps = r.replica_snapshot();
        assert_eq!(reps.len(), 3);
        let per_policy: u64 = r.snapshot().values().map(|s| s.batches).sum();
        let per_replica: u64 = reps.iter().map(|x| x.batches).sum();
        assert_eq!(per_replica, per_policy);
        assert_eq!(reps[0].batches, 1);
        assert_eq!(reps[0].rows, 4);
        assert_eq!(reps[1].batches, 0, "idle replicas keep their slot");
        assert_eq!(reps[2].batches, 2);
        assert_eq!(reps[2].rows, 3);
        // multi-replica render appends the per-replica table
        assert!(r.render().contains("replica"));
    }

    #[test]
    fn replica_health_ledger_and_render() {
        let r = Recorder::new(vec!["fp".into()], 3);
        r.record_failed(PolicyId(0));
        r.record_pool_event(PoolEvent::ReplicaFailed {
            replica: 1,
            generation: 0,
            failed_batches: 2,
        });
        r.record_pool_event(PoolEvent::ReplicaRestarted { replica: 1, generation: 1 });
        r.record_pool_event(PoolEvent::Heartbeat { replica: 0, generation: 0, age_us: 1500 });
        r.record_pool_event(PoolEvent::ReplicaExcluded { replica: 2 });
        let snap = r.snapshot();
        let s = &snap["fp"];
        assert_eq!(s.failed, 1);
        assert_eq!(s.requests, s.completed + s.errors + s.expired + s.failed);
        let reps = r.replica_snapshot();
        assert_eq!((reps[1].swept, reps[1].restarts, reps[1].generation), (2, 1, 1));
        assert_eq!(reps[0].beat_age_us, 1500);
        assert!(reps[2].excluded && !reps[0].excluded);
        let table = r.render();
        assert!(table.contains("restarts") && table.contains("beat age"));
        assert!(table.contains("excluded") && table.contains("failed"));
    }

    /// Hot-reload ledger (DESIGN.md §5.13): each manifest version gets
    /// its own slot block, keyed `name@vN`, and reconciles independently
    /// — the acceptance identity `requests == completed + errors +
    /// expired + failed` must hold on both versions' ledgers after a
    /// mid-run reload.
    #[test]
    fn versioned_slots_reconcile_per_version() {
        let r = Recorder::new(vec!["fp".into(), "m3".into()], 1);
        let m3 = PolicyId(1);
        // v0 traffic under the bare name
        r.record_request_at(0, m3, 1000, 100, false);
        r.record_failed_at(0, m3);
        // reload publishes v1; draining v0 requests keep landing on v0
        r.register_version(1);
        r.record_request_at(1, m3, 900, 80, false);
        r.record_request_at(1, m3, 950, 90, true);
        r.record_expired_at(1, m3, 5000);
        r.record_shed_at(1, m3);
        r.record_governed_at(1, m3);
        r.record_batch_at(1, m3, 4, 100, 256, 300, 0);
        r.record_request_at(0, m3, 1100, 120, false);

        let snap = r.snapshot();
        let v0 = &snap["m3"];
        assert_eq!((v0.requests, v0.completed, v0.failed), (3, 2, 1));
        assert_eq!(v0.requests, v0.completed + v0.errors + v0.expired + v0.failed);
        assert_eq!(v0.batches, 0, "v1 batches must not leak into v0");
        let v1 = &snap["m3@v1"];
        assert_eq!((v1.requests, v1.completed, v1.errors, v1.expired), (3, 1, 1, 1));
        assert_eq!(v1.requests, v1.completed + v1.errors + v1.expired + v1.failed);
        assert_eq!((v1.shed, v1.governed, v1.batches), (1, 1, 1));
        // the idle fp@v1 slot stays hidden like any idle policy
        assert!(!snap.contains_key("fp@v1"));
        assert!(r.render().contains("m3@v1"));

        // record paths self-heal an unregistered version (defense in
        // depth — registration normally precedes publication)
        r.record_shed_at(3, PolicyId(0));
        assert_eq!(r.snapshot()["fp@v3"].shed, 1);
    }

    /// Residency events fold into the per-replica cache ledger and the
    /// render grows the residency table (DESIGN.md §5.13).
    #[test]
    fn residency_ledger_accumulates_and_renders() {
        let r = Recorder::new(vec!["fp".into()], 2);
        assert!(r.residency_snapshot().iter().all(|x| !x.active()));
        assert!(!r.render().contains("p50 load"), "idle residency stays out of the render");
        // replica 0: two pin loads at startup, then a hit and a demand miss
        for _ in 0..2 {
            r.record_pool_event(PoolEvent::CellLoaded {
                replica: 0,
                load_us: 4000,
                pinned: true,
                resident: 1,
            });
        }
        r.record_pool_event(PoolEvent::ResidencyLookup { replica: 0, hit: true, wait_us: 0 });
        r.record_pool_event(PoolEvent::ResidencyLookup { replica: 0, hit: false, wait_us: 7000 });
        r.record_pool_event(PoolEvent::CellLoaded {
            replica: 0,
            load_us: 6000,
            pinned: false,
            resident: 3,
        });
        r.record_pool_event(PoolEvent::CellEvicted { replica: 0, resident: 2 });
        let res = r.residency_snapshot();
        assert_eq!((res[0].hits, res[0].misses), (1, 1));
        assert_eq!((res[0].loads, res[0].pinned_loads, res[0].evictions), (3, 2, 1));
        assert_eq!(res[0].resident, 2, "resident tracks the latest event");
        assert_eq!(res[0].load_us.count(), 3);
        assert_eq!(res[0].wait_us.count(), 1, "only misses record a wait");
        assert!(!res[1].active(), "untouched replica keeps a zero ledger");
        let table = r.render();
        assert!(table.contains("p50 load") && table.contains("evicted"));
    }
}
