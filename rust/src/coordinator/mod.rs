//! L3 coordinator: the paper's missing "end-to-end system" — typed
//! request specs, dynamic batching, per-request precision *policies*
//! (whole-model mode + per-module overrides + fallback escalation),
//! bounded admission with explicit backpressure, per-request deadlines,
//! a load-adaptive precision governor, and serving metrics over the
//! PJRT engine replica pool.

pub mod batcher;
pub mod governor;
pub mod net;
pub mod node;
pub mod request;
pub mod server;
pub mod stats;

pub use batcher::{Batch, Batcher, Drained};
pub use governor::{GovernorConfig, GovernorShared, PrecisionGovernor, Signals, StepEvent};
pub use request::{GroupKey, PolicyRef, Request, RequestSpec, Response, Timing};
pub use server::{ConfigError, Coordinator, ServerConfig, SubmitError};
pub use net::{Admission, BackoffSchedule, NetClient, NetServer};
pub use node::{EngineNode, FrontEnd, FrontEndConfig, NodeDispatch, NodeKey};
pub use stats::{Histogram, PolicyStats, Recorder, ReplicaStats};
