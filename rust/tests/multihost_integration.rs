//! Multi-host tier integration (DESIGN.md §5.14) on the fake engine: a
//! `FrontEnd` routing over real TCP links to `EngineNode` processes-worth
//! of coordinators, with node death, typed cross-tier outcomes, and
//! exact per-tier ledger reconciliation.
//!
//! The invariants under test:
//!   * no client ever hangs: every admitted request gets exactly one
//!     terminal reply no matter when an engine node dies;
//!   * `admitted = completed + shed + expired + failed` holds exactly on
//!     the client ledger, the front tier's recorder, and every surviving
//!     node's recorder;
//!   * node-side outcomes cross the link typed (`busy` is a shed, not an
//!     error string the front end re-parses);
//!   * a killed node re-joins (fresh process, fresh ephemeral port, via
//!     `FrontEnd::relocate`) and dispatch spreads work across the
//!     restored fleet.

use std::sync::Arc;
use std::time::{Duration, Instant};

use zqhero::coordinator::{
    Coordinator, EngineNode, FrontEnd, FrontEndConfig, RequestSpec, Response, ServerConfig,
};
use zqhero::sync::mpsc::Receiver;

/// Two tasks x two modes = four (task, policy) groups, so dispatch has
/// concurrent groups to spread across nodes (a single group pins to one
/// node while it has requests in flight).  Checkpoints are declared but
/// never opened under `fake_engine`.
const MANIFEST: &str = r#"{
  "model": {"vocab_size": 64, "hidden": 8, "layers": 1, "heads": 2, "ffn": 16,
            "max_seq": 8, "type_vocab": 2, "num_labels": 3, "ln_eps": 0.00001},
  "seq": 8,
  "buckets": [1, 2, 4],
  "modes": {
    "fp": {
      "switches": {"embedding": false, "qkv": false, "attn": false,
                   "attn_output": false, "fc1": false, "fc2": false},
      "artifacts": {},
      "params": []
    },
    "m3": {
      "switches": {"embedding": true, "qkv": true, "attn": true,
                   "attn_output": true, "fc1": true, "fc2": true},
      "artifacts": {},
      "params": []
    }
  },
  "calib": {"artifact": "calib.bin", "batch": 1, "params": [], "stats": []},
  "tasks": {
    "mh-a": {"splits": {}, "metrics": [], "classes": 3, "checkpoint": "ckpt-{mode}.bin"},
    "mh-b": {"splits": {}, "metrics": [], "classes": 3, "checkpoint": "ckpt-{mode}.bin"}
  }
}"#;

fn fake_artifacts(test: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("zqhero-multihost-{}-{test}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create fake artifacts dir");
    std::fs::write(dir.join("manifest.json"), MANIFEST).expect("write fake manifest");
    dir
}

fn groups() -> Vec<(String, String)> {
    ["mh-a", "mh-b"]
        .iter()
        .flat_map(|t| ["fp", "m3"].iter().map(move |m| (t.to_string(), m.to_string())))
        .collect()
}

fn node_config(latency_ms: u64, queue_cap: usize) -> ServerConfig {
    ServerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_cap,
        fake_engine: Some(Duration::from_millis(latency_ms)),
        ..ServerConfig::default()
    }
}

fn start_node(
    dir: &std::path::Path,
    latency_ms: u64,
    queue_cap: usize,
) -> (Arc<Coordinator>, EngineNode) {
    let coord = Arc::new(
        Coordinator::start(dir.to_path_buf(), &groups(), node_config(latency_ms, queue_cap))
            .expect("start node coordinator"),
    );
    let node = EngineNode::start(Arc::clone(&coord), "127.0.0.1", 0).expect("start engine node");
    (coord, node)
}

/// The i-th burst request: round-robin over the four groups, payload
/// length sweeping the seq range so both seq classes appear.
fn spec(i: usize) -> RequestSpec {
    let g = groups();
    let (task, policy) = &g[i % g.len()];
    let len = 1 + i % 8;
    RequestSpec::task(task).policy(policy).ids((0..len as i32).collect())
}

/// Drain every receiver with a generous bound: a reply that never
/// arrives is precisely the hung-client bug the sweep discipline exists
/// to prevent.
fn drain(rxs: Vec<(u64, Receiver<Response>)>) -> Vec<Response> {
    rxs.into_iter()
        .map(|(i, rx)| {
            rx.recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|_| panic!("client hung: request {i} never got a terminal reply"))
        })
        .collect()
}

struct Outcomes {
    completed: usize,
    shed: usize,
    expired: usize,
    failed: usize,
}

fn classify(resps: &[Response], num_labels: usize) -> Outcomes {
    let mut o = Outcomes { completed: 0, shed: 0, expired: 0, failed: 0 };
    for r in resps {
        if r.busy {
            assert!(r.error.is_some(), "busy reply must say so");
            o.shed += 1;
        } else if r.expired {
            o.expired += 1;
        } else if r.failed {
            assert!(r.error.is_some(), "typed failure must carry an error");
            o.failed += 1;
        } else {
            assert!(r.error.is_none(), "unexpected error class: {:?}", r.error);
            assert_eq!(r.logits.len(), num_labels, "completed reply must carry logits");
            o.completed += 1;
        }
    }
    o
}

fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Sum the (completed, shed, expired, failed, requests, errors) ledger
/// across a recorder snapshot, asserting the per-policy identity.
fn ledger(rec: &zqhero::coordinator::Recorder, tier: &str) -> (u64, u64, u64, u64) {
    let (mut c, mut sh, mut ex, mut fl) = (0u64, 0u64, 0u64, 0u64);
    for (name, s) in rec.snapshot() {
        assert_eq!(
            s.requests,
            s.completed + s.errors + s.expired + s.failed,
            "{tier} ledger identity broken for policy {name}"
        );
        assert_eq!(s.errors, 0, "{tier} saw untyped errors for policy {name}");
        c += s.completed;
        sh += s.shed;
        ex += s.expired;
        fl += s.failed;
    }
    (c, sh, ex, fl)
}

#[test]
fn two_tier_serves_end_to_end_with_exact_ledgers() {
    let dir = fake_artifacts("baseline");
    let (c0, n0) = start_node(&dir, 2, 256);
    let (c1, n1) = start_node(&dir, 2, 256);
    let fe = FrontEnd::start(&dir, &[n0.addr, n1.addr], FrontEndConfig::default())
        .expect("start front end");
    assert_eq!(fe.live_nodes(), 2);

    let mut rxs = Vec::new();
    for i in 0..64u64 {
        rxs.push((i, fe.submit(spec(i as usize)).expect("admit")));
    }
    let out = classify(&drain(rxs), fe.num_labels());
    assert_eq!(out.completed, 64);
    assert_eq!((out.shed, out.expired, out.failed), (0, 0, 0));

    // front tier ledger agrees exactly with the client's
    let (fc, fsh, fex, ffl) = ledger(fe.recorder(), "front");
    assert_eq!((fc, fsh, fex, ffl), (64, 0, 0, 0));
    assert_eq!(fe.queue_depth(), 0, "front-end backlog slots leaked");

    // node tier: each node's ledger holds, the aggregate equals the
    // front's exactly (fault-free run — at-least-once never retried),
    // and with four concurrent groups both nodes did real work
    let (n0c, ..) = ledger(&c0.recorder, "node 0");
    let (n1c, ..) = ledger(&c1.recorder, "node 1");
    assert_eq!(n0c + n1c, 64, "tier ledgers disagree");
    assert!(n0c > 0 && n1c > 0, "dispatch never spread the groups: {n0c} vs {n1c}");
    assert_eq!(c0.queue_depth() + c1.queue_depth(), 0, "node backlog slots leaked");
}

#[test]
fn node_side_busy_crosses_the_wire_typed_as_shed() {
    let dir = fake_artifacts("busy");
    // tiny node queue, slow batches: most of a flood must shed node-side
    let (c0, n0) = start_node(&dir, 40, 2);
    let fe = FrontEnd::start(&dir, &[n0.addr], FrontEndConfig::default())
        .expect("start front end");

    let mut rxs = Vec::new();
    for i in 0..32u64 {
        rxs.push((i, fe.submit(spec(i as usize)).expect("front admits (cap 1024)")));
    }
    let resps = drain(rxs);
    let out = classify(&resps, fe.num_labels());
    assert_eq!(out.completed + out.shed, 32, "only completed/busy outcomes expected");
    assert!(out.shed > 0, "node admission bound never tripped — not a backpressure test");
    assert_eq!((out.expired, out.failed), (0, 0));
    // the busy replies came back typed, not as re-parsed error strings
    assert!(resps.iter().filter(|r| r.busy).all(|r| !r.failed && !r.expired));

    // remote shed lands in the front tier's shed column, same class as a
    // local admission shed — and the node's own ledger agrees
    let (fc, fsh, _, _) = ledger(fe.recorder(), "front");
    assert_eq!((fc as usize, fsh as usize), (out.completed, out.shed));
    let (nc, nsh, _, _) = ledger(&c0.recorder, "node 0");
    assert_eq!((nc as usize, nsh as usize), (out.completed, out.shed));
    assert_eq!(fe.queue_depth(), 0, "front-end backlog slots leaked");
}

#[test]
fn node_death_mid_burst_no_hangs_exact_ledgers_and_rejoin_restores_goodput() {
    let dir = fake_artifacts("chaos");
    // slow batches so the kill lands with work genuinely in flight
    let (c0, n0) = start_node(&dir, 20, 256);
    let (c1, n1) = start_node(&dir, 20, 256);
    let fe = FrontEnd::start(&dir, &[n0.addr, n1.addr], FrontEndConfig::default())
        .expect("start front end");

    // open-loop paced burst; kill node 0 (listener AND coordinator —
    // the whole process, as far as the front end can tell) mid-stream
    let mut n0 = Some(n0);
    let mut c0 = Some(c0);
    let mut rxs = Vec::new();
    for i in 0..96u64 {
        if i == 32 {
            drop(n0.take());
            drop(c0.take());
        }
        rxs.push((i, fe.submit(spec(i as usize)).expect("admit")));
        std::thread::sleep(Duration::from_millis(1));
    }

    // no client hangs: every admitted request gets a terminal reply even
    // though a node died holding some of them
    let out = classify(&drain(rxs), fe.num_labels());
    assert_eq!(
        out.completed + out.shed + out.expired + out.failed,
        96,
        "client ledger does not reconcile"
    );
    // in-flight work swept off the dead node retried on the live one:
    // with a healthy survivor, nothing should exhaust its attempts
    assert_eq!(out.failed, 0, "retry-on-live-node failed despite a healthy survivor");
    assert_eq!(out.expired, 0, "no deadlines in this burst");

    // both tiers' ledgers reconcile exactly; the survivor's completed
    // count can only exceed the front's by re-executions of swept
    // requests whose first reply died with node 0 (at-least-once)
    let (fc, fsh, fex, ffl) = ledger(fe.recorder(), "front");
    assert_eq!(
        (fc as usize, fsh as usize, fex as usize, ffl as usize),
        (out.completed, out.shed, out.expired, out.failed),
        "front recorder disagrees with the client ledger"
    );
    let (n1c, ..) = ledger(&c1.recorder, "node 1 (survivor)");
    assert!(
        n1c as usize <= out.completed && n1c > 0,
        "survivor executed {n1c} vs {} client completions",
        out.completed
    );
    assert_eq!(fe.queue_depth(), 0, "front-end backlog slots leaked");
    assert_eq!(c1.queue_depth(), 0, "survivor backlog slots leaked");
    assert!(!fe.dispatch().alive(0), "dead node still admitted to dispatch");

    // supervised re-join: a fresh node process on a fresh ephemeral port
    // takes over slot 0; the link supervisor must pick it up and revive
    // the slot
    let (c0b, n0b) = start_node(&dir, 20, 256);
    fe.relocate(0, n0b.addr);
    wait_until("node 0 re-join", || fe.live_nodes() == 2);

    // goodput restored: a second burst completes in full and dispatch
    // spreads the groups across the restored fleet again
    let mut rxs = Vec::new();
    for i in 0..64u64 {
        rxs.push((i, fe.submit(spec(i as usize)).expect("admit after re-join")));
    }
    let out2 = classify(&drain(rxs), fe.num_labels());
    assert_eq!(out2.completed, 64, "re-joined tier did not restore goodput");
    let (rc, ..) = ledger(&c0b.recorder, "node 0 (re-joined)");
    assert!(rc > 0, "re-joined node never received work");
    assert_eq!(fe.queue_depth(), 0, "front-end backlog slots leaked after re-join");

    drop(fe);
    drop((c1, n1, c0b, n0b));
}
