"""``GeMM^quant`` — INT8 matrix multiply with folded-scale epilogues
(paper eqs. 14, 18, 20-22, 28, 30), as a Pallas kernel.

TPU adaptation (DESIGN.md §7): tensor-core MMA becomes an MXU
``dot_general`` with ``preferred_element_type=int32``.  The kernel tiles the
output ``[block_n, block_m]`` with the full contraction dimension resident
in VMEM (k <= 512 here; an A100 CUDA kernel would split-K, the MXU pipeline
does not need to at these sizes).  Because every scale is pre-folded into
the weight (eqs. 20-23, 32), the epilogue applied to the int32 accumulator
tile is a single fused multiply(+bias) and, for INT8 outputs, a bare
``Round`` — the paper's key "no extra kernel" property.

Epilogue variants (static at lowering):
  * x_scale='twq'   : per-token [n,1] runtime scales enter the epilogue.
  * x_scale='folded': input scale already folded into W (FWQ inputs).
  * out='i8'        : Round+clamp to int8 (output scale folded away).
  * out='f32'       : dequantized f32 output (+bias).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 127.0


def _pick(n, want):
    b = min(n, want)
    while n % b:
        b -= 1
    return b


def _gemm_kernel(*refs, twq_in, out_i8):
    """Ref order: [x, w, xs?, ws, b] -> [y]."""
    it = iter(refs)
    x_ref = next(it)
    w_ref = next(it)
    xs_ref = next(it) if twq_in else None
    ws_ref = next(it)
    b_ref = next(it)
    y_ref = next(it)

    acc = jax.lax.dot_general(
        x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    if twq_in:
        acc = acc * xs_ref[...]          # [bn,1] per-token
    y = acc * ws_ref[...] + b_ref[...]   # [1,bm] column scale + bias
    if out_i8:
        y_ref[...] = jnp.clip(jnp.round(y), -QMAX, QMAX).astype(jnp.int8)
    else:
        y_ref[...] = y


def _gemm(x_i8, w_i8, x_scale, w_scale, bias, *, out_i8,
          block_n=None, block_m=None):
    n, k = x_i8.shape
    k2, m = w_i8.shape
    assert k == k2, (x_i8.shape, w_i8.shape)
    # [256, 512] output tile: int32 accumulator 512 KB + int8 operands
    # (x 256xk <= 128 KB, w kx512 <= 256 KB) stays within VMEM while
    # cutting grid steps 8-16x vs the original 64x128 tiles (§Perf).
    bn = block_n or _pick(n, 256)
    bm = block_m or _pick(m, 512)
    twq_in = x_scale is not None

    args = [x_i8, w_i8]
    in_specs = [
        pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
        pl.BlockSpec((k, bm), lambda i, j: (0, j)),
    ]
    if twq_in:
        args.append(x_scale)
        in_specs.append(pl.BlockSpec((bn, 1), lambda i, j: (i, 0)))
    args += [w_scale.reshape(1, m), bias.reshape(1, m)]
    in_specs += [pl.BlockSpec((1, bm), lambda i, j: (0, j))] * 2

    out_dtype = jnp.int8 if out_i8 else jnp.float32
    kernel = functools.partial(_gemm_kernel, twq_in=twq_in, out_i8=out_i8)
    return pl.pallas_call(
        kernel,
        grid=(n // bn, m // bm),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bn, bm), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((n, m), out_dtype)],
        interpret=True,
    )(*args)[0]


def gemm_twq_to_i8(x_i8, w_i8, x_scale, w_scale, bias, **kw):
    """TWQ-int8 x folded-int8-W -> int8 (eq. 22: requant == Round)."""
    return _gemm(x_i8, w_i8, x_scale, w_scale, bias, out_i8=True, **kw)


def gemm_twq_to_f32(x_i8, w_i8, x_scale, w_scale, bias, **kw):
    """TWQ-int8 x int8-W -> f32 (dequant epilogue; FC1, eq. 28)."""
    return _gemm(x_i8, w_i8, x_scale, w_scale, bias, out_i8=False, **kw)


def gemm_folded_to_i8(x_i8, w_i8, w_scale, bias, **kw):
    """FWQ-folded int8 x folded-int8-W -> int8 (eqs. 23/32 epilogue)."""
    return _gemm(x_i8, w_i8, None, w_scale, bias, out_i8=True, **kw)


def gemm_folded_to_f32(x_i8, w_i8, w_scale, bias, **kw):
    """FWQ-folded int8 x int8-W -> f32 (mode-fallback dequant)."""
    return _gemm(x_i8, w_i8, None, w_scale, bias, out_i8=False, **kw)
