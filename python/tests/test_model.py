"""L2 correctness: FP encoder, HERO quantized encoder (all modes + extra
switch combos), calibration statistics, and the PTQ transform."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig, MODES, QuantSwitches, switches_from_tag
from compile.modeling import (
    fp_param_specs, hero_param_specs, init_fp_params, bert_forward,
    hero_forward, calibration_forward, quantize_checkpoint,
)
from compile.data import attn_mask

CFG = ModelConfig(vocab_size=256, hidden=64, layers=2, heads=4, ffn=128,
                  max_seq=32)


@pytest.fixture(scope="module")
def setup():
    fp = init_fp_params(CFG, seed=3)
    r = np.random.default_rng(0)
    ids = np.full((4, 32), 0, np.int32)
    for i in range(4):
        n = r.integers(8, 32)
        ids[i, :n] = r.integers(4, CFG.vocab_size, n)
        ids[i, 0] = 1
    ty = np.zeros((4, 32), np.int32)
    mask = attn_mask(ids)
    fpj = {k: jnp.asarray(v) for k, v in fp.items()}
    logits, stats = calibration_forward(fpj, CFG, jnp.asarray(ids), jnp.asarray(ty),
                                        jnp.asarray(mask))
    stats = {k: np.asarray(v) for k, v in stats.items()}
    return fp, fpj, ids, ty, mask, np.asarray(logits), stats


def run_hero(fp, stats, sw, ids, ty, mask):
    hq = quantize_checkpoint(fp, stats, CFG, sw)
    hqj = {k: jnp.asarray(v) for k, v in hq.items()}
    return np.asarray(hero_forward(hqj, CFG, sw, jnp.asarray(ids),
                                   jnp.asarray(ty), jnp.asarray(mask))), hq


# ------------------------------------------------------------- spec parity


@pytest.mark.parametrize("tag", [f"{i:06b}" for i in range(64)])
def test_quantize_matches_specs_all_combos(tag, setup):
    """quantize_checkpoint output must match hero_param_specs exactly for
    every one of the 64 switch combinations (names, order, shape, dtype)."""
    fp, _, _, _, _, _, stats = setup
    sw = switches_from_tag(tag)
    hq = quantize_checkpoint(fp, stats, CFG, sw)
    specs = hero_param_specs(CFG, sw)
    assert list(hq.keys()) == [n for n, _, _ in specs]
    for name, shape, dt in specs:
        assert tuple(hq[name].shape) == shape, (name, hq[name].shape, shape)
        want = np.int8 if dt == "i8" else np.float32
        assert hq[name].dtype == want, (name, hq[name].dtype)


def test_fp_specs_cover_init():
    fp = init_fp_params(CFG, seed=0)
    assert list(fp.keys()) == [n for n, _, _ in fp_param_specs(CFG)]


# -------------------------------------------------------- mode divergence


@pytest.mark.parametrize("mode", ["m1", "m2", "m3"])
def test_hero_mode_close_to_fp(mode, setup):
    fp, _, ids, ty, mask, logits_fp, stats = setup
    lo, _ = run_hero(fp, stats, MODES[mode], ids, ty, mask)
    diff = np.abs(lo - logits_fp).max()
    scale = np.abs(logits_fp).max() + 1e-6
    assert diff / scale < 0.25, (mode, diff, scale)
    # predictions (argmax) should mostly agree on random inputs
    agree = (lo.argmax(-1) == logits_fp.argmax(-1)).mean()
    assert agree >= 0.75, (mode, agree)


@pytest.mark.parametrize("tag", ["010000", "011000", "011100", "110110",
                                 "100010", "111010"])
def test_hero_extra_switch_combos_run(tag, setup):
    """Non-preset combinations (incl. the 'unfused quantize' fallbacks)
    must run and stay near FP."""
    fp, _, ids, ty, mask, logits_fp, stats = setup
    lo, _ = run_hero(fp, stats, switches_from_tag(tag), ids, ty, mask)
    assert np.isfinite(lo).all()
    diff = np.abs(lo - logits_fp).max() / (np.abs(logits_fp).max() + 1e-6)
    assert diff < 0.35, (tag, diff)


def test_all_off_equals_fp(setup):
    fp, fpj, ids, ty, mask, logits_fp, stats = setup
    lo, _ = run_hero(fp, stats, QuantSwitches(), ids, ty, mask)
    np.testing.assert_allclose(lo, logits_fp, rtol=2e-4, atol=2e-5)


# ------------------------------------------------------------ calibration


def test_calibration_stats_shapes_and_positivity(setup):
    _, _, _, _, _, _, stats = setup
    L, d, f = CFG.layers, CFG.hidden, CFG.ffn
    assert stats["q_absmax"].shape == (L,)
    assert stats["attn_absmax"].shape == (L, d)
    assert stats["gelu_absmax"].shape == (L, f)
    assert stats["x2_absmax"].shape == (L, d)
    for k, v in stats.items():
        assert (v >= 0).all(), k
        assert np.isfinite(v).all(), k
    # softmax output max must be <= 1 and > 0
    assert (stats["p_max"] <= 1.0 + 1e-6).all()
    assert (stats["p_max"] > 0).all()


def test_calibration_masks_pad_tokens(setup):
    """Stats must not change when garbage is placed in PAD positions."""
    fp, fpj, ids, ty, mask, _, stats = setup
    ids2 = ids.copy()
    pad_pos = ids2 == 0
    assert pad_pos.any()
    ids2[pad_pos] = 200  # garbage tokens at masked positions
    _, stats2 = calibration_forward(fpj, CFG, jnp.asarray(ids2), jnp.asarray(ty),
                                    jnp.asarray(mask))
    for k in stats:
        np.testing.assert_allclose(stats[k], np.asarray(stats2[k]), rtol=1e-5,
                                   err_msg=k)


def test_calibration_logits_match_plain_forward(setup):
    fp, fpj, ids, ty, mask, logits_fp, _ = setup
    plain = bert_forward(fpj, CFG, jnp.asarray(ids), jnp.asarray(ty), jnp.asarray(mask))
    np.testing.assert_allclose(logits_fp, np.asarray(plain), rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------- PTQ transform


def test_folded_weights_reconstruct(setup):
    """W~_2 folding (eq. 32): dequantized folded weight must equal
    diag(S_a) W diag(1/S_x2) within the weight-quant step."""
    fp, _, _, _, _, _, stats = setup
    sw = MODES["m3"]
    hq = quantize_checkpoint(fp, stats, CFG, sw)
    from compile.modeling.quantize import derive_scales
    sc = derive_scales(stats, CFG)[0]
    wt_expected = (sc["s_a"][:, None] * fp["L0.fc2.w"]) / sc["s_x2"][None, :]
    recon = hq["L0.fc2.wq"].astype(np.float32) * hq["L0.fc2.ws"][None, :]
    step = hq["L0.fc2.ws"][None, :]
    assert (np.abs(recon - wt_expected) <= step / 2 + 1e-6).all()


def test_sq_fold_makes_round_exact(setup):
    """After eq. 20-22 folding, requantizing X_q needs no division: the
    epilogue scale S_in*S~_w already lands in the S_q domain."""
    fp, _, _, _, _, _, stats = setup
    sw = MODES["m3"]
    hq = quantize_checkpoint(fp, stats, CFG, sw)
    from compile.modeling.quantize import derive_scales
    sc = derive_scales(stats, CFG)[0]
    # W~_q * S_q must reconstruct W_q within quant error
    recon = (hq["L0.attn.q.wq"].astype(np.float32) * hq["L0.attn.q.ws"][None, :]
             * sc["sq_q"])
    err = np.abs(recon - fp["L0.attn.q.w"])
    step = hq["L0.attn.q.ws"][None, :] * sc["sq_q"]
    assert (err <= step / 2 + 1e-6).all()


def test_percentile_clipping_shrinks_scales(setup):
    fp, _, _, _, _, _, stats = setup
    from compile.modeling.quantize import derive_scales
    # build a fake 5-batch history with one outlier batch
    hist = {k: np.stack([v, v * 0.9, v * 0.95, v * 1.05, v * 10.0])
            for k, v in stats.items()}
    full = derive_scales(hist, CFG, pct=100.0)
    clipped = derive_scales(hist, CFG, pct=75.0)
    assert clipped[0]["sq_q"] < full[0]["sq_q"]
    assert (clipped[0]["s_attn"] <= full[0]["s_attn"] + 1e-12).all()
