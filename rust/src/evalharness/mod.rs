//! Evaluation harness: regenerates the paper's Table 2 (GLUE validation
//! accuracy per quantization mode) plus the Discussion ablations, entirely
//! in rust over the PJRT runtime.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::calib::{load_history, run_calibration, save_history, truncate_history, StatHistory};
use crate::data::{batches, Labels, Split};
use crate::metrics;
use crate::model::manifest::TaskSpec;
use crate::model::Container;
use crate::quant::{quantize_checkpoint, validate_against_mode, AggStats};
use crate::runtime::Runtime;

pub const DEFAULT_CALIB_BATCHES: usize = 100; // paper §3
pub const EVAL_BUCKET: usize = 16;

// ------------------------------------------------------------- pipeline

/// Load (or run + cache) the 100-batch calibration history for a task.
pub fn ensure_calibration(
    rt: &mut Runtime,
    task: &TaskSpec,
    num_batches: usize,
    force: bool,
) -> Result<StatHistory> {
    let path = rt
        .manifest
        .path(&format!("checkpoints/{}/calib.json", task.name));
    if path.exists() && !force {
        let hist = load_history(&path)?;
        if hist.first().map(|(_, b)| b.len()).unwrap_or(0) >= num_batches {
            return Ok(truncate_history(&hist, num_batches));
        }
    }
    let fp = Container::read_file(&rt.manifest.path(&task.checkpoint))?;
    let hist = run_calibration(rt, task, &fp, num_batches)?;
    save_history(&path, &hist, num_batches)?;
    Ok(hist)
}

/// Quantize one task for one mode; writes `checkpoints/<task>/hero-<mode>.bin`
/// (or a custom suffix for ablations) and returns the container.
pub fn quantize_task(
    rt: &mut Runtime,
    task: &TaskSpec,
    mode: &str,
    hist: &StatHistory,
    pct: f64,
    suffix: Option<&str>,
) -> Result<Container> {
    let mode_spec = rt.manifest.mode(mode)?.clone();
    let fp = Container::read_file(&rt.manifest.path(&task.checkpoint))?;
    let stats = AggStats::from_history(hist, &rt.manifest.model, pct)?;
    let ckpt = quantize_checkpoint(&fp, &stats, &rt.manifest.model, &mode_spec.switches)?;
    validate_against_mode(&ckpt, &mode_spec)?;
    let name = match suffix {
        Some(s) => format!("checkpoints/{}/hero-{mode}-{s}.bin", task.name),
        None => format!("checkpoints/{}/hero-{mode}.bin", task.name),
    };
    ckpt.write_file(&rt.manifest.path(&name))?;
    Ok(ckpt)
}

/// Make sure the runtime has a device-resident checkpoint for (task, mode):
/// fp comes straight from disk; quantized modes are derived on demand.
pub fn ensure_checkpoint(
    rt: &mut Runtime,
    task: &TaskSpec,
    mode: &str,
    calib_batches: usize,
    pct: f64,
) -> Result<()> {
    if rt.has_checkpoint(&task.name, mode) {
        return Ok(());
    }
    let ckpt = if mode == "fp" {
        let specs = rt.manifest.mode("fp")?.params.clone();
        Container::read_file(&rt.manifest.path(&task.checkpoint))?.reordered(&specs)?
    } else {
        let rel = task.checkpoint_rel(mode);
        let path = rt.manifest.path(&rel);
        if path.exists() && calib_batches == DEFAULT_CALIB_BATCHES && pct >= 100.0 {
            Container::read_file(&path)?
        } else {
            let hist = ensure_calibration(rt, task, calib_batches.max(1), false)?;
            let hist = truncate_history(&hist, calib_batches.max(1));
            quantize_task(rt, task, mode, &hist, pct, None)?
        }
    };
    rt.upload_checkpoint(&task.name, mode, &ckpt)
}

// ------------------------------------------------------------- evaluation

/// Run a dev split through the model; returns (preds-or-scores, labels).
pub fn predict_split(
    rt: &mut Runtime,
    task: &TaskSpec,
    mode: &str,
    split_name: &str,
) -> Result<(Vec<i32>, Vec<f64>, Labels)> {
    let split = Split::load(&rt.manifest, task, split_name)?;
    let nl = rt.manifest.model.num_labels;
    let mut preds = Vec::with_capacity(split.len());
    let mut scores = Vec::with_capacity(split.len());
    for b in batches(&split, EVAL_BUCKET) {
        let logits = rt.infer(&task.name, mode, b.bucket, &b.ids, &b.type_ids, &b.mask)?;
        let v = logits.as_f32()?;
        for row in 0..b.real {
            let lg = &v[row * nl..(row + 1) * nl];
            if task.classes == 0 {
                scores.push(lg[0] as f64);
            } else {
                let (mut best, mut bi) = (f32::NEG_INFINITY, 0);
                for (i, x) in lg.iter().take(task.classes).enumerate() {
                    if *x > best {
                        best = *x;
                        bi = i;
                    }
                }
                preds.push(bi as i32);
            }
        }
    }
    Ok((preds, scores, split.labels))
}

/// Metric values for one (task, mode) on one split.
pub fn eval_split(
    rt: &mut Runtime,
    task: &TaskSpec,
    mode: &str,
    split_name: &str,
) -> Result<BTreeMap<String, f64>> {
    let (preds, scores, labels) = predict_split(rt, task, mode, split_name)?;
    let mut out = BTreeMap::new();
    match &labels {
        Labels::Class(ls) => {
            for m in &task.metrics {
                let v = metrics::compute(m, &metrics::MetricInput::Class {
                    preds: &preds,
                    labels: ls,
                });
                out.insert(m.clone(), v);
            }
        }
        Labels::Score(ls) => {
            let lf: Vec<f64> = ls.iter().map(|x| *x as f64).collect();
            for m in &task.metrics {
                let v = metrics::compute(m, &metrics::MetricInput::Reg {
                    scores: &scores,
                    labels: &lf,
                });
                out.insert(m.clone(), v);
            }
        }
    }
    Ok(out)
}

/// Full evaluation of one (task, mode) across its dev splits.
/// Keys like "acc", and "acc_mm" for the MNLI mismatched split.
pub fn eval_task(
    rt: &mut Runtime,
    task: &TaskSpec,
    mode: &str,
    calib_batches: usize,
    pct: f64,
) -> Result<BTreeMap<String, f64>> {
    ensure_checkpoint(rt, task, mode, calib_batches, pct)?;
    let mut out = BTreeMap::new();
    for split_name in task.splits.keys() {
        if split_name == "train" {
            continue;
        }
        let vals = eval_split(rt, task, mode, split_name)?;
        for (k, v) in vals {
            let key = if split_name == "dev" { k } else { format!("{k}_mm") };
            out.insert(key, v);
        }
    }
    Ok(out)
}

// --------------------------------------------------------- Table 2 layout

/// Format one task's metrics the way the paper's Table 2 prints them.
pub fn paper_cell(task: &str, m: &BTreeMap<String, f64>) -> String {
    let g = |k: &str| m.get(k).map(|v| format!("{:.2}", v * 100.0)).unwrap_or("-".into());
    match task {
        "cola" => g("mcc"),
        "mnli" => format!("{}/{}", g("acc"), g("acc_mm")),
        "mrpc" | "qqp" => format!("{}/{}", g("f1"), g("acc")),
        "qnli" | "rte" | "sst2" => g("acc"),
        "stsb" => format!("{}/{}", g("pearson"), g("spearman")),
        _ => format!("{m:?}"),
    }
}

pub fn paper_header(task: &str) -> &'static str {
    match task {
        "cola" => "CoLA Mcc",
        "mnli" => "MNLI-m/-mm Acc",
        "mrpc" => "MRPC F1/Acc",
        "qnli" => "QNLI Acc",
        "qqp" => "QQP F1/Acc",
        "rte" => "RTE Acc",
        "sst2" => "SST-2 Acc",
        "stsb" => "STS-B Pear/Spea",
        _ => "?",
    }
}

pub fn mode_label(mode: &str) -> String {
    match mode {
        "fp" => "FP32 (paper: FP16)".to_string(),
        m => format!("ZeroQuant-HERO-{}", m.to_uppercase()),
    }
}

/// Run the whole Table 2: tasks x modes.  Returns mode -> task -> metrics.
pub fn table2(
    rt: &mut Runtime,
    tasks: &[String],
    modes: &[String],
    calib_batches: usize,
    pct: f64,
    mut progress: impl FnMut(&str, &str),
) -> Result<BTreeMap<String, BTreeMap<String, BTreeMap<String, f64>>>> {
    let mut out: BTreeMap<String, BTreeMap<String, BTreeMap<String, f64>>> = BTreeMap::new();
    for mode in modes {
        for tname in tasks {
            progress(mode, tname);
            let task = rt.manifest.task(tname)?.clone();
            let vals = eval_task(rt, &task, mode, calib_batches, pct)
                .with_context(|| format!("eval {tname} {mode}"))?;
            out.entry(mode.clone()).or_default().insert(tname.clone(), vals);
        }
    }
    Ok(out)
}
