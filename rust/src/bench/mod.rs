//! Bench harness (criterion is unavailable offline — DESIGN.md §2):
//! warmup + timed iterations with mean/percentile reporting, plus table
//! formatting shared by the paper-reproduction benches.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl Stats {
    pub fn from_samples_us(mut v: Vec<f64>) -> Stats {
        assert!(!v.is_empty());
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| {
            let idx = ((v.len() - 1) as f64 * p).round() as usize;
            v[idx]
        };
        Stats {
            iters: v.len(),
            mean_us: v.iter().sum::<f64>() / v.len() as f64,
            p50_us: pick(0.50),
            p95_us: pick(0.95),
            p99_us: pick(0.99),
            min_us: v[0],
            max_us: v[v.len() - 1],
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    Stats::from_samples_us(samples)
}

/// Adaptive: run for at least `min_time_s` seconds, at least 5 iters.
pub fn bench_seconds<F: FnMut()>(warmup: usize, min_time_s: f64, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_time_s || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    Stats::from_samples_us(samples)
}

// ------------------------------------------------------------- formatting

/// Simple monospace table printer for the paper-reproduction benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn fmt_us(us: f64) -> String {
    if us >= 1e6 {
        format!("{:.2}s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{us:.0}us")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples_us((1..=100).map(|i| i as f64).collect());
        assert!(s.min_us <= s.p50_us);
        assert!(s.p50_us <= s.p95_us);
        assert!(s.p95_us <= s.p99_us);
        assert!(s.p99_us <= s.max_us);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench(2, 10, || n += 1);
        assert_eq!(s.iters, 10);
        assert_eq!(n, 12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(vec!["xxxx".into(), "1".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_us(500.0), "500us");
        assert_eq!(fmt_us(1500.0), "1.50ms");
        assert_eq!(fmt_us(2.5e6), "2.50s");
    }
}
