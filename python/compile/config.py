"""Model + quantization-mode configuration shared by all of L1/L2.

The quantization switch set mirrors Table 1 of the paper: six module-level
switches {embedding, qkv, attn, attn_output, fc1, fc2}, each independently
INT8 (True) or high-precision (False).  The named modes FP / M1 / M2 / M3
are the paper's presets; arbitrary combinations are legal and exercised by
the ablation benches.
"""

from dataclasses import dataclass, field, asdict, replace


@dataclass(frozen=True)
class ModelConfig:
    """BERT-style encoder hyperparameters.

    The repo-default model is a scaled-down BERT (the paper uses
    BERT_base; see DESIGN.md §2 for the substitution argument): the graph
    structure, quantization insertion points and calibration pipeline are
    identical, only the dimensions differ.
    """

    vocab_size: int = 2048
    hidden: int = 128
    layers: int = 4
    heads: int = 4
    ffn: int = 512
    max_seq: int = 128
    type_vocab: int = 2
    num_labels: int = 3  # padded; STS-B regression reads logits[:, 0]
    ln_eps: float = 1e-12

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


@dataclass(frozen=True)
class QuantSwitches:
    """Table 1 row: which modules run INT8."""

    embedding: bool = False
    qkv: bool = False
    attn: bool = False
    attn_output: bool = False
    fc1: bool = False
    fc2: bool = False

    def any(self) -> bool:
        return any(asdict(self).values())

    def tag(self) -> str:
        bits = [self.embedding, self.qkv, self.attn, self.attn_output, self.fc1, self.fc2]
        return "".join("1" if b else "0" for b in bits)


# The paper's presets (Table 1).  FP = the baseline row.
MODES = {
    "fp": QuantSwitches(),
    "m1": QuantSwitches(embedding=True, qkv=True, attn=False, attn_output=False, fc1=True, fc2=False),
    "m2": QuantSwitches(embedding=True, qkv=True, attn=True, attn_output=True, fc1=True, fc2=False),
    "m3": QuantSwitches(embedding=True, qkv=True, attn=True, attn_output=True, fc1=True, fc2=True),
}

# Named precision policies shipped in the manifest `policies` section
# (§3 mixed precision): base mode + ordered per-module-group overrides +
# an accuracy-fallback escalation chain.  The rust coordinator validates
# these against the mode table at load and serves them per request; the
# uniform per-mode policies are implicit and need no entry here.
POLICIES = {
    # paper-style recovery: keep everything INT8 but run the attention
    # output projection in full precision; no artifact matches that exact
    # switch set, so the chain escalates to the nearest safe mode.
    "attn-out-fp": {
        "base": "m3",
        "overrides": [["attn_output", "fp"]],
        "fallback": ["m2", "m1", "fp"],
    },
    # M3 with FC2 recovered — lands exactly on the M2 artifact.
    "fc2-fp": {
        "base": "m3",
        "overrides": [["fc2", "fp"]],
    },
}

# Symmetric int8 range used everywhere except Softmax^quant output,
# which is asymmetric (paper §2.2.2): softmax has no negative values, so the
# full [-128, 127] range is used with a fixed zero point of -128.
QMAX = 127.0
ASYM_LEVELS = 255.0
ASYM_ZERO_POINT = -128


def mode_switches(name: str) -> QuantSwitches:
    try:
        return MODES[name]
    except KeyError:
        raise ValueError(f"unknown mode {name!r}; expected one of {sorted(MODES)}") from None


def switches_from_tag(tag: str) -> QuantSwitches:
    """Inverse of QuantSwitches.tag(), for ablation sweeps ('101011' etc.)."""
    if len(tag) != 6 or set(tag) - {"0", "1"}:
        raise ValueError(f"bad switch tag {tag!r}")
    b = [c == "1" for c in tag]
    return QuantSwitches(
        embedding=b[0], qkv=b[1], attn=b[2], attn_output=b[3], fc1=b[4], fc2=b[5]
    )
