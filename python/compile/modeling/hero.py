"""ZeroQuant-HERO quantized encoder (paper §2.2), assembled from the L1
Pallas kernels with per-module precision switches (Table 1).

The forward consumes the *quantized* parameter set of
``params.hero_param_specs`` — int8 weights with scales already folded by
the rust ``quantize`` step (eqs. 20-23, 32) — so the graph contains no
dequantize kernels and no divisions on the hot path:

  * TWQ scales ride along with int8 activations out of each ``LN^quant``;
  * SQ/FWQ requantization is a bare ``Round`` in each GeMM epilogue;
  * the only standalone quantize ops appear for the "unfused" switch
    combinations the paper calls out as overhead (e.g. INT8 attention fed
    by an FP QKV GeMM).
"""

import jax.numpy as jnp

from ..config import ModelConfig, QuantSwitches
from ..kernels import (
    ln_quant, ln_quant_embed, twq_quantize,
    gemm_twq_to_i8, gemm_twq_to_f32, gemm_folded_to_i8, gemm_folded_to_f32,
    gelu_quant, attention_quant,
)
from ..kernels.ref import attention_fp, gelu, round_clamp_i8
from .bert import layer_norm, split_heads, merge_heads, embed

MASK_BIG = 1e9


def _dequant_twq(x_i8, s):
    return x_i8.astype(jnp.float32) * s


def _split_heads_i8(x, b, s, h, dh):
    return x.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)


def hero_forward(params, cfg: ModelConfig, sw: QuantSwitches,
                 input_ids, type_ids, attn_mask):
    """Quantized forward.  Returns logits f32 [b, num_labels].

    ``params``: dict name -> array matching hero_param_specs(cfg, sw).
    """
    b, s = input_ids.shape
    d, h, dh, f = cfg.hidden, cfg.heads, cfg.head_dim, cfg.ffn
    eps = cfg.ln_eps

    # ---------------- embedding (paper §2.2.1) ----------------
    x_t, x_pb = embed(params, cfg, input_ids, type_ids)
    if sw.embedding:
        # TWQ the token-embedding gather output, then the quant-aware LN
        # consumes INT8 and emits INT8 (eq. 7) — 2x data-volume reduction.
        xt_i8, st = twq_quantize(x_t)
        x, s_x = ln_quant_embed(xt_i8, x_pb, params["emb.ln.g"],
                                params["emb.ln.b"], t_scale=st, eps=eps)
        x_is_i8 = True
    else:
        x = layer_norm(x_t + x_pb, params["emb.ln.g"], params["emb.ln.b"], eps)
        s_x = None
        x_is_i8 = False

    kmask = jnp.repeat(attn_mask, h, axis=0)  # [b*h, s] keys mask

    for i in range(cfg.layers):
        p = f"L{i}."

        # ---- reconcile layer input with the QKV precision
        if sw.qkv and not x_is_i8:
            x, s_x = twq_quantize(x)          # standalone quant (unfused cost)
            x_is_i8 = True
        elif not sw.qkv and x_is_i8:
            x = _dequant_twq(x, s_x)          # INT8 stream into FP module
            x_is_i8 = False

        # residual operands for LN1 (kept in whatever precision x has)
        resid_i8, resid_s, resid_f = (x, s_x, None) if x_is_i8 else (None, None, x)

        # ---------------- attention (paper §2.2.2) ----------------
        if sw.qkv:
            if sw.attn:
                # INT8 GeMM, epilogue Round -> SQ int8 (eq. 22)
                qs = [gemm_twq_to_i8(
                    x, params[p + f"attn.{t}.wq"], s_x,
                    params[p + f"attn.{t}.ws"].reshape(1, d),
                    params[p + f"attn.{t}.b"].reshape(1, d)) for t in "qkv"]
                q_i8, k_i8, v_i8 = qs
            else:
                # INT8 GeMM with dequant epilogue -> f32 Q/K/V
                q, k, v = (gemm_twq_to_f32(
                    x, params[p + f"attn.{t}.wq"], s_x,
                    params[p + f"attn.{t}.ws"].reshape(1, d),
                    params[p + f"attn.{t}.b"].reshape(1, d)) for t in "qkv")
        else:
            xf = resid_f
            q = xf @ params[p + "attn.q.w"] + params[p + "attn.q.b"]
            k = xf @ params[p + "attn.k.w"] + params[p + "attn.k.b"]
            v = xf @ params[p + "attn.v.w"] + params[p + "attn.v.b"]
            if sw.attn:
                # fp QKV into INT8 attention: on-the-fly SQ (unfused cost)
                q_i8 = round_clamp_i8(q * params[p + "attn.inv_sq_q"])
                k_i8 = round_clamp_i8(k * params[p + "attn.inv_sq_k"])
                v_i8 = round_clamp_i8(v * params[p + "attn.inv_sq_v"])

        if sw.attn:
            qh = _split_heads_i8(q_i8, b, s, h, dh)
            kh = _split_heads_i8(k_i8, b, s, h, dh)
            vh = _split_heads_i8(v_i8, b, s, h, dh)
            pv = jnp.tile(params[p + "attn.pv_scale"].reshape(h, 1, dh), (b, 1, 1))
            attn_i8 = attention_quant(
                qh, kh, vh, kmask,
                params[p + "attn.qk_scale"].reshape(1, 1),
                params[p + "attn.sp"].reshape(1, 1), pv)
            x_attn_i8 = merge_heads(attn_i8, b, s, h, dh)  # FWQ S_attn domain
        else:
            qh = split_heads(q, b, s, h, dh)
            kh = split_heads(k, b, s, h, dh)
            vh = split_heads(v, b, s, h, dh)
            attn = attention_fp(qh, kh, vh, kmask,
                                1.0 / jnp.sqrt(dh).astype(jnp.float32))
            x_attn = merge_heads(attn, b, s, h, dh)

        # ---- attention output projection
        if sw.attn_output:
            if not sw.attn:
                # FWQ-quantize fp X_attn on the fly (unfused cost)
                x_attn_i8 = round_clamp_i8(
                    x_attn * params[p + "attn.inv_s_attn"].reshape(1, d))
            # folded W~_o (eq. 23): epilogue Round -> X_o int8 in S_o domain
            xo_i8 = gemm_folded_to_i8(
                x_attn_i8, params[p + "attn.o.wq"],
                params[p + "attn.o.ws"].reshape(1, d),
                params[p + "attn.o.bq"].reshape(1, d))
            ln_b, ln_b_scale = xo_i8, params[p + "ln1.so"].reshape(1, d)
        else:
            if sw.attn:
                x_attn = _dequant_twq(x_attn_i8, params[p + "attn.s_attn"].reshape(1, d))
            x_o = x_attn @ params[p + "attn.o.w"] + params[p + "attn.o.b"]
            ln_b, ln_b_scale = x_o, None

        # ---- LN^quant (eq. 19): output INT8 iff FC1 runs INT8
        if sw.fc1:
            x, s_x = ln_quant(
                resid_i8 if resid_i8 is not None else resid_f, ln_b,
                params[p + "ln1.g"], params[p + "ln1.b"],
                a_scale=resid_s, b_scale=ln_b_scale, quantize_out=True, eps=eps)
            x_is_i8 = True
        else:
            x = ln_quant(
                resid_i8 if resid_i8 is not None else resid_f, ln_b,
                params[p + "ln1.g"], params[p + "ln1.b"],
                a_scale=resid_s, b_scale=ln_b_scale, quantize_out=False, eps=eps)
            s_x, x_is_i8 = None, False

        resid_i8, resid_s, resid_f = (x, s_x, None) if x_is_i8 else (None, None, x)

        # ---------------- MLP (paper §2.2.3) ----------------
        if sw.fc1:
            x1 = gemm_twq_to_f32(
                x, params[p + "fc1.wq"], s_x,
                params[p + "fc1.ws"].reshape(1, f),
                params[p + "fc1.b"].reshape(1, f))
        else:
            x1 = resid_f @ params[p + "fc1.w"] + params[p + "fc1.b"]

        if sw.fc2:
            a_i8 = gelu_quant(x1, params[p + "gelu.sa"].reshape(1, f))
            x2_i8 = gemm_folded_to_i8(
                a_i8, params[p + "fc2.wq"],
                params[p + "fc2.ws"].reshape(1, d),
                params[p + "fc2.bq"].reshape(1, d))
            ln_b, ln_b_scale = x2_i8, params[p + "ln2.sx2"].reshape(1, d)
        else:
            a_act = gelu(x1)
            x2 = a_act @ params[p + "fc2.w"] + params[p + "fc2.b"]
            ln_b, ln_b_scale = x2, None

        # ---- LN^quant (eq. 31): output INT8 iff next consumer is INT8
        next_i8 = sw.qkv if i + 1 < cfg.layers else False
        if next_i8:
            x, s_x = ln_quant(
                resid_i8 if resid_i8 is not None else resid_f, ln_b,
                params[p + "ln2.g"], params[p + "ln2.b"],
                a_scale=resid_s, b_scale=ln_b_scale, quantize_out=True, eps=eps)
            x_is_i8 = True
        else:
            x = ln_quant(
                resid_i8 if resid_i8 is not None else resid_f, ln_b,
                params[p + "ln2.g"], params[p + "ln2.b"],
                a_scale=resid_s, b_scale=ln_b_scale, quantize_out=False, eps=eps)
            s_x, x_is_i8 = None, False

    assert not x_is_i8
    cls = x.reshape(b, s, d)[:, 0]
    pooled = jnp.tanh(cls @ params["pool.w"] + params["pool.b"])
    return pooled @ params["cls.w"] + params["cls.b"]
