//! Typed view over `artifacts/manifest.json` — the L2→L3 contract
//! (model config, per-mode parameter signatures, artifact paths, tasks).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};

use super::tensor::DType;

/// Dense route id for a task, an index into `Manifest::task_order`.
///
/// The manifest is the single source of truth for the id space: every
/// component that loads the same `manifest.json` (coordinator, engine
/// thread, CLI) derives identical ids, so they can be passed across
/// threads without a handshake.  Strings are resolved to ids exactly once
/// at admission (DESIGN.md §5.2); everything downstream is `Copy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u16);

/// Dense route id for a precision mode, an index into `Manifest::mode_order`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModeId(pub u16);

impl TaskId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl ModeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// The one definition of name -> dense-id interning, shared by
/// `Manifest::{task_id,mode_id}` and the engine's mirrored route tables.
pub fn intern_position(order: &[String], name: &str) -> Option<u16> {
    order.iter().position(|n| n == name).map(|i| i as u16)
}

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub vocab_size: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub type_vocab: usize,
    pub num_labels: usize,
    pub ln_eps: f64,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Switches {
    pub embedding: bool,
    pub qkv: bool,
    pub attn: bool,
    pub attn_output: bool,
    pub fc1: bool,
    pub fc2: bool,
}

impl Switches {
    pub const ALL_OFF: Switches = Switches {
        embedding: false,
        qkv: false,
        attn: false,
        attn_output: false,
        fc1: false,
        fc2: false,
    };

    pub fn tag(&self) -> String {
        [self.embedding, self.qkv, self.attn, self.attn_output, self.fc1, self.fc2]
            .iter()
            .map(|b| if *b { '1' } else { '0' })
            .collect()
    }

    /// Table-1 row as the paper prints it.
    pub fn row(&self) -> [bool; 6] {
        [self.embedding, self.qkv, self.attn, self.attn_output, self.fc1, self.fc2]
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ModeSpec {
    pub name: String,
    pub switches: Switches,
    pub params: Vec<ParamSpec>,
    /// bucket (batch size) -> artifact path relative to the artifacts root.
    pub artifacts: BTreeMap<usize, String>,
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    /// 0 = regression (STS-B).
    pub classes: usize,
    pub metrics: Vec<String>,
    pub splits: BTreeMap<String, String>,
    pub checkpoint: String,
}

#[derive(Debug, Clone)]
pub struct CalibSpec {
    pub artifact: String,
    pub batch: usize,
    pub params: Vec<ParamSpec>,
    /// stat name -> shape, in artifact output order (after logits).
    pub stats: Vec<(String, Vec<usize>)>,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub model: ModelCfg,
    pub seq: usize,
    pub buckets: Vec<usize>,
    pub modes: BTreeMap<String, ModeSpec>,
    /// Mode order as listed in the manifest (fp, m1, m2, m3).
    pub mode_order: Vec<String>,
    pub calib: CalibSpec,
    pub tasks: BTreeMap<String, TaskSpec>,
    pub task_order: Vec<String>,
    pub micro: BTreeMap<String, String>,
}

fn parse_specs(v: &Value) -> Result<Vec<ParamSpec>> {
    let mut out = Vec::new();
    for item in v.as_array().context("params not an array")? {
        let t = item.as_array().context("param spec not an array")?;
        if t.len() != 3 {
            bail!("param spec must be [name, shape, dtype]");
        }
        let shape = t[1]
            .as_array()
            .context("shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        out.push(ParamSpec {
            name: t[0].as_str().context("name")?.to_string(),
            shape,
            dtype: DType::from_manifest(t[2].as_str().context("dtype")?)?,
        });
    }
    Ok(out)
}

fn get_usize(v: &Value, key: &str) -> Result<usize> {
    v.req(key)?.as_usize().with_context(|| format!("{key} not a number"))
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let v = json::parse(&src).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let m = v.req("model")?;
        let model = ModelCfg {
            vocab_size: get_usize(m, "vocab_size")?,
            hidden: get_usize(m, "hidden")?,
            layers: get_usize(m, "layers")?,
            heads: get_usize(m, "heads")?,
            ffn: get_usize(m, "ffn")?,
            max_seq: get_usize(m, "max_seq")?,
            type_vocab: get_usize(m, "type_vocab")?,
            num_labels: get_usize(m, "num_labels")?,
            ln_eps: m.req("ln_eps")?.as_f64().context("ln_eps")?,
        };

        let buckets = v
            .req("buckets")?
            .as_array()
            .context("buckets")?
            .iter()
            .map(|b| b.as_usize().context("bucket"))
            .collect::<Result<Vec<_>>>()?;

        let mut modes = BTreeMap::new();
        let mut mode_order = Vec::new();
        for (name, mv) in v.req("modes")?.as_object().context("modes")? {
            let swv = mv.req("switches")?;
            let flag = |k: &str| -> Result<bool> {
                swv.req(k)?.as_bool().with_context(|| format!("switch {k}"))
            };
            let switches = Switches {
                embedding: flag("embedding")?,
                qkv: flag("qkv")?,
                attn: flag("attn")?,
                attn_output: flag("attn_output")?,
                fc1: flag("fc1")?,
                fc2: flag("fc2")?,
            };
            let mut artifacts = BTreeMap::new();
            for (bk, pv) in mv.req("artifacts")?.as_object().context("artifacts")? {
                let bucket: usize = bk
                    .strip_prefix('b')
                    .and_then(|s| s.parse().ok())
                    .with_context(|| format!("bad bucket key {bk}"))?;
                artifacts.insert(bucket, pv.as_str().context("artifact path")?.to_string());
            }
            mode_order.push(name.clone());
            modes.insert(
                name.clone(),
                ModeSpec {
                    name: name.clone(),
                    switches,
                    params: parse_specs(mv.req("params")?)?,
                    artifacts,
                },
            );
        }

        let cv = v.req("calib")?;
        let mut stats = Vec::new();
        for item in cv.req("stats")?.as_array().context("stats")? {
            let t = item.as_array().context("stat spec")?;
            let shape = t[1]
                .as_array()
                .context("stat shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            stats.push((t[0].as_str().context("stat name")?.to_string(), shape));
        }
        let calib = CalibSpec {
            artifact: cv.req("artifact")?.as_str().context("calib artifact")?.to_string(),
            batch: get_usize(cv, "batch")?,
            params: parse_specs(cv.req("params")?)?,
            stats,
        };

        let mut tasks = BTreeMap::new();
        let mut task_order = Vec::new();
        for (name, tv) in v.req("tasks")?.as_object().context("tasks")? {
            let mut splits = BTreeMap::new();
            for (sn, sv) in tv.req("splits")?.as_object().context("splits")? {
                splits.insert(sn.clone(), sv.as_str().context("split path")?.to_string());
            }
            let metrics = tv
                .req("metrics")?
                .as_array()
                .context("metrics")?
                .iter()
                .map(|x| x.as_str().map(str::to_string).context("metric"))
                .collect::<Result<Vec<_>>>()?;
            task_order.push(name.clone());
            tasks.insert(
                name.clone(),
                TaskSpec {
                    name: name.clone(),
                    classes: get_usize(tv, "classes")?,
                    metrics,
                    splits,
                    checkpoint: tv.req("checkpoint")?.as_str().context("checkpoint")?.to_string(),
                },
            );
        }

        let mut micro = BTreeMap::new();
        if let Some(mv) = v.get("micro").and_then(|x| x.as_object()) {
            for (k, pv) in mv {
                if let Some(p) = pv.as_str() {
                    micro.insert(k.clone(), p.to_string());
                }
            }
        }

        Ok(Manifest {
            root: artifacts_dir.to_path_buf(),
            model,
            seq: get_usize(&v, "seq")?,
            buckets,
            modes,
            mode_order,
            calib,
            tasks,
            task_order,
            micro,
        })
    }

    pub fn mode(&self, name: &str) -> Result<&ModeSpec> {
        self.modes
            .get(name)
            .with_context(|| format!("unknown mode {name:?} (have {:?})", self.mode_order))
    }

    pub fn task(&self, name: &str) -> Result<&TaskSpec> {
        self.tasks
            .get(name)
            .with_context(|| format!("unknown task {name:?} (have {:?})", self.task_order))
    }

    // ------------------------------------------------------ route interning

    pub fn num_tasks(&self) -> usize {
        self.task_order.len()
    }

    pub fn num_modes(&self) -> usize {
        self.mode_order.len()
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Resolve a task name to its dense id (position in `task_order`).
    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        intern_position(&self.task_order, name)
            .map(TaskId)
            .with_context(|| format!("unknown task {name:?} (have {:?})", self.task_order))
    }

    /// Resolve a mode name to its dense id (position in `mode_order`).
    pub fn mode_id(&self, name: &str) -> Result<ModeId> {
        intern_position(&self.mode_order, name)
            .map(ModeId)
            .with_context(|| format!("unknown mode {name:?} (have {:?})", self.mode_order))
    }

    pub fn task_name(&self, id: TaskId) -> &str {
        &self.task_order[id.index()]
    }

    pub fn mode_name(&self, id: ModeId) -> &str {
        &self.mode_order[id.index()]
    }

    pub fn task_by_id(&self, id: TaskId) -> &TaskSpec {
        &self.tasks[&self.task_order[id.index()]]
    }

    pub fn mode_by_id(&self, id: ModeId) -> &ModeSpec {
        &self.modes[&self.mode_order[id.index()]]
    }

    /// Dense index of an exact bucket size (for `Vec`-indexed exe tables).
    pub fn bucket_index(&self, bucket: usize) -> Result<usize> {
        self.buckets
            .iter()
            .position(|b| *b == bucket)
            .with_context(|| format!("bucket {bucket} not in manifest buckets {:?}", self.buckets))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Smallest bucket >= n, or the largest bucket if n exceeds all.
    pub fn bucket_for(&self, n: usize) -> usize {
        for b in &self.buckets {
            if *b >= n {
                return *b;
            }
        }
        *self.buckets.last().expect("no buckets")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let man = Manifest {
            root: PathBuf::new(),
            model: ModelCfg {
                vocab_size: 1, hidden: 1, layers: 1, heads: 1, ffn: 1,
                max_seq: 1, type_vocab: 1, num_labels: 1, ln_eps: 1e-12,
            },
            seq: 128,
            buckets: vec![1, 4, 8, 16],
            modes: BTreeMap::new(),
            mode_order: vec![],
            calib: CalibSpec { artifact: String::new(), batch: 16, params: vec![], stats: vec![] },
            tasks: BTreeMap::new(),
            task_order: vec![],
            micro: BTreeMap::new(),
        };
        assert_eq!(man.bucket_for(1), 1);
        assert_eq!(man.bucket_for(2), 4);
        assert_eq!(man.bucket_for(4), 4);
        assert_eq!(man.bucket_for(9), 16);
        assert_eq!(man.bucket_for(99), 16);
    }

    #[test]
    fn route_ids_are_dense_and_roundtrip() {
        let man = Manifest {
            root: PathBuf::new(),
            model: ModelCfg {
                vocab_size: 1, hidden: 1, layers: 1, heads: 1, ffn: 1,
                max_seq: 1, type_vocab: 1, num_labels: 1, ln_eps: 1e-12,
            },
            seq: 128,
            buckets: vec![1, 4, 8, 16],
            modes: BTreeMap::new(),
            mode_order: vec!["fp".into(), "m1".into(), "m3".into()],
            calib: CalibSpec { artifact: String::new(), batch: 16, params: vec![], stats: vec![] },
            tasks: BTreeMap::new(),
            task_order: vec!["cola".into(), "sst2".into()],
            micro: BTreeMap::new(),
        };
        assert_eq!(man.task_id("sst2").unwrap(), TaskId(1));
        assert_eq!(man.mode_id("m3").unwrap(), ModeId(2));
        assert_eq!(man.task_name(TaskId(1)), "sst2");
        assert_eq!(man.mode_name(ModeId(0)), "fp");
        assert!(man.task_id("nope").is_err());
        assert!(man.mode_id("m9").is_err());
        assert_eq!(man.bucket_index(8).unwrap(), 2);
        assert!(man.bucket_index(5).is_err());
        assert_eq!(man.num_tasks(), 2);
        assert_eq!(man.num_modes(), 3);
    }

    #[test]
    fn switches_tag() {
        let mut sw = Switches::ALL_OFF;
        sw.embedding = true;
        sw.fc1 = true;
        assert_eq!(sw.tag(), "100010");
    }
}
